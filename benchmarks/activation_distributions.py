"""Paper Figs. 1–2 — input-activation magnitude distributions at k_proj
(systematic outliers) and down_proj (massive outliers) under each
transform.  Emits the summary statistics the figures visualize: channel
-magnitude max/mean ratio (peakedness), difficulty, kurtosis.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_suite, timeit
from repro.core.difficulty import (
    channel_magnitudes,
    kurtosis,
    quantization_difficulty,
)
from repro.core.transforms import TRANSFORMS


def _stats(x):
    cm = np.asarray(channel_magnitudes(x))
    return {
        "peak_ratio": float(cm.max() / max(cm.mean(), 1e-9)),
        "difficulty": float(quantization_difficulty(x)),
        "kurtosis": float(kurtosis(x)),
        "absmax": float(np.abs(np.asarray(x)).max()),
    }


def run() -> dict:
    suite = make_suite()
    picks = {
        "fig1_k_proj_1": next(c for c in suite
                              if c.module == "k_proj" and c.layer == 1),
        "fig2_down_proj_30": next(c for c in suite
                                  if c.module == "down_proj"
                                  and c.layer == 30),
    }
    out = {}
    t_us = timeit(lambda c=picks["fig1_k_proj_1"]: channel_magnitudes(c.x))
    for fig, case in picks.items():
        for kind, tf in TRANSFORMS.items():
            xh, _ = tf(case.x, case.w)
            s = _stats(xh)
            out[(fig, kind)] = s
            emit(f"{fig}_{kind}", t_us if kind == "none" else 0.0,
                 f"peak_ratio={s['peak_ratio']:.1f};difficulty="
                 f"{s['difficulty']:.1f};absmax={s['absmax']:.1f}")
    # figure-level claims: smoothing flattens activations harder than
    # rotation (paper §IV-C) except under massive outliers the rotated
    # absmax stays high (Eq. 8)
    k = ("fig1_k_proj_1", "smooth"), ("fig1_k_proj_1", "rotate")
    emit("fig1_smooth_flatter_than_rotate", 0.0,
         f"holds={out[k[0]]['difficulty'] < out[k[1]]['difficulty']}")
    m = ("fig2_down_proj_30", "rotate"), ("fig2_down_proj_30", "smooth_rotate")
    emit("fig2_smoothrot_absmax_below_rotate", 0.0,
         f"holds={out[m[1]]['absmax'] < out[m[0]]['absmax']}")
    return {f"{a}_{b}": v for (a, b), v in out.items()}


if __name__ == "__main__":
    run()
