"""Auto-plan vs fixed §V plan: summed Eq. (2) layer-wise error across
model families (dense, moe, ssm).

The searched per-layer plan force-includes the fixed plan's choice per
(layer, module) in its candidate set, so under the shared error metric
the auto plan is ≤ the fixed plan by construction — this benchmark
measures HOW MUCH better the per-layer search is, per family, and emits
the machine-readable rows EXPERIMENTS tracking consumes.

Usage: PYTHONPATH=src python -m benchmarks.autoplan_quality
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.autoplan import LayerwisePlan, SearchConfig, plan_errors, search_plan
from repro.configs.base import get_config
from repro.core.transforms import TransformPlan
from repro.launch import compat
from repro.models.api import get_model
from repro.serving.fold import collect_calibration

ARCHS = (
    ("stablelm_3b", "dense"),
    ("deepseek_v2_lite_16b", "moe"),
    ("mamba2_780m", "ssm"),
)


def run(keep_samples: int = 128) -> dict:
    key = jax.random.PRNGKey(0)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    out: dict[str, float] = {}
    with compat.set_mesh(mesh):
        for arch, family in ARCHS:
            cfg = get_config(arch).reduced()
            model = get_model(cfg)
            params = model.init(key, cfg)
            toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
            stats = collect_calibration(model, params, cfg, [{"tokens": toks}],
                                        keep_samples=keep_samples)
            search = SearchConfig()
            auto, _ = search_plan(params, cfg, stats, search=search)
            fixed = LayerwisePlan.from_global(
                TransformPlan(), auto.num_layers, arch=cfg.name)
            e_auto = sum(float(np.sum(v)) for v in
                         plan_errors(auto, params, cfg, stats, search).values())
            e_fixed = sum(float(np.sum(v)) for v in
                          plan_errors(fixed, params, cfg, stats, search).values())
            win = e_auto <= e_fixed
            gain = 0.0 if e_fixed == 0 else 100.0 * (1 - e_auto / e_fixed)
            out[f"{arch}_auto"] = e_auto
            out[f"{arch}_fixed"] = e_fixed
            emit(f"autoplan_error_{family}_{arch}", 0.0,
                 f"auto={e_auto:.4g};fixed={e_fixed:.4g};"
                 f"gain={gain:.1f}%;auto_le_fixed={win}")
    return out


if __name__ == "__main__":
    run()
