"""Shared benchmark substrate: a synthetic 'LLaMA2-7B-like' module suite.

Real LLaMA2-7B weights/WikiText-2 are unavailable offline (DESIGN.md §8),
so the paper's figures are reproduced on a synthetic suite calibrated to
its reported observations (§IV-A):

  * 32 decoder layers × 4 tapped modules (k_proj, o_proj, gate_proj @
    d=4096; down_proj @ d=11008 — the real LLaMA2-7B dims);
  * systematic outliers (hot channels across all tokens) in attention and
    gate/up inputs, strength rising toward later layers (Fig. 3 trend);
  * MASSIVE token-level outliers (|o| > 1000) at down_proj of layers 1
    and 30, and many-token large activations at down_proj 31;
  * weights ~N(0, 0.02²) with a few hot input-rows, difficulty below
    activations' (paper: "no substantial outliers occur in weights").

Sequence length 128 matches the paper's sample (§III-A).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.outliers import OutlierSpec, synth_activations, synth_weight

N_LAYERS = 32
N_TOKENS = 128
D_ATTN = 4096
D_FFN = 11008
MASSIVE_LAYERS = (1, 30)
HEAVY_LAST = 31

MODULES = ("k_proj", "o_proj", "gate_proj", "down_proj")


@dataclasses.dataclass(frozen=True)
class ModuleCase:
    layer: int
    module: str
    x: jax.Array   # (tokens, c_in)
    w: jax.Array   # (c_in, c_out)

    @property
    def name(self) -> str:
        return f"{self.module}_{self.layer}"

    @property
    def has_massive(self) -> bool:
        return self.module == "down_proj" and self.layer in MASSIVE_LAYERS


def _spec_for(layer: int, module: str) -> OutlierSpec:
    depth = layer / (N_LAYERS - 1)
    if module == "down_proj":
        if layer in MASSIVE_LAYERS:
            # massive outliers are TOKEN-specific; the paper finds them at
            # down_proj withOUT a strong systematic-channel structure —
            # that is precisely why rotation has nothing to win on the
            # bulk tokens and loses on the massive ones (§IV-D)
            return OutlierSpec(
                n_tokens=N_TOKENS, d=D_FFN, base_std=0.25,
                n_systematic=0,
                n_massive_tokens=2, n_massive_dims=2, massive_value=1600.0)
        if layer == HEAVY_LAST:
            # many tokens with large values (paper: down_proj 31)
            return OutlierSpec(
                n_tokens=N_TOKENS, d=D_FFN, base_std=0.3,
                n_systematic=10, systematic_scale=45.0,
                n_massive_tokens=24, n_massive_dims=3, massive_value=220.0)
        # n_systematic ∝ d keeps the pooled error/difficulty² slope aligned
        # across module widths (slope ∝ d/n_sys); scales stay in the
        # Δ ≲ 3σ_bulk regime where RTN noise is uniform — beyond it bulk
        # values round to zero and the error saturates (error_vs_difficulty)
        return OutlierSpec(n_tokens=N_TOKENS, d=D_FFN, base_std=1.0,
                           n_systematic=16, systematic_scale=3 + 17 * depth,
                           systematic_jitter=0.1)
    # attention + gate: systematic outliers growing with depth; k_proj
    # difficulty peaks mid-model (paper Fig. 3a)
    scale = {
        "k_proj": 3 + 17 * (1 - abs(2 * depth - 1)),
        "o_proj": 3 + 14 * depth,
        "gate_proj": 3 + 17 * depth,
    }[module]
    return OutlierSpec(n_tokens=N_TOKENS, d=D_ATTN, base_std=1.0,
                       n_systematic=6, systematic_scale=scale,
                       systematic_jitter=0.1)


def make_suite(seed: int = 0) -> list[ModuleCase]:
    cases = []
    for layer in range(N_LAYERS):
        for module in MODULES:
            spec = _spec_for(layer, module)
            kx = jax.random.PRNGKey(seed * 7919 + layer * 37
                                    + MODULES.index(module))
            # one weight draw PER MODULE (not per layer): layer-to-layer
            # error variation then reflects the activations, as in Fig. 3
            kw = jax.random.PRNGKey(seed * 104729 + MODULES.index(module))
            if layer == HEAVY_LAST:
                kw = jax.random.fold_in(kw, 1)
            x = synth_activations(kx, spec)
            c_in = spec.d
            # proxy c_out equalized across modules so the pooled
            # error/difficulty² slope (∝ ||W||_F² ∝ c_out) is comparable
            c_out = D_ATTN
            # weights: matched statistics across cases so the error ~
            # difficulty² relation isn't confounded by ||W|| variation
            # (the paper's weights are near-uniform in difficulty, §IV-B);
            # std compensates c_in so E||Wcol||² matches across module dims;
            # the last layer gets hot rows (gate_proj/down_proj 31 anomaly)
            w_hot = 8 if layer == HEAVY_LAST else 0
            std = 0.02 * (D_ATTN / c_in) ** 0.5
            w = synth_weight(kw, c_in, c_out // 8, std=std,
                             n_hot_rows=w_hot, hot_scale=5.0)
            cases.append(ModuleCase(layer, module, x, w))
    return cases


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (CPU; relative only)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
