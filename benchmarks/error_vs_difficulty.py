"""Paper Fig. 3 — layer-wise quantization error & quantization difficulty.

Per (layer × module): Eq. (2) error at W4A4, activation difficulty
(std of channel magnitudes), weight difficulty.  Headline claim (§IV-B):
corr(error, activation difficulty²) > 0.97 once the massive-outlier
modules (down_proj 1/30/31, gate_proj 31) are excluded.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import MASSIVE_LAYERS, HEAVY_LAST, emit, make_suite, timeit
from repro.core.difficulty import layerwise_error, quantization_difficulty


def run() -> dict:
    suite = make_suite()
    rows = []
    t_us = timeit(lambda c=suite[0]: layerwise_error(c.x, c.w))
    for case in suite:
        err = float(layerwise_error(case.x, case.w))
        dx = float(quantization_difficulty(case.x))
        dw = float(quantization_difficulty(case.w))
        excluded = (case.module == "down_proj"
                    and case.layer in (*MASSIVE_LAYERS, HEAVY_LAST)) or \
                   (case.module == "gate_proj" and case.layer == HEAVY_LAST)
        rows.append((case.name, err, dx, dw, excluded))
    errs = np.array([r[1] for r in rows if not r[4]])
    dx2 = np.array([r[2] ** 2 for r in rows if not r[4]])
    corr = float(np.corrcoef(errs, dx2)[0, 1])
    # weight difficulty generally below activation difficulty (paper §IV-B)
    frac_w_below = float(np.mean([r[3] < r[2] for r in rows]))
    emit("fig3_error_vs_difficulty", t_us,
         f"corr={corr:.4f};target>0.97;w_below_act_frac={frac_w_below:.2f}")
    # per-module error trend: monotone-ish growth except k_proj mid-peak
    for module in ("k_proj", "down_proj"):
        series = [r[1] for r in rows if r[0].startswith(module) and not r[4]]
        emit(f"fig3_{module}_error_range", 0.0,
             f"first={series[0]:.3e};last={series[-1]:.3e}")
    return {"corr": corr, "rows": rows}


if __name__ == "__main__":
    run()
