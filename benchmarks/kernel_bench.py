"""Kernel micro-benchmarks (infrastructure table): the XLA-native integer
serving path vs the bf16 baseline, per shape class.  CPU wall times are
RELATIVE indicators only (the TPU numbers come from the dry-run roofline);
the derived column carries the arithmetic-intensity facts that transfer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.hadamard import apply_hadamard
from repro.core.qlinear import QuantPolicy, qlinear, quantize_weight
from repro.kernels import ops, ref

SHAPES = [(64, 2048, 2048), (128, 4096, 1024)]


def run() -> dict:
    out = {}
    key = jax.random.PRNGKey(0)
    for n, k, m in SHAPES:
        x = jax.random.normal(key, (n, k)).astype(jnp.bfloat16)
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, m)) * 0.02
        wb = w.astype(jnp.bfloat16)
        qw4 = quantize_weight(w, bits=4, pack=True)
        qw8 = quantize_weight(w, bits=8, pack=False)

        t_bf16 = timeit(jax.jit(lambda a, b: a @ b), x, wb)
        pol4 = QuantPolicy(weight_bits=4, act_bits=4, use_kernels="never")
        pol8 = QuantPolicy(weight_bits=8, act_bits=8, use_kernels="never")
        t_w4 = timeit(jax.jit(lambda a, q=qw4: qlinear(a, q, pol4)), x)
        t_w8 = timeit(jax.jit(lambda a, q=qw8: qlinear(a, q, pol8)), x)
        t_had = timeit(jax.jit(lambda a: apply_hadamard(a, k)), x)
        t_qnt = timeit(jax.jit(lambda a: ref.quantize_per_token_ref(a, 4)), x)

        tag = f"{n}x{k}x{m}"
        hbm_bf16 = (n * k + k * m) * 2
        hbm_w4 = n * k * 2 + k * m // 2
        emit(f"kernel_matmul_bf16_{tag}", t_bf16, f"hbm_bytes={hbm_bf16}")
        emit(f"kernel_qlinear_w4a4_{tag}", t_w4,
             f"hbm_bytes={hbm_w4};weight_traffic_saving="
             f"{(k*m*2)/(k*m//2):.1f}x")
        emit(f"kernel_qlinear_w8a8_{tag}", t_w8, f"hbm_bytes={n*k*2+k*m}")
        emit(f"kernel_hadamard_fast_{tag}", t_had,
             f"flops_vs_dense={2*k*sum(s for s in [k])}")
        emit(f"kernel_quantize_token_{tag}", t_qnt, "pass=reduce+round")
        out[tag] = dict(bf16=t_bf16, w4=t_w4, w8=t_w8, had=t_had)

    # interpret-mode Pallas kernels (correctness-path timing, small shape)
    x = jax.random.normal(key, (16, 512)).astype(jnp.bfloat16)
    t_pal = timeit(lambda: ops.fused_hadamard_quant(x, block=128,
                                                    interpret=True))
    emit("kernel_pallas_fused_hadamard_quant_interpret_16x512", t_pal,
         "interpret-mode (CPU emulation; TPU target)")
    return out


if __name__ == "__main__":
    run()
