"""Kernel micro-benchmarks (infrastructure table).

Three parts:

1. Fused vs staged quant-linear: the one-pass ``ops.fused_qlinear``
   kernel against the staged ``ops.fused_quant_matmul`` composition it
   replaces (XLA pre-rotation → hadamard-quant kernel → quant-matmul
   kernel).  Wall times run through the Pallas INTERPRETER on CPU and
   are relative indicators only; the transferable facts are the
   HBM-bytes-moved accounting (3 activation round trips → 1) and the
   TPU-v5e roofline model derived from it (launch/roofline.py HW
   constants) — that model is the fused ≥ staged throughput claim.
   Results land in ``experiments/kernels/BENCH_kernels.json`` so the
   perf trajectory records across PRs, and benchmarks/report.py renders
   the §Kernels table from it.

2. Paged-attention decode: the in-VMEM Pallas kernel
   (``ops.paged_attention``) against the XLA gather path it replaces
   (``paged_view`` materializes each slot's pages contiguously, then
   attention re-reads the copy — every cached byte crosses HBM three
   times per layer per tick, int8 pools inflating to bf16 on the way).
   Same artifact, rows tagged ``kind="paged_attention"``; the CI gate
   holds the modeled tok/s and the strictly-fewer-HBM-bytes contract.

3. The XLA-native integer serving path vs the bf16 baseline per shape
   class (the seed's original table; unchanged contract).

``--quick`` (CI smoke) runs one small shape per kernel family only.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.hadamard import apply_hadamard
from repro.core.qlinear import QuantPolicy, qlinear, quantize_weight
from repro.kernels import ops, ref
from repro.launch.roofline import HW

SHAPES = [(64, 2048, 2048), (128, 4096, 1024)]

# (n, k, m): decode-shaped tall-skinny (max_slots rows) + a prefill tile.
# Interpret-mode emulation bounds the sizes; HBM/roofline accounting
# scales exactly, so the ratios transfer to the serving dims.
FUSED_SHAPES = [(4, 512, 256), (4, 2048, 512), (32, 1024, 512)]
QUICK_SHAPES = [(4, 512, 256)]

# (b, hq, hkv, d, page, width, length, int8kv): decode ticks over a paged
# pool — a small-slot cell, a quantized pool, and a deeper-context cell.
PAGED_SHAPES = [
    (4, 8, 2, 64, 16, 8, 100, False),
    (4, 8, 2, 64, 16, 8, 100, True),
    (8, 16, 4, 64, 32, 8, 200, False),
]
PAGED_QUICK_SHAPES = [(4, 8, 2, 64, 16, 8, 100, False)]

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "kernels", "BENCH_kernels.json")


def _has_xla_prestage(k: int) -> bool:
    """True when the rotation of dim k has leading Kronecker factors that
    run as XLA matmuls before the kernel (one extra activation round
    trip on BOTH paths — e.g. 2048 = H_512 ⊗ H_4); single-factor dims
    (512 = H_512) fuse the whole rotation."""
    from repro.core.hadamard import plan_hadamard

    return len(plan_hadamard(k).factors) > 1


def hbm_bytes(n: int, k: int, m: int, *, packed: bool, fused: bool,
              act_bytes: int = 2) -> int:
    """HBM traffic of one quantized linear, by construction of the path.

    staged:
      [XLA leading factors read x, write x'  (2·n·k·act) — multi-factor k]
      hadamard-quant kernel reads x', writes codes+Δa   (n·k·act + n·k + 4n)
      quant-matmul reads codes+Δa+W+Δw, writes y        (n·k + 4n + W + 4m + 2·n·m)
    fused:
      [the same XLA leading-factor round trip — multi-factor k]
      one kernel reads x'+W+Δw, writes y; codes and Δa never leave VMEM.
    """
    w = k * m // 2 if packed else k * m
    out = 2 * n * m
    pre = 2 * n * k * act_bytes if _has_xla_prestage(k) else 0
    if fused:
        return pre + n * k * act_bytes + w + 4 * m + out
    return (pre
            + n * k * act_bytes + n * k + 4 * n
            + n * k + 4 * n + w + 4 * m + out)


def activation_roundtrips(k: int, *, fused: bool) -> int:
    """Activation HBM round trips per linear (the 3 → 1 headline is for
    multi-factor rotation dims; fully-fusable dims go 2 → 1)."""
    pre = 1 if _has_xla_prestage(k) else 0
    return (1 + pre) if fused else (2 + pre)


def roofline_terms(n: int, k: int, m: int, bytes_moved: int,
                   hw: HW = HW()) -> dict:
    """Modelled step time on TPU v5e: int8 matmul FLOPs vs HBM stream."""
    compute_s = 2.0 * n * k * m / hw.peak_int8
    memory_s = bytes_moved / hw.hbm_bw
    bound = max(compute_s, memory_s)
    return {"compute_s": compute_s, "memory_s": memory_s,
            "modeled_tok_s": n / bound if bound else 0.0,
            "dominant": "memory" if memory_s >= compute_s else "compute"}


def bench_fused_vs_staged(shapes) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)
    for n, k, m in shapes:
        x = jax.random.normal(key, (n, k)).astype(jnp.bfloat16)
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, m)) * 0.02
        wf = apply_hadamard(w.astype(jnp.float32), axis=0)
        qw = quantize_weight(wf, bits=4, pack=True, had_dim=k)

        # jit both sides so wall time measures interpreter execution, not
        # per-call retracing (fused_qlinear is deliberately unjitted at
        # module level; model steps jit around it)
        staged = jax.jit(lambda a, q: ops.fused_quant_matmul(
            a, q, interpret=True))
        fused = jax.jit(lambda a, q: ops.fused_qlinear(a, q, interpret=True))
        t_staged = timeit(staged, x, qw, warmup=1, iters=3)
        t_fused = timeit(fused, x, qw, warmup=1, iters=3)

        b_staged = hbm_bytes(n, k, m, packed=True, fused=False)
        b_fused = hbm_bytes(n, k, m, packed=True, fused=True)
        r_staged = roofline_terms(n, k, m, b_staged)
        r_fused = roofline_terms(n, k, m, b_fused)
        row = {
            "shape": f"{n}x{k}x{m}", "packed": True, "had_dim": k,
            "staged_us_interpret": t_staged, "fused_us_interpret": t_fused,
            "hbm_bytes_staged": b_staged, "hbm_bytes_fused": b_fused,
            "activation_roundtrips_staged":
                activation_roundtrips(k, fused=False),
            "activation_roundtrips_fused":
                activation_roundtrips(k, fused=True),
            "memory_s_staged": r_staged["memory_s"],
            "memory_s_fused": r_fused["memory_s"],
            "modeled_tok_s_staged": r_staged["modeled_tok_s"],
            "modeled_tok_s_fused": r_fused["modeled_tok_s"],
            "fused_ge_staged": (r_fused["modeled_tok_s"]
                                >= r_staged["modeled_tok_s"]),
        }
        rows.append(row)
        emit(f"kernel_fused_qlinear_{row['shape']}", t_fused,
             f"hbm_bytes={b_fused};"
             f"roundtrips={row['activation_roundtrips_fused']};"
             f"modeled_tok_s={r_fused['modeled_tok_s']:.3e}")
        emit(f"kernel_staged_qlinear_{row['shape']}", t_staged,
             f"hbm_bytes={b_staged};"
             f"roundtrips={row['activation_roundtrips_staged']};"
             f"modeled_tok_s={r_staged['modeled_tok_s']:.3e};"
             f"fused_speedup_roofline={b_staged / b_fused:.2f}x")
    return rows


def paged_hbm_bytes(b: int, hkv: int, d: int, page: int, width: int, *,
                    int8kv: bool, fused: bool, hq: int) -> int:
    """HBM traffic of one layer's paged decode attention, by construction.

    ``width`` = page-table width.  BOTH paths traverse the full
    (b, width, page) logical extent — ``paged_view`` gathers every
    table entry (``-1`` clamps to page 0) and materializes the full
    contiguous view, and the kernel's grid walks every logical page
    (dead entries fetch clamped page 0; the pipeline skips the compute
    and dedupes consecutive repeat fetches, so counting them is
    conservative AGAINST the kernel).  Per cached position a pool
    stores k + v rows of ``hkv·d`` (1 B int8 / 2 B bf16) plus, when
    quantized, two ``hkv·4`` B scale rows.

    gather (``paged_view`` + attention):
      read pool pages → write the contiguous DEQUANTIZED bf16 view
      (b · width · page · hkv · d · 2 B × {k,v}) → attention re-reads it.
    fused (``ops.paged_attention``):
      read pool pages ONCE (the table-driven BlockSpec DMA); the
      contiguous view never exists.
    Both move the (b, hq, d) query in and the output out.
    """
    positions = b * width * page
    kv_b = 1 if int8kv else 2
    pool = positions * hkv * (2 * d * kv_b + (8 if int8kv else 0))
    qo = 2 * b * hq * d * 2
    if fused:
        return pool + qo
    view = positions * hkv * d * 2 * 2          # contiguous bf16, k and v
    return pool + view + view + qo


def paged_roofline(b: int, hq: int, d: int, length: int, bytes_moved: int,
                   hw: HW = HW()) -> dict:
    """Modeled decode-tick attention time on TPU v5e: f32/bf16 QK+PV
    FLOPs (4·b·hq·len·d) vs the HBM stream."""
    compute_s = 4.0 * b * hq * length * d / hw.peak_bf16
    memory_s = bytes_moved / hw.hbm_bw
    bound = max(compute_s, memory_s)
    return {"compute_s": compute_s, "memory_s": memory_s,
            "modeled_tok_s": b / bound if bound else 0.0,
            "dominant": "memory" if memory_s >= compute_s else "compute"}


def _paged_case(b, hq, hkv, d, page, width, length, int8kv, seed=0):
    """Pool + contiguously-allocated (but physically scattered) table."""
    rng = np.random.default_rng(seed)
    n_pages = b * width
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    kp = jax.random.normal(ks[0], (n_pages, page, hkv, d)).astype(jnp.bfloat16)
    vp = jax.random.normal(ks[1], (n_pages, page, hkv, d)).astype(jnp.bfloat16)
    q = jax.random.normal(ks[2], (b, 1, hq, d)).astype(jnp.bfloat16)
    layer_kv = {"k": kp, "v": vp}
    if int8kv:
        # the engine's actual KV quantizer — benchmarking any other
        # scheme would silently stop modeling what the pool stores
        from repro.models.common import _quant_kv

        kq, ksc = _quant_kv(kp)
        vq, vsc = _quant_kv(vp)
        layer_kv = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
    perm = rng.permutation(n_pages)
    pages = -(-length // page)
    table = np.full((b, width), -1, np.int32)
    nxt = 0
    for i in range(b):
        for j in range(pages):
            table[i, j] = perm[nxt]
            nxt += 1
    lens = jnp.full((b,), length, jnp.int32)
    return q, layer_kv, jnp.asarray(table), lens


def bench_paged_attention(shapes) -> list[dict]:
    rows = []
    for b, hq, hkv, d, page, width, length, int8kv in shapes:
        q, layer_kv, table, lens = _paged_case(
            b, hq, hkv, d, page, width, length, int8kv)

        from repro.models.common import attention_scores, paged_view

        def gather(q_, kv_, t_, ln_):
            kc, vc = paged_view(kv_, t_)
            return attention_scores(q_, kc, vc, causal=False, length=ln_)

        fused = jax.jit(lambda q_, kv_, t_, ln_: ops.paged_attention(
            q_, kv_, t_, ln_, interpret=True))
        t_gather = timeit(jax.jit(gather), q, layer_kv, table, lens,
                          warmup=1, iters=3)
        t_fused = timeit(fused, q, layer_kv, table, lens, warmup=1, iters=3)

        b_gather = paged_hbm_bytes(b, hkv, d, page, width, int8kv=int8kv,
                                   fused=False, hq=hq)
        b_fused = paged_hbm_bytes(b, hkv, d, page, width, int8kv=int8kv,
                                  fused=True, hq=hq)
        r_gather = paged_roofline(b, hq, d, length, b_gather)
        r_fused = paged_roofline(b, hq, d, length, b_fused)
        row = {
            "kind": "paged_attention",
            "shape": f"b{b}xh{hq}/{hkv}xd{d}xp{page}xl{length}"
                     f"{'_int8kv' if int8kv else ''}",
            "int8kv": int8kv,
            "gather_us_interpret": t_gather, "fused_us_interpret": t_fused,
            "hbm_bytes_gather": b_gather, "hbm_bytes_fused": b_fused,
            "memory_s_gather": r_gather["memory_s"],
            "memory_s_fused": r_fused["memory_s"],
            "modeled_tok_s_gather": r_gather["modeled_tok_s"],
            "modeled_tok_s_fused": r_fused["modeled_tok_s"],
            # the acceptance contract: the kernel moves STRICTLY fewer
            # modeled HBM bytes than the gather path
            "fused_lt_gather_bytes": b_fused < b_gather,
        }
        rows.append(row)
        emit(f"kernel_paged_attn_fused_{row['shape']}", t_fused,
             f"hbm_bytes={b_fused};modeled_tok_s="
             f"{r_fused['modeled_tok_s']:.3e}")
        emit(f"kernel_paged_attn_gather_{row['shape']}", t_gather,
             f"hbm_bytes={b_gather};modeled_tok_s="
             f"{r_gather['modeled_tok_s']:.3e};"
             f"fused_bytes_saving={b_gather / b_fused:.2f}x")
    return rows


def write_artifact(rows: list[dict], quick: bool = False,
                   out_path: str | None = None) -> str:
    # --quick (CI smoke) writes a sibling file so it never truncates the
    # committed full-shape perf trajectory that report.py renders;
    # --out redirects entirely (CI emits fresh JSONs OUTSIDE the
    # checkout so report.py --check compares against the committed
    # baseline, not the file it just overwrote)
    path = out_path or (OUT_PATH.replace(".json", "_quick.json") if quick
                        else OUT_PATH)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def run(quick: bool = False, out_path: str | None = None) -> dict:
    out = {}
    rows = bench_fused_vs_staged(QUICK_SHAPES if quick else FUSED_SHAPES)
    paged_rows = bench_paged_attention(PAGED_QUICK_SHAPES if quick
                                       else PAGED_SHAPES)
    path = write_artifact(rows + paged_rows, quick, out_path)
    out["fused_vs_staged"] = rows
    out["paged_attention"] = paged_rows
    assert all(r["fused_ge_staged"] for r in rows), \
        "fused path must dominate the staged roofline"
    assert all(r["fused_lt_gather_bytes"] for r in paged_rows), \
        "paged kernel must move strictly fewer HBM bytes than the gather"
    emit("kernel_bench_artifact", 0.0, f"wrote={os.path.relpath(path)}")
    if quick:
        return out

    key = jax.random.PRNGKey(0)
    for n, k, m in SHAPES:
        x = jax.random.normal(key, (n, k)).astype(jnp.bfloat16)
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, m)) * 0.02
        wb = w.astype(jnp.bfloat16)
        qw4 = quantize_weight(w, bits=4, pack=True)
        qw8 = quantize_weight(w, bits=8, pack=False)

        t_bf16 = timeit(jax.jit(lambda a, b: a @ b), x, wb)
        pol4 = QuantPolicy(weight_bits=4, act_bits=4, use_kernels="never")
        pol8 = QuantPolicy(weight_bits=8, act_bits=8, use_kernels="never")
        t_w4 = timeit(jax.jit(lambda a, q=qw4: qlinear(a, q, pol4)), x)
        t_w8 = timeit(jax.jit(lambda a, q=qw8: qlinear(a, q, pol8)), x)
        t_had = timeit(jax.jit(lambda a: apply_hadamard(a, k)), x)
        t_qnt = timeit(jax.jit(lambda a: ref.quantize_per_token_ref(a, 4)), x)

        tag = f"{n}x{k}x{m}"
        hbm_bf16 = (n * k + k * m) * 2
        hbm_w4 = n * k * 2 + k * m // 2
        emit(f"kernel_matmul_bf16_{tag}", t_bf16, f"hbm_bytes={hbm_bf16}")
        emit(f"kernel_qlinear_w4a4_{tag}", t_w4,
             f"hbm_bytes={hbm_w4};weight_traffic_saving="
             f"{(k*m*2)/(k*m//2):.1f}x")
        emit(f"kernel_qlinear_w8a8_{tag}", t_w8, f"hbm_bytes={n*k*2+k*m}")
        emit(f"kernel_hadamard_fast_{tag}", t_had,
             f"flops_vs_dense={2*k*sum(s for s in [k])}")
        emit(f"kernel_quantize_token_{tag}", t_qnt, "pass=reduce+round")
        out[tag] = dict(bf16=t_bf16, w4=t_w4, w8=t_w8, had=t_had)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: one small fused-vs-staged shape")
    ap.add_argument("--out", default="",
                    help="artifact path override (CI regression gate)")
    args = ap.parse_args()
    run(quick=args.quick, out_path=args.out or None)
