"""Load generator for the async HTTP front-end + chunked-prefill probe.

Three measurement families, all on the paged engine (reduced
stablelm_3b, CPU interpret mode):

  * **HTTP load scenarios** — seeded Poisson and all-at-once burst
    arrivals driven through a loopback :class:`ServingFrontend` with the
    stdlib async client.  Every offered request is classified
    completed / shed (503) / deadline-expired, and client-side TTFT and
    inter-token-gap percentiles plus goodput are recorded.  Wall-clock
    percentiles are report-only; the ``--check`` gate compares only the
    deterministic accounting contracts (every request accounted for,
    some requests served).
  * **Chunked-prefill probe** (engine-direct, traced) — victims decode
    while a long prompt arrives.  Unchunked, the whole-prompt prefill
    dispatch stalls the victims' token streams for its full duration;
    with ``prefill_chunk`` set, bounded continuation dispatches
    interleave with decode ticks.  The probe derives each victim's
    inter-token gaps from the trace-event chains (the same derivation
    ``repro.obs.summarize`` uses) and asserts the ISSUE's contract:
    chunking bounds the p99 victim gap below the unchunked run's, with
    token-identical outputs.  Both booleans gate via ``--check``.
  * **Trace replay** — the burst scenario runs with a JSONL trace
    attached; ``summarize(load_trace(path))`` must equal the in-memory
    summary bit-for-bit (the front-end's shed/deadline events ride the
    same schema), gated as ``trace_replay_identical``.
  * **Retry goodput** (docs/resilience.md) — the same saturating burst
    against a tight admission bound, once with fire-and-forget clients
    and once with clients running capped jittered exponential backoff
    that honors the 503 ``Retry-After`` header.  Gated:
    ``retry_goodput`` (the retrying cohort completes at least as many
    requests, and recovers at least one shed).
  * **Fault recovery** (docs/resilience.md) — an injected decode
    dispatch failure kills the engine thread mid-burst; the front-end
    watchdog rebuilds the engine from its factory and resumes in-flight
    requests.  Gated: ``recovered`` (restart happened AND every request
    completed full-length), ``accounted`` (exact accounting across the
    restart) and ``all_pages_freed`` (the rebuilt engine's pool fully
    restored).

Writes ``experiments/serving/BENCH_load.json`` (``--quick`` → the
``_quick`` sibling) for benchmarks/report.py's §Load table and the
``report.py --check`` regression gate.  The first HTTP scenario pays
the process's jit compiles in its wall-clock numbers (visible as
second-scale TTFTs) — those stay report-only; every gated contract is
either deterministic or measured on warm caches (the probe warms up
explicitly).
"""

from __future__ import annotations

import argparse
import asyncio
import json
import os
import tempfile

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.api import get_model
from repro.obs import Observability, load_trace, percentile_summary, summarize
from repro.resilience.faults import FaultPlan, FaultSpec
from repro.serving.engine import EngineConfig, PagedServingEngine, Request
from repro.serving.frontend import ServingFrontend, http_generate, http_get

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "serving", "BENCH_load.json")

ARCH = "stablelm_3b"
HOST = "127.0.0.1"

# front-end engine scale (reduced config; serving_throughput idiom)
MAX_SLOTS = 4
MAX_LEN = 64
PAGE_SIZE = 4
PREFILL_BUCKET = 8
PREFILL_CHUNK = 8

# chunked-prefill probe scale: the long prompt must dwarf the chunk so
# the one-shot dispatch visibly stalls the victims — prefill attention
# is quadratic in prompt length, so 224 tokens one-shot costs far more
# than the sum of its 8-token chunks and the gap contrast is robust to
# CPU wall-clock noise
PROBE_MAX_LEN = 256
PROBE_PAGE_SIZE = 8
PROBE_LONG_PROMPT = 224
PROBE_VICTIM_NEW = 24


def _setup():
    cfg = get_config(ARCH).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return model, params, cfg


def _engine(model, params, cfg, *, obs=None, chunk=PREFILL_CHUNK,
            max_len=MAX_LEN, page_size=PAGE_SIZE, faults=None):
    return PagedServingEngine(
        model, params, cfg,
        config=EngineConfig(max_slots=MAX_SLOTS, max_len=max_len,
                            page_size=page_size,
                            prefill_bucket=PREFILL_BUCKET,
                            prefill_chunk=chunk, obs=obs, faults=faults))


# ---------------------------------------------------------------------------
# HTTP load scenarios
# ---------------------------------------------------------------------------


def _prompts(cfg, n: int, seed: int) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(int(rng.integers(4, 13)),))
            for _ in range(n)]


async def _generate_with_retry(port: int, payload: dict, *, seed: int,
                               max_retries: int = 8,
                               max_backoff_s: float = 0.5) -> dict:
    """Resilient client: on a 503 shed, honor the ``Retry-After`` header
    (docs/resilience.md) with capped jittered exponential backoff on top,
    then resubmit.  Returns the final response, annotated with the retry
    count so the scenario row can report recovered sheds."""
    rng = np.random.default_rng(seed)
    r = await http_generate(HOST, port, payload)
    retries = 0
    while r["status"] == 503 and retries < max_retries:
        hinted = float(r.get("headers", {}).get("retry-after", 0.0) or 0.0)
        backoff = min(max_backoff_s, 0.02 * (2 ** retries))
        await asyncio.sleep(hinted + float(rng.uniform(0, backoff)))
        retries += 1
        r = await http_generate(HOST, port, payload)
    r["retries"] = retries
    return r


async def _drive(frontend: ServingFrontend, prompts, *, rate: float | None,
                 max_new: int, seed: int, retry: bool = False):
    """Fire one /generate per prompt (Poisson gaps at ``rate`` req/s, or
    all at once) and gather classified results."""
    loop = asyncio.get_running_loop()
    rng = np.random.default_rng(seed + 1)

    async def one(prompt, i):
        t0 = loop.time()
        payload = {"prompt": prompt.tolist(), "max_new_tokens": max_new}
        if retry:
            r = await _generate_with_retry(frontend.port, payload,
                                           seed=seed + 100 + i)
        else:
            r = await http_generate(HOST, frontend.port, payload)
        r["t_submit"] = t0
        return r

    t_start = loop.time()
    tasks = []
    for i, p in enumerate(prompts):
        tasks.append(asyncio.create_task(one(p, i)))
        if rate:
            await asyncio.sleep(float(rng.exponential(1.0 / rate)))
    results = await asyncio.gather(*tasks)
    wall = loop.time() - t_start
    stats = (await http_get(HOST, frontend.port, "/stats"))["body"]
    return list(results), wall, stats


def _scenario_row(name: str, results, wall: float, stats: dict,
                  rate: float | None) -> dict:
    offered = len(results)
    ok = [r for r in results
          if r["status"] == 200 and r["body"] is not None
          and not r["body"].get("failed")]
    completed = [r for r in ok if not r["body"].get("expired")]
    shed = [r for r in results if r["status"] == 503]
    expired = [r for r in ok if r["body"].get("expired")]
    failed = [r for r in results
              if r["status"] == 200 and r["body"] is not None
              and r["body"].get("failed")]
    ttft = [r["token_times"][0] - r["t_submit"]
            for r in completed if r["token_times"]]
    gaps = [b - a for r in completed
            for a, b in zip(r["token_times"], r["token_times"][1:])]
    tokens = sum(len(r["tokens"]) for r in completed)
    row = {
        "kind": "http",
        "scenario": name,
        "offered": offered,
        "rate_req_s": rate or 0.0,
        "completed": len(completed),
        "shed": len(shed),
        "expired": len(expired),
        "failed": len(failed),
        # --check contracts: every offered request classified, and the
        # scenario actually served traffic
        "accounted": int(len(completed) + len(shed) + len(expired)
                         + len(failed) == offered),
        "served_any": int(len(completed) > 0),
        "wall_s": round(wall, 4),
        # report-only (wall-clock; does not transfer across machines)
        "goodput_tok_s": round(tokens / max(wall, 1e-9), 2),
        "ttft_s": percentile_summary(ttft),
        "client_gap_s": percentile_summary(gaps),
        "frontend": stats.get("frontend", {}),
    }
    if any("retries" in r for r in results):
        row["retried"] = sum(1 for r in results if r.get("retries", 0) > 0)
    return row


async def _http_scenario(model, params, cfg, *, name, n, rate, max_new, seed,
                         max_queue_depth, shed_score, trace_path=None,
                         retry=False):
    obs = Observability(trace_path=trace_path) if trace_path else None
    eng = _engine(model, params, cfg, obs=obs)
    prompts = _prompts(cfg, n, seed)
    async with ServingFrontend(eng, host=HOST, port=0,
                               max_queue_depth=max_queue_depth,
                               shed_score=shed_score) as fe:
        results, wall, stats = await _drive(fe, prompts, rate=rate,
                                            max_new=max_new, seed=seed,
                                            retry=retry)
    row = _scenario_row(name, results, wall, stats, rate)
    if obs is not None:
        mem = obs.summary()
        obs.close()
        row["trace_replay_identical"] = int(
            summarize(load_trace(trace_path)) == mem)
    return row


async def _fault_recovery_scenario(model, params, cfg, *, max_new: int) -> dict:
    """Kill the engine thread mid-burst with an injected decode dispatch
    failure; the watchdog rebuilds from ``engine_factory`` and resumes
    the in-flight requests (docs/resilience.md)."""
    n = 4
    plan = FaultPlan([FaultSpec("dispatch_raise", op="decode", at=3)])
    eng = _engine(model, params, cfg, faults=plan)
    prompts = _prompts(cfg, n, seed=17)
    async with ServingFrontend(
            eng, host=HOST, port=0, max_queue_depth=64, shed_score=32.0,
            engine_factory=lambda: _engine(model, params, cfg),
            watchdog_interval_s=0.05, watchdog_stall_s=5.0) as fe:
        results, wall, stats = await _drive(fe, prompts, rate=None,
                                            max_new=max_new, seed=17)
        # retires are processed on the engine thread; poll until every
        # page is back in the rebuilt engine's pool
        for _ in range(200):
            if fe.engine.pages_in_use == 0:
                break
            await asyncio.sleep(0.02)
        pages_free = int(fe.engine.pages_in_use == 0)
        restarts = fe.restarts
    row = _scenario_row("fault_recovery", results, wall, stats, None)
    full = [r for r in results
            if r["status"] == 200 and r["body"] is not None
            and not r["body"].get("failed")
            and len(r["tokens"]) == max_new]
    row.update({
        "restarts": restarts,
        "faults_fired": len(plan.fired),
        # --check contracts: the watchdog actually restarted the engine
        # AND every request still completed full-length; the rebuilt
        # engine's page pool is fully restored
        "recovered": int(restarts >= 1 and len(full) == n),
        "all_pages_freed": pages_free,
    })
    return row


# ---------------------------------------------------------------------------
# chunked-prefill probe (engine-direct)
# ---------------------------------------------------------------------------


def _probe_requests(cfg) -> tuple[list[Request], list[Request]]:
    rng = np.random.default_rng(7)
    victims = [Request(uid=i,
                       prompt=rng.integers(0, cfg.vocab_size, size=(5 + i,)),
                       max_new_tokens=PROBE_VICTIM_NEW) for i in range(2)]
    # TWO long prompts arriving together: the one-shot admission round
    # pays a single (2, long) prefill dispatch — twice the stall — while
    # the chunked run's (2, chunk) continuations stay bounded, keeping
    # the gap contrast well clear of wall-clock noise
    longs = [Request(uid=8 + i,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=(PROBE_LONG_PROMPT,)),
                     max_new_tokens=4) for i in range(2)]
    return victims, longs


def _probe_once(model, params, cfg, chunk: int | None):
    """One victims-decoding + long-prompt-arrival pass; returns (per-uid
    token-gap lists from the trace chains, per-uid output tokens,
    engine stats)."""
    obs = Observability()
    eng = _engine(model, params, cfg, obs=obs, chunk=chunk,
                  max_len=PROBE_MAX_LEN, page_size=PROBE_PAGE_SIZE)
    victims, longs = _probe_requests(cfg)
    for r in victims:
        eng.submit(r)
    for _ in range(3):          # victims decoding before the long arrivals
        eng.step()
    for r in longs:
        eng.submit(r)
    done = eng.run(max_ticks=500)
    # per-uid emission-timestamp chains, exactly as summarize() builds
    # them (first_token seeds, tick stamps its uids, token stamps resume
    # prefill tokens)
    chains: dict[int, list[float]] = {}
    for ev in obs.tracer.events:
        if ev["ev"] == "first_token" or ev["ev"] == "token":
            chains.setdefault(ev["uid"], []).append(ev["ts"])
        elif ev["ev"] == "tick":
            for uid in ev["uids"]:
                chains.setdefault(uid, []).append(ev["ts"])
    victim_uids = {r.uid for r in victims}
    gaps = [b - a for uid, ts in chains.items() if uid in victim_uids
            for a, b in zip(ts, ts[1:])]
    outputs = {r.uid: list(map(int, r.out_tokens)) for r in done}
    return gaps, outputs, eng.run_stats


def _probe(model, params, cfg, repeats: int) -> dict:
    rows = {}
    for label, chunk in (("unchunked", None), ("chunked", PREFILL_CHUNK)):
        _probe_once(model, params, cfg, chunk)          # jit warmup
        best = None
        for _ in range(repeats):
            gaps, outputs, st = _probe_once(model, params, cfg, chunk)
            p = percentile_summary(gaps)
            if best is None or p["p99"] < best["gaps"]["p99"]:
                best = {"gaps": p, "outputs": outputs,
                        "prefill_dispatches": st["prefill_dispatches"]}
        rows[label] = best
    identical = int(rows["chunked"]["outputs"] == rows["unchunked"]["outputs"])
    bounds = int(rows["chunked"]["gaps"]["p99"]
                 < rows["unchunked"]["gaps"]["p99"])
    return {
        "kind": "probe",
        "prefill_chunk": PREFILL_CHUNK,
        "long_prompt": PROBE_LONG_PROMPT,
        "victim_gap_unchunked_s": rows["unchunked"]["gaps"],
        "victim_gap_chunked_s": rows["chunked"]["gaps"],
        "prefill_dispatches": {m: rows[m]["prefill_dispatches"]
                               for m in rows},
        # --check contracts (the ISSUE's acceptance booleans)
        "chunked_prefill_bounds_p99": bounds,
        "chunked_tokens_identical": identical,
    }


# ---------------------------------------------------------------------------


async def _run(quick: bool) -> list[dict]:
    model, params, cfg = _setup()
    rows = []

    max_new = 6 if quick else 8
    scenarios = [("poisson_low", 6 if quick else 16, 4.0)]
    if not quick:
        scenarios.append(("poisson_high", 24, 40.0))
    for name, n, rate in scenarios:
        row = await _http_scenario(model, params, cfg, name=name, n=n,
                                   rate=rate, max_new=max_new, seed=11,
                                   max_queue_depth=64, shed_score=32.0)
        rows.append(row)
        print(f"{name}: {row['completed']}/{row['offered']} completed, "
              f"{row['shed']} shed, goodput {row['goodput_tok_s']} tok/s")

    # burst to saturation against a tight admission bound → sheds; also
    # carries the trace for the replay-identity gate
    with tempfile.TemporaryDirectory() as td:
        trace = os.path.join(td, "load_trace.jsonl")
        row = await _http_scenario(
            model, params, cfg, name="burst", n=10 if quick else 20,
            rate=None, max_new=max_new, seed=13,
            max_queue_depth=4, shed_score=32.0, trace_path=trace)
    rows.append(row)
    print(f"burst: {row['completed']}/{row['offered']} completed, "
          f"{row['shed']} shed, replay_identical="
          f"{row['trace_replay_identical']}")

    # retry goodput: same saturating burst against a tight admission
    # bound, fire-and-forget vs Retry-After-honoring backoff clients
    pair = {}
    for name, retry in (("burst_noretry", False), ("burst_retry", True)):
        pair[name] = await _http_scenario(
            model, params, cfg, name=name, n=10, rate=None, max_new=max_new,
            seed=19, max_queue_depth=2, shed_score=32.0, retry=retry)
        rows.append(pair[name])
    pair["burst_retry"]["retry_goodput"] = int(
        pair["burst_retry"]["completed"] >= pair["burst_noretry"]["completed"]
        and pair["burst_retry"]["completed"] > 0
        and pair["burst_noretry"]["shed"] > 0)
    print(f"retry: {pair['burst_retry']['completed']}/10 completed "
          f"(noretry {pair['burst_noretry']['completed']}/10, "
          f"{pair['burst_noretry']['shed']} shed), "
          f"retry_goodput={pair['burst_retry']['retry_goodput']}")

    row = await _fault_recovery_scenario(model, params, cfg, max_new=max_new)
    rows.append(row)
    print(f"fault_recovery: {row['completed']}/{row['offered']} completed, "
          f"restarts={row['restarts']}, recovered={row['recovered']}, "
          f"all_pages_freed={row['all_pages_freed']}")

    probe = _probe(model, params, cfg, repeats=3)
    rows.append(probe)
    print(f"probe: chunked p99 gap {probe['victim_gap_chunked_s']['p99']:.4f}s"
          f" vs unchunked {probe['victim_gap_unchunked_s']['p99']:.4f}s, "
          f"bounds_p99={probe['chunked_prefill_bounds_p99']}, "
          f"tokens_identical={probe['chunked_tokens_identical']}")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small scenario sizes (CI; BENCH_load_quick.json)")
    ap.add_argument("--out", default=None, help="artifact path override")
    args = ap.parse_args()

    rows = asyncio.run(_run(args.quick))

    out = args.out or (ARTIFACT.replace(".json", "_quick.json")
                       if args.quick else ARTIFACT)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
