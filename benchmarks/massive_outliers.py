"""Paper Fig. 5 + §IV-D/E math — the massive-outlier token under rotation
vs smooth-rotation.

Validates, on the down_proj-30 analogue:
  * Eq. (7): rotated values cluster at 2^{|O|−1} |centroids|;
  * Eq. (8): max|t̂| = Σ|o_i|/√d + O(ε);
  * Eq. (9): max|t̃| ≈ Σ√(|o_i|·max|W_i|/d) after smooth(0.5)+rotate;
  * effective-bin usage: the fraction of the 4-bit grid actually occupied
    by the non-outlier mass (Fig. 5's 'effective quantization bins') —
    smooth-rotation uses far more of the grid.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_suite, timeit
from repro.core.hadamard import apply_hadamard
from repro.core.quantizer import QuantConfig, quantize
from repro.core.transforms import TRANSFORMS, smoothing_scales


def _massive_case():
    for c in make_suite():
        if c.has_massive and c.layer == 30:
            return c
    raise RuntimeError("no massive case")


def run() -> dict:
    case = _massive_case()
    x, w = case.x, case.w
    d = x.shape[1]
    # the token with the largest |value| (Fig. 5 selects that token)
    tok_idx = int(np.argmax(np.abs(np.asarray(x)).max(axis=1)))
    t = x[tok_idx]
    outlier_dims = np.where(np.abs(np.asarray(t)) > 500)[0]
    o_vals = np.asarray(t)[outlier_dims]
    t_us = timeit(lambda: apply_hadamard(t[None], d))

    # Eq. (7): centroid count — count well-separated |value| clusters
    t_rot = np.asarray(apply_hadamard(t[None], d))[0]
    hist, edges = np.histogram(np.abs(t_rot), bins=400)
    # cluster centers = contiguous occupied bins separated by gaps
    occupied = hist > 0
    clusters = int(np.sum(np.diff(np.concatenate(([0], occupied.view(np.int8)
                                                   ))) == 1))
    expected_clusters = 2 ** (len(outlier_dims) - 1)
    emit("fig5_eq7_centroids", t_us,
         f"measured={clusters};expected={expected_clusters}")

    # Eq. (8): rotated max
    eq8 = np.abs(o_vals).sum() / np.sqrt(d)
    emit("fig5_eq8_rotated_max", 0.0,
         f"measured={np.abs(t_rot).max():.2f};predicted={eq8:.2f}")

    # Eq. (9): smooth-rotate max
    s = np.asarray(smoothing_scales(x, w, 0.5))
    t_sr = np.asarray(apply_hadamard((np.asarray(t) / s)[None], d))[0]
    wmax = np.abs(np.asarray(w)).max(axis=1)
    eq9 = sum(np.sqrt(np.abs(v) * wmax[j] / d)
              for j, v in zip(outlier_dims, o_vals))
    emit("fig5_eq9_smoothrot_max", 0.0,
         f"measured={np.abs(t_sr).max():.2f};predicted={eq9:.2f}")

    # effective 4-bit bins occupied by the non-outlier mass
    def bins_used(vec):
        q, _ = quantize(vec[None], QuantConfig(bits=4,
                                               granularity="per_token"))
        return int(len(np.unique(np.asarray(q))))

    used_rot = bins_used(np.asarray(t_rot, np.float32))
    used_sr = bins_used(np.asarray(t_sr, np.float32))
    emit("fig5_bins_used_rotate", 0.0, f"bins={used_rot}/15")
    emit("fig5_bins_used_smooth_rotate", 0.0, f"bins={used_sr}/15")
    return {"clusters": clusters, "expected": expected_clusters,
            "eq8": (float(np.abs(t_rot).max()), float(eq8)),
            "eq9": (float(np.abs(t_sr).max()), float(eq9)),
            "bins": (used_rot, used_sr)}


if __name__ == "__main__":
    run()
