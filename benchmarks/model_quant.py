"""End-to-end model-level quantization quality (beyond the paper's
layer-wise scope, §V future work): full-model logit fidelity under the
four transform plans at W4A4/W8A8 on reduced archs, demonstrating the
paper's ranking carries to whole networks including MoE and SSM."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.core.transforms import TransformPlan
from repro.models.api import get_model
from repro.serving.fold import collect_calibration, fold_quantize
from repro.launch import compat

PLANS = {
    "none": TransformPlan(attn_in="none", attn_out="none", mlp_in="none",
                          mlp_out="none"),
    "rotate": TransformPlan(attn_in="rotate", attn_out="rotate",
                            mlp_in="rotate", mlp_out="rotate"),
    "paper_smooth_rotate": TransformPlan(),  # §V default
}

ARCHS = ("stablelm_3b", "mamba2_780m", "deepseek_v2_lite_16b")


def run(auto_plan: bool = False) -> dict:
    key = jax.random.PRNGKey(0)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    out = {}
    with compat.set_mesh(mesh):
        for arch in ARCHS:
            cfg = get_config(arch).reduced()
            model = get_model(cfg)
            params = model.init(key, cfg)
            toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
            stats = collect_calibration(model, params, cfg,
                                        [{"tokens": toks}],
                                        keep_samples=128 if auto_plan else 0)
            of = model.forward(params, cfg, toks)
            lf = np.asarray(of[0] if isinstance(of, tuple) else of,
                            np.float32)
            plans = dict(PLANS)
            if auto_plan:
                from repro.autoplan import search_plan

                plans["auto"] = search_plan(params, cfg, stats)[0]
            t_us = 0.0
            for pname, plan in plans.items():
                policy = QuantPolicy(weight_bits=4, act_bits=4,
                                     use_kernels="never")
                q = fold_quantize(params, cfg, policy=policy, plan=plan,
                                  stats=stats)
                fwd = jax.jit(lambda p, t: model.forward(p, cfg, t,
                                                         policy=policy))
                if t_us == 0.0:
                    t_us = timeit(fwd, q, toks)
                oq = fwd(q, toks)
                lq = np.asarray(oq[0] if isinstance(oq, tuple) else oq,
                                np.float32)
                rel = float(np.linalg.norm(lq - lf) / np.linalg.norm(lf))
                agree = float((lq.argmax(-1) == lf.argmax(-1)).mean())
                out[(arch, pname)] = rel
                emit(f"model_w4a4_{arch}_{pname}", t_us,
                     f"logit_rel_err={rel:.3f};top1_agree={agree:.2f}")
    for arch in ARCHS:
        better = out[(arch, "paper_smooth_rotate")] < out[(arch, "none")]
        emit(f"model_transforms_beat_none_{arch}", 0.0, f"holds={better}")
    return {f"{a}_{p}": v for (a, p), v in out.items()}


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--auto-plan", action="store_true",
                    help="additionally score a searched per-layer plan "
                         "(repro.autoplan) against the fixed plans")
    args = ap.parse_args(argv)
    run(auto_plan=args.auto_plan)


if __name__ == "__main__":
    main()
