"""Prefix-cache benchmark: shared-system-prompt serving, cache on vs off.

The workload the cache exists for: every request opens with the SAME
system prompt (several full pages) and ends with a unique per-user tail.
A seeder request (the bare system prompt) populates the cache, then a
burst of requests is served twice on the paged engine — ``prefix_cache``
off (the pre-cache allocator) and on — and the runs are compared:

  * **hit rate** — every burst request must match the seeded prefix
    (``all_hits``);
  * **prefill-token reduction** — the cache-on run dispatches prefill
    only for the non-shared suffix, so its engine-counted prefill
    tokens drop by exactly ``n_requests * system_tokens``
    (``suffix_only_prefill`` — the ISSUE's acceptance pin);
  * **token identity** — greedy outputs are bit-identical across the
    two runs (``tokens_identical``);
  * wall-clock tok/s for both runs (report-only: does not transfer
    across machines) plus the engine's roofline-modeled savings
    (``saved_prefill_flops`` / ``saved_hbm_bytes``, analytic).

Writes ``experiments/serving/BENCH_prefix.json`` (``--quick`` → the
``_quick`` sibling) for benchmarks/report.py's §Prefix table and the
``report.py --check`` regression gate, which compares only the
deterministic counters and contract booleans above.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.models.api import get_model
from repro.serving.engine import EngineConfig, PagedServingEngine, Request

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "serving", "BENCH_prefix.json")

MAX_SLOTS = 4
MAX_LEN = 64
PAGE_SIZE = 4          # reduced-config scale (serving_throughput idiom)
PREFILL_BUCKET = 8
SYS_PAGES = 6          # shared system prompt: 6 full pages = 24 tokens

REPEATS = 3   # timed sections take the best of N runs (CPU wall clock
#               is too noisy single-shot); counters are deterministic


def _system(cfg) -> np.ndarray:
    rng = np.random.default_rng(42)
    return rng.integers(0, cfg.vocab_size, size=(SYS_PAGES * PAGE_SIZE,))


def _requests(cfg, n: int, max_new: int) -> list[Request]:
    system = _system(cfg)
    rng = np.random.default_rng(1)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [system,
                         rng.integers(0, cfg.vocab_size, size=(3 + i % 5,))]),
                    max_new_tokens=max_new) for i in range(n)]


def _serve_once(model, params, cfg, *, prefix_cache, n_requests, max_new):
    eng = PagedServingEngine(
        model, params, cfg,
        config=EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                            page_size=PAGE_SIZE,
                            prefill_bucket=PREFILL_BUCKET,
                            prefix_cache=prefix_cache))
    # seeder: the bare system prompt, run to completion BEFORE the burst
    # so its pages are registered when the burst admits (same-round
    # co-admissions never share — docs/serving.md §Prefix caching)
    eng.submit(Request(uid=1000, prompt=_system(cfg), max_new_tokens=1))
    eng.run(max_ticks=10_000)
    for r in _requests(cfg, n_requests, max_new):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run(max_ticks=10_000)
    return eng, done, time.perf_counter() - t0


def _serve(model, params, cfg, *, prefix_cache, n_requests, max_new,
           repeats=REPEATS):
    dt = float("inf")
    for _ in range(repeats):
        eng, done, t = _serve_once(model, params, cfg,
                                   prefix_cache=prefix_cache,
                                   n_requests=n_requests, max_new=max_new)
        dt = min(dt, t)
    st = eng.run_stats
    burst = [r for r in done if r.uid < 1000]
    row = {
        "tokens": st["decode_tokens"],
        "prefill_tokens": st["prefill_tokens"],
        "prefill_dispatches": st["prefill_dispatches"],
        "decode_dispatches": st["decode_dispatches"],
        "ticks": st["ticks"],
        "seconds": round(dt, 4),
        "tok_s": round(st["decode_tokens"] / max(dt, 1e-9), 2),
        "outputs": {r.uid: list(map(int, r.out_tokens)) for r in burst},
    }
    px = st["prefix"]
    if px["enabled"]:
        row["prefix"] = {k: px[k] for k in
                         ("hits", "misses", "hit_rate", "shared_pages",
                          "cow_copies", "evictions", "cached_pages",
                          "saved_prefill_tokens", "saved_prefill_flops",
                          "saved_hbm_bytes")}
    return row


def bench_arch(arch: str, *, n_requests: int = 8, max_new: int = 8) -> dict:
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    sys_len = SYS_PAGES * PAGE_SIZE
    row = {"arch": arch, "max_slots": MAX_SLOTS, "n_requests": n_requests,
           "max_new": max_new, "system_tokens": sys_len,
           "system_pages": SYS_PAGES}
    for mode, on in (("off", False), ("on", True)):
        # warmup: identical workload so the timed pass hits warm jit
        # caches only (the suffix-prefill shape differs from the full
        # prefill shape, so each mode warms its own compiles)
        _serve(model, params, cfg, prefix_cache=on, n_requests=n_requests,
               max_new=max_new, repeats=1)
        row[mode] = _serve(model, params, cfg, prefix_cache=on,
                           n_requests=n_requests, max_new=max_new)
    outs = {m: row[m].pop("outputs") for m in ("off", "on")}
    px = row["on"]["prefix"]
    # --check contracts: deterministic, machine-portable
    row["tokens_identical"] = int(outs["on"] == outs["off"])
    row["all_hits"] = int(px["hits"] == n_requests)
    row["suffix_only_prefill"] = int(
        row["off"]["prefill_tokens"] - row["on"]["prefill_tokens"]
        == n_requests * sys_len
        and px["saved_prefill_tokens"] == n_requests * sys_len)
    row["prefill_tokens_reduced"] = int(
        row["on"]["prefill_tokens"] < row["off"]["prefill_tokens"])
    row["shared_pages_accounted"] = int(
        px["shared_pages"] == n_requests * SYS_PAGES)
    return row


def run(archs=("stablelm_3b",), *, n_requests: int = 8, max_new: int = 8,
        out_path: str = ARTIFACT) -> list[dict]:
    rows = []
    for arch in archs:
        row = bench_arch(arch, n_requests=n_requests, max_new=max_new)
        rows.append(row)
        px = row["on"]["prefix"]
        for mode in ("off", "on"):
            r = row[mode]
            emit(f"prefix_{arch}_{mode}",
                 1e6 * r["seconds"] / max(r["tokens"], 1),
                 f"tok_s={r['tok_s']};prefill_tokens={r['prefill_tokens']};"
                 f"prefill_dispatches={r['prefill_dispatches']}")
        emit(f"prefix_{arch}_contracts", 0.0,
             f"hit_rate={px['hit_rate']};"
             f"saved_prefill_tokens={px['saved_prefill_tokens']};"
             f"tokens_identical={row['tokens_identical']};"
             f"suffix_only_prefill={row['suffix_only_prefill']}")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; default stablelm_3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests/tokens, writes the "
                         "_quick sibling artifact (never truncates the "
                         "committed baseline)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    suffix = "_quick.json" if args.quick else ".json"
    out = args.out or ARTIFACT.replace(".json", suffix)
    kw = (dict(n_requests=4, max_new=4) if args.quick
          else dict(n_requests=args.requests, max_new=args.max_new))
    run(tuple(args.arch or ("stablelm_3b",)), out_path=out, **kw)


if __name__ == "__main__":
    main()
