"""Generate the EXPERIMENTS.md §Dry-run, §Roofline, §Autoplan, §Serving
and §Kernels tables from the JSON artifacts
(experiments/dryrun/<mesh>/<arch>__<shape>.json,
experiments/autoplan/<arch>_telemetry.json,
experiments/serving/throughput.json,
experiments/kernels/BENCH_kernels.json).

Usage: PYTHONPATH=src python -m benchmarks.report [--out EXPERIMENTS_tables.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
AUTOPLAN_ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                             "autoplan")
SERVING_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "serving", "throughput.json")
KERNELS_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "kernels", "BENCH_kernels.json")


def load(mesh: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ROOT, mesh, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compile s | args GB/dev | temp GB/dev | "
           "peak GB/dev | HLO GFLOPs/dev | wire GB/dev | #coll |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.1f} | "
            f"{m['argument_gb']:.2f} | {m['temp_gb']:.2f} | "
            f"{m['peak_gb_estimate']:.2f} | "
            f"{r['hlo']['flops_per_device'] / 1e9:.1f} | "
            f"{r['hlo']['wire_gb_per_device']:.2f} | "
            f"{r['hlo']['n_collectives']} |")
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | mem(kern) s | "
           "coll s | dcn s | dominant | useful | frac | frac(kern) |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        x = r["roofline"]
        mk = x.get("memory_s_kernelized", x["memory_s"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {x['compute_s']:.4f} | "
            f"{x['memory_s']:.4f} | {mk:.4f} | {x['collective_s']:.4f} | "
            f"{x['dcn_s']:.4f} | {x['dominant']} | "
            f"{x['useful_ratio']:.2f} | {x['roofline_frac']:.4f} | "
            f"{x.get('roofline_frac_kern', x['roofline_frac']):.4f} |")
    return "\n".join(out)


def load_autoplan() -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(AUTOPLAN_ROOT,
                                              "*_telemetry.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def autoplan_table(rows: list[dict]) -> str:
    """Per (arch, module): mean pre/post difficulty + summed plan errors
    from the autoplan telemetry artifacts (repro.autoplan.telemetry)."""
    out = ["| arch | module | difficulty pre | post | reduction | "
           "err auto | err fixed §V |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        ea, ef = r.get("error_auto", {}), r.get("error_fixed", {})
        for m, t in sorted(r["modules"].items()):
            pre = sum(t["difficulty_pre"]) / max(len(t["difficulty_pre"]), 1)
            post = sum(t["difficulty_post"]) / max(len(t["difficulty_post"]), 1)
            red = 0.0 if pre == 0 else 100.0 * (1 - post / pre)
            sa = sum(ea[m]) if m in ea else float("nan")
            sf = sum(ef[m]) if m in ef else float("nan")
            out.append(f"| {r['arch']} | {m} | {pre:.4f} | {post:.4f} | "
                       f"{red:+.1f}% | {sa:.4g} | {sf:.4g} |")
    return "\n".join(out)


def load_serving() -> list[dict]:
    if not os.path.exists(SERVING_PATH):
        return []
    with open(SERVING_PATH) as f:
        return json.load(f)


def serving_table(rows: list[dict]) -> str:
    """Batched vs per-slot engine throughput (serving_throughput.py)."""
    out = ["| arch | slots | engine | tok/s | dispatches/tick | "
           "tick GFLOPs (roofline) | batched ≥ per-slot |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        for eng in ("batched", "per_slot"):
            e = r[eng]
            out.append(
                f"| {r['arch']} | {r['max_slots']} | {eng} | "
                f"{e['tok_s']:.1f} | {e['dispatches_per_tick']:.2f} | "
                f"{r['tick_gflops_roofline']:.4g} | "
                f"{'yes' if r['batched_ge_per_slot'] else 'NO'} |")
    return "\n".join(out)


def load_kernels() -> list[dict]:
    if not os.path.exists(KERNELS_PATH):
        return []
    with open(KERNELS_PATH) as f:
        return json.load(f)


def kernels_table(rows: list[dict]) -> str:
    """Fused one-pass qlinear vs the staged 3-round-trip composition
    (benchmarks/kernel_bench.py → experiments/kernels/BENCH_kernels.json)."""
    out = ["| shape (n×k×m) | HBM staged | HBM fused | roundtrips | "
           "staged µs | fused µs | modeled tok/s staged | fused | "
           "fused ≥ staged |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['shape']} | {r['hbm_bytes_staged']} | "
            f"{r['hbm_bytes_fused']} | "
            f"{r['activation_roundtrips_staged']}→"
            f"{r['activation_roundtrips_fused']} | "
            f"{r['staged_us_interpret']:.0f} | {r['fused_us_interpret']:.0f} | "
            f"{r['modeled_tok_s_staged']:.3g} | "
            f"{r['modeled_tok_s_fused']:.3g} | "
            f"{'yes' if r['fused_ge_staged'] else 'NO'} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    parts = []
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        if not rows:
            continue
        parts.append(f"\n### Dry-run — mesh {mesh} ({len(rows)} cells)\n")
        parts.append(dryrun_table(rows))
        parts.append(f"\n### Roofline — mesh {mesh}\n")
        parts.append(roofline_table(rows))
    ap_rows = load_autoplan()
    if ap_rows:
        parts.append(f"\n### Autoplan telemetry ({len(ap_rows)} archs)\n")
        parts.append(autoplan_table(ap_rows))
    sv_rows = load_serving()
    if sv_rows:
        parts.append(f"\n### Serving throughput ({len(sv_rows)} archs)\n")
        parts.append(serving_table(sv_rows))
    kn_rows = load_kernels()
    if kn_rows:
        parts.append(f"\n### Kernels — fused vs staged qlinear "
                     f"({len(kn_rows)} shapes)\n")
        parts.append(kernels_table(kn_rows))
    text = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
