"""Generate the EXPERIMENTS.md §Dry-run, §Roofline, §Autoplan, §Serving,
§Prefix, §Speculative and §Kernels tables from the JSON artifacts
(experiments/dryrun/<mesh>/<arch>__<shape>.json,
experiments/autoplan/<arch>_telemetry.json,
experiments/serving/BENCH_serving.json,
experiments/serving/BENCH_prefix.json,
experiments/serving/BENCH_spec.json,
experiments/kernels/BENCH_kernels.json).

Usage: PYTHONPATH=src python -m benchmarks.report [--out EXPERIMENTS_tables.md]

``--check FRESH.json [...]`` is the CI benchmark-regression gate: each
freshly emitted ``BENCH_*_quick.json`` is compared against its committed
``experiments/**/BENCH_*.json`` baseline (the ``_quick`` suffix is
stripped to find it) and the run FAILS on a >20% throughput regression.
Wall-clock numbers do not transfer between machines, so the gate
compares MACHINE-PORTABLE metrics only: the kernels' modeled tok/s (an
analytic roofline quantity) and the serving engines' throughput ratios
relative to the per-slot baseline measured in the SAME run.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
AUTOPLAN_ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                             "autoplan")
EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")
SERVING_PATH = os.path.join(EXPERIMENTS, "serving", "BENCH_serving.json")
LATENCY_PATH = os.path.join(EXPERIMENTS, "serving", "BENCH_latency.json")
KERNELS_PATH = os.path.join(EXPERIMENTS, "kernels", "BENCH_kernels.json")
LOAD_PATH = os.path.join(EXPERIMENTS, "serving", "BENCH_load.json")
PREFIX_PATH = os.path.join(EXPERIMENTS, "serving", "BENCH_prefix.json")
SPEC_PATH = os.path.join(EXPERIMENTS, "serving", "BENCH_spec.json")

CHECK_THRESHOLD = 0.8      # fresh metric must be ≥ 80% of the baseline


def load(mesh: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ROOT, mesh, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compile s | args GB/dev | temp GB/dev | "
           "peak GB/dev | HLO GFLOPs/dev | wire GB/dev | #coll |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.1f} | "
            f"{m['argument_gb']:.2f} | {m['temp_gb']:.2f} | "
            f"{m['peak_gb_estimate']:.2f} | "
            f"{r['hlo']['flops_per_device'] / 1e9:.1f} | "
            f"{r['hlo']['wire_gb_per_device']:.2f} | "
            f"{r['hlo']['n_collectives']} |")
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | mem(kern) s | "
           "coll s | dcn s | dominant | useful | frac | frac(kern) |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        x = r["roofline"]
        mk = x.get("memory_s_kernelized", x["memory_s"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {x['compute_s']:.4f} | "
            f"{x['memory_s']:.4f} | {mk:.4f} | {x['collective_s']:.4f} | "
            f"{x['dcn_s']:.4f} | {x['dominant']} | "
            f"{x['useful_ratio']:.2f} | {x['roofline_frac']:.4f} | "
            f"{x.get('roofline_frac_kern', x['roofline_frac']):.4f} |")
    return "\n".join(out)


def load_autoplan() -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(AUTOPLAN_ROOT,
                                              "*_telemetry.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def autoplan_table(rows: list[dict]) -> str:
    """Per (arch, module): mean pre/post difficulty + summed plan errors
    from the autoplan telemetry artifacts (repro.autoplan.telemetry)."""
    out = ["| arch | module | difficulty pre | post | reduction | "
           "err auto | err fixed §V |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        ea, ef = r.get("error_auto", {}), r.get("error_fixed", {})
        for m, t in sorted(r["modules"].items()):
            pre = sum(t["difficulty_pre"]) / max(len(t["difficulty_pre"]), 1)
            post = sum(t["difficulty_post"]) / max(len(t["difficulty_post"]), 1)
            red = 0.0 if pre == 0 else 100.0 * (1 - post / pre)
            sa = sum(ea[m]) if m in ea else float("nan")
            sf = sum(ef[m]) if m in ef else float("nan")
            out.append(f"| {r['arch']} | {m} | {pre:.4f} | {post:.4f} | "
                       f"{red:+.1f}% | {sa:.4g} | {sf:.4g} |")
    return "\n".join(out)


def load_serving() -> list[dict]:
    if not os.path.exists(SERVING_PATH):
        return []
    with open(SERVING_PATH) as f:
        return json.load(f)


def serving_table(rows: list[dict]) -> str:
    """Paged vs batched vs per-slot engine throughput
    (serving_throughput.py → BENCH_serving.json).  The paged row notes
    the resolved decode-attention backend ("pallas" = the in-VMEM
    paged-attention kernel on TPU auto; "xla" = the paged_view gather
    fallback the CPU run measured — docs/paged_attention.md)."""
    out = ["| arch | slots | engine | attn backend | tok/s | "
           "prefill tok/s | dispatches/tick | pool occ. peak | "
           "paged ≥ per-slot | batched prefill ≥ per-req |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        for eng in ("paged", "batched", "per_slot"):
            if eng not in r:
                continue
            e = r[eng]
            occ = (f"{e['page_occupancy_peak']:.2f}"
                   if "page_occupancy_peak" in e else "—")
            out.append(
                f"| {r['arch']} | {r['max_slots']} | {eng} | "
                f"{e.get('paged_attention_backend', '—')} | "
                f"{e['tok_s']:.1f} | {e.get('prefill_tok_s', 0):.1f} | "
                f"{e['dispatches_per_tick']:.2f} | {occ} | "
                f"{'yes' if r.get('paged_ge_per_slot') else 'NO'} | "
                f"{'yes' if r.get('batched_prefill_ge_per_request') else 'NO'}"
                " |")
    return "\n".join(out)


def load_latency() -> list[dict]:
    if not os.path.exists(LATENCY_PATH):
        return []
    with open(LATENCY_PATH) as f:
        return json.load(f)


def latency_table(rows: list[dict]) -> str:
    """Per-engine request latency from the traced serving pass
    (serving_throughput.py → BENCH_latency.json, spans collected by
    repro.obs).  TTFT = submit → first token (sampled from the prefill
    logits); per-token = consecutive token-emission deltas."""
    out = ["| arch | engine | reqs | TTFT p50 ms | p99 ms | "
           "per-token p50 ms | p99 ms | all measured |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        for eng, e in r["engines"].items():
            measured = (e["all_requests_measured"]
                        and e["all_tokens_measured"]
                        and e["percentiles_ordered"])
            out.append(
                f"| {r['arch']} | {eng} | {e['requests']} | "
                f"{1e3 * e['ttft_s']['p50_s']:.2f} | "
                f"{1e3 * e['ttft_s']['p99_s']:.2f} | "
                f"{1e3 * e['per_token_s']['p50_s']:.2f} | "
                f"{1e3 * e['per_token_s']['p99_s']:.2f} | "
                f"{'yes' if measured else 'NO'} |")
    return "\n".join(out)


def load_load() -> list[dict]:
    if not os.path.exists(LOAD_PATH):
        return []
    with open(LOAD_PATH) as f:
        return json.load(f)


def load_table(rows: list[dict]) -> str:
    """HTTP front-end load scenarios + the chunked-prefill probe
    (load_gen.py → BENCH_load.json).  Offered requests are classified
    completed / shed (503 admission control) / deadline-expired /
    failed (engine fault, docs/resilience.md); TTFT and inter-token
    gaps are CLIENT-side (over loopback HTTP), goodput counts completed
    requests' tokens only."""
    out = ["| scenario | offered | rate req/s | completed | shed | "
           "expired | failed | goodput tok/s | TTFT p50 ms | p99 ms | "
           "gap p50 ms | p99 ms | accounted |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    probe = None
    retry_rows = {}
    recovery = None
    for r in rows:
        if r.get("kind") == "probe":
            probe = r
            continue
        t, g = r["ttft_s"], r["client_gap_s"]
        out.append(
            f"| {r['scenario']} | {r['offered']} | {r['rate_req_s']:.0f} | "
            f"{r['completed']} | {r['shed']} | {r['expired']} | "
            f"{r.get('failed', 0)} | "
            f"{r['goodput_tok_s']:.1f} | "
            f"{1e3 * (t['p50'] or 0):.1f} | {1e3 * (t['p99'] or 0):.1f} | "
            f"{1e3 * (g['p50'] or 0):.1f} | {1e3 * (g['p99'] or 0):.1f} | "
            f"{'yes' if r['accounted'] else 'NO'} |")
        if r["scenario"] in ("burst_noretry", "burst_retry"):
            retry_rows[r["scenario"]] = r
        elif r["scenario"] == "fault_recovery":
            recovery = r
    if len(retry_rows) == 2:
        nr, rt = retry_rows["burst_noretry"], retry_rows["burst_retry"]
        out += ["",
                f"Retry goodput (Retry-After backoff clients): "
                f"{rt['completed']}/{rt['offered']} completed with retries "
                f"({rt.get('retried', 0)} requests retried) vs "
                f"{nr['completed']}/{nr['offered']} fire-and-forget "
                f"({nr['shed']} shed) — retry goodput: "
                f"{'yes' if rt.get('retry_goodput') else 'NO'}."]
    if recovery is not None:
        out += ["",
                f"Fault recovery (injected decode dispatch failure): "
                f"{recovery['restarts']} watchdog restart(s), "
                f"recovered: {'yes' if recovery['recovered'] else 'NO'}, "
                f"all pages freed: "
                f"{'yes' if recovery['all_pages_freed'] else 'NO'}."]
    if probe is not None:
        u = probe["victim_gap_unchunked_s"]["p99"]
        c = probe["victim_gap_chunked_s"]["p99"]
        out += ["",
                f"Chunked-prefill probe (long prompt "
                f"{probe['long_prompt']}, chunk {probe['prefill_chunk']}): "
                f"victim p99 inter-token gap {1e3 * c:.1f} ms chunked vs "
                f"{1e3 * u:.1f} ms one-shot — bounds p99: "
                f"{'yes' if probe['chunked_prefill_bounds_p99'] else 'NO'}, "
                f"tokens identical: "
                f"{'yes' if probe['chunked_tokens_identical'] else 'NO'}."]
    return "\n".join(out)


def load_prefix() -> list[dict]:
    if not os.path.exists(PREFIX_PATH):
        return []
    with open(PREFIX_PATH) as f:
        return json.load(f)


def prefix_table(rows: list[dict]) -> str:
    """Prefix-cache on/off comparison on the shared-system-prompt
    workload (prefix_bench.py → BENCH_prefix.json).  Prefill tokens are
    the engine's own dispatch accounting — the cache-on run prefills
    only the non-shared suffix (docs/serving.md §Prefix caching); tok/s
    is report-only wall clock."""
    out = ["| arch | reqs | sys tokens | hit rate | prefill tok off→on | "
           "saved tok | saved GFLOPs | COW | evict | tok/s off→on | "
           "identical | suffix-only |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        px = r["on"]["prefix"]
        out.append(
            f"| {r['arch']} | {r['n_requests']} | {r['system_tokens']} | "
            f"{px['hit_rate']:.2f} | {r['off']['prefill_tokens']}→"
            f"{r['on']['prefill_tokens']} | {px['saved_prefill_tokens']} | "
            f"{px['saved_prefill_flops'] / 1e9:.3f} | {px['cow_copies']} | "
            f"{px['evictions']} | {r['off']['tok_s']:.0f}→"
            f"{r['on']['tok_s']:.0f} | "
            f"{'yes' if r['tokens_identical'] else 'NO'} | "
            f"{'yes' if r['suffix_only_prefill'] else 'NO'} |")
    return "\n".join(out)


def load_spec() -> list[dict]:
    if not os.path.exists(SPEC_PATH):
        return []
    with open(SPEC_PATH) as f:
        return json.load(f)


def spec_table(rows: list[dict]) -> str:
    """Speculative-decoding on/off comparison (spec_bench.py →
    BENCH_spec.json).  Accepted tokens per verify dispatch is the
    headline — the plain engine's ceiling is exactly 1.0; tok/s is
    report-only wall clock."""
    out = ["| arch | reqs | mode | accept rate | tok/dispatch | "
           "verify disp. | draft disp. | tok/s | identical | accounted |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ident = "yes" if r["tokens_identical"] else "NO"
        acct = "yes" if r["acceptance_accounted"] else "NO"
        for mode in (["off"] + [f"k{k}" for k in r["spec_ks"]]):
            e = r[mode]
            sp = e.get("spec")
            rate = "—" if sp is None else f"{sp['acceptance_rate']:.2f}"
            tpd = 1.0 if sp is None else sp["accepted_per_dispatch"]
            verify = (e["decode_dispatches"] if sp is None
                      else sp["verify_dispatches"])
            drafts = 0 if sp is None else sp["draft_dispatches"]
            out.append(
                f"| {r['arch']} | {r['n_requests']} | {mode} | {rate} | "
                f"{tpd:.2f} | {verify} | {drafts} | "
                f"{e['tok_s']:.0f} | {ident} | {acct} |")
    return "\n".join(out)


def load_kernels() -> list[dict]:
    if not os.path.exists(KERNELS_PATH):
        return []
    with open(KERNELS_PATH) as f:
        return json.load(f)


def paged_attention_table(rows: list[dict]) -> str:
    """In-VMEM paged-attention kernel vs the XLA gather path
    (kernel_bench.py rows tagged kind="paged_attention"; the fused
    backend is what `auto` resolves to on TPU, the gather is the parity
    fallback)."""
    out = ["| shape | int8 KV | HBM gather | HBM fused (kernel) | "
           "gather µs | fused µs | modeled tok/s gather | fused | "
           "fused bytes < gather |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['shape']} | {'yes' if r['int8kv'] else 'no'} | "
            f"{r['hbm_bytes_gather']} | {r['hbm_bytes_fused']} | "
            f"{r['gather_us_interpret']:.0f} | {r['fused_us_interpret']:.0f} |"
            f" {r['modeled_tok_s_gather']:.3g} | "
            f"{r['modeled_tok_s_fused']:.3g} | "
            f"{'yes' if r['fused_lt_gather_bytes'] else 'NO'} |")
    return "\n".join(out)


def kernels_table(rows: list[dict]) -> str:
    """Fused one-pass qlinear vs the staged 3-round-trip composition
    (benchmarks/kernel_bench.py → experiments/kernels/BENCH_kernels.json)."""
    out = ["| shape (n×k×m) | HBM staged | HBM fused | roundtrips | "
           "staged µs | fused µs | modeled tok/s staged | fused | "
           "fused ≥ staged |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['shape']} | {r['hbm_bytes_staged']} | "
            f"{r['hbm_bytes_fused']} | "
            f"{r['activation_roundtrips_staged']}→"
            f"{r['activation_roundtrips_fused']} | "
            f"{r['staged_us_interpret']:.0f} | {r['fused_us_interpret']:.0f} | "
            f"{r['modeled_tok_s_staged']:.3g} | "
            f"{r['modeled_tok_s_fused']:.3g} | "
            f"{'yes' if r['fused_ge_staged'] else 'NO'} |")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# --check: benchmark-regression gate (CI)
# ---------------------------------------------------------------------------


def _find_baseline(fresh_path: str) -> str | None:
    """The committed experiments/** baseline for a fresh BENCH JSON.

    Prefer the EXACT basename (quick runs compare against a committed
    quick baseline — relative speedups shrink with the workload, so a
    quick-vs-full comparison would be biased); fall back to the
    ``_quick``-stripped name for purely analytic metrics."""
    names = [os.path.basename(fresh_path)]
    stripped = names[0].replace("_quick.json", ".json")
    if stripped != names[0]:
        names.append(stripped)
    for name in names:
        hits = sorted(glob.glob(os.path.join(EXPERIMENTS, "**", name),
                                recursive=True))
        hits = [h for h in hits
                if os.path.abspath(h) != os.path.abspath(fresh_path)]
        if hits:
            return hits[0]
    return None


def _kernel_metrics(rows: list[dict]) -> dict[str, float]:
    """shape → modeled tok/s of the fused kernel (analytic: transfers
    across machines), plus the per-kind contract as a 0/1 metric
    (fused ≥ staged roofline for the qlinear rows; strictly fewer HBM
    bytes than the gather for the paged-attention rows)."""
    out = {}
    for r in rows:
        if r.get("kind") == "paged_attention":
            key = f"paged:{r['shape']}"
            out[f"{key}:modeled_tok_s_fused"] = r["modeled_tok_s_fused"]
            out[f"{key}:fused_lt_gather_bytes"] = float(
                r["fused_lt_gather_bytes"])
            continue
        out[f"{r['shape']}:modeled_tok_s_fused"] = r["modeled_tok_s_fused"]
        out[f"{r['shape']}:fused_ge_staged"] = float(r["fused_ge_staged"])
    return out


def _serving_metrics(rows: list[dict]) -> dict[str, float]:
    """Machine-portable serving throughput metrics.

    Wall-clock tok/s does not transfer across machines, and even
    same-machine CROSS-run ratios are too noisy at the CI smoke scale —
    so the gate compares (a) DETERMINISTIC dispatch efficiency (tokens
    served per decode/prefill dispatch: losing the batched tick or the
    batched admission collapses these), and (b) the same-run contract
    booleans, whose two sides share one process and one machine."""
    out = {}
    for r in rows:
        for eng in ("paged", "batched", "per_slot"):
            if eng not in r:
                continue
            e = r[eng]
            out[f"{r['arch']}:{eng}_tokens_per_decode_dispatch"] = (
                e["tokens"] / max(e["decode_dispatches"], 1))
            out[f"{r['arch']}:{eng}_prefill_tokens_per_dispatch"] = (
                e["prefill_tokens"] / max(e["prefill_dispatches"], 1))
        for flag in ("paged_ge_per_slot", "batched_prefill_ge_per_request",
                     "greedy_tokens_identical"):
            if flag in r:
                out[f"{r['arch']}:{flag}"] = float(r[flag])
    return out


def _latency_metrics(rows: list[dict]) -> dict[str, float]:
    """Machine-portable latency-artifact metrics: the wall-clock
    percentiles stay report-only; the gate compares the deterministic
    sample counts (every request a TTFT, every decode token a latency
    sample — a broken tracer or summarizer collapses these to 0) and
    the measurement contracts as 0/1 metrics."""
    out = {}
    for r in rows:
        for eng, e in r["engines"].items():
            key = f"{r['arch']}:{eng}"
            out[f"{key}:requests"] = float(e["requests"])
            out[f"{key}:ttft_samples"] = float(e["ttft_s"]["count"])
            out[f"{key}:per_token_samples"] = float(e["per_token_s"]["count"])
            for flag in ("all_requests_measured", "all_tokens_measured",
                         "percentiles_ordered"):
                out[f"{key}:{flag}"] = float(e[flag])
    return out


def _load_metrics(rows: list[dict]) -> dict[str, float]:
    """Machine-portable load-artifact metrics: client-side wall-clock
    percentiles and goodput stay report-only; the gate compares the
    per-scenario accounting contracts (every offered request classified,
    traffic actually served), the chunked-prefill probe's contract
    booleans, the trace replay-identity bit, and the resilience
    scenarios' retry-goodput / watchdog-recovery booleans
    (docs/resilience.md)."""
    flags = ("accounted", "served_any", "trace_replay_identical",
             "retry_goodput", "recovered", "all_pages_freed")
    out = {}
    for r in rows:
        if r.get("kind") == "probe":
            out["probe:chunked_prefill_bounds_p99"] = float(
                r["chunked_prefill_bounds_p99"])
            out["probe:chunked_tokens_identical"] = float(
                r["chunked_tokens_identical"])
            continue
        key = r["scenario"]
        for flag in flags:
            if flag in r:
                out[f"{key}:{flag}"] = float(r[flag])
    return out


def _prefix_metrics(rows: list[dict]) -> dict[str, float]:
    """Machine-portable prefix-cache metrics: wall-clock tok/s stays
    report-only; the gate compares the deterministic cache counters
    (hit rate, saved prefill tokens — a broken matcher collapses both
    to 0) and the contract booleans (higher = better throughout)."""
    out = {}
    for r in rows:
        key = r["arch"]
        px = r["on"]["prefix"]
        out[f"{key}:hit_rate"] = px["hit_rate"]
        out[f"{key}:saved_prefill_tokens"] = float(
            px["saved_prefill_tokens"])
        for flag in ("tokens_identical", "all_hits", "suffix_only_prefill",
                     "prefill_tokens_reduced", "shared_pages_accounted"):
            out[f"{key}:{flag}"] = float(r[flag])
    return out


def _spec_metrics(rows: list[dict]) -> dict[str, float]:
    """Machine-portable speculative-decoding metrics: wall-clock tok/s
    stays report-only; the gate compares the deterministic acceptance
    counters (accepted tokens per verify dispatch — a broken draft or
    verify path collapses it toward 1) and the contract booleans
    (higher = better throughout)."""
    out = {}
    for r in rows:
        key = r["arch"]
        for k in r["spec_ks"]:
            sp = r[f"k{k}"]["spec"]
            out[f"{key}:k{k}:accepted_per_dispatch"] = (
                sp["accepted_per_dispatch"])
            out[f"{key}:k{k}:acceptance_rate"] = sp["acceptance_rate"]
        for flag in ("tokens_identical", "acceptance_accounted",
                     "one_dispatch_per_tick",
                     "accepted_per_dispatch_exceeds_plain"):
            out[f"{key}:{flag}"] = float(r[flag])
    return out


def _bench_metrics(path: str, rows: list[dict]) -> dict[str, float]:
    name = os.path.basename(path)
    if "kernels" in name:
        return _kernel_metrics(rows)
    if "latency" in name:      # before "serving": both live under serving/
        return _latency_metrics(rows)
    if "load" in name:         # ditto: BENCH_load* lives under serving/
        return _load_metrics(rows)
    if "prefix" in name:       # ditto: BENCH_prefix* lives under serving/
        return _prefix_metrics(rows)
    if "spec" in name:         # ditto: BENCH_spec* lives under serving/
        return _spec_metrics(rows)
    if "serving" in name:
        return _serving_metrics(rows)
    raise SystemExit(f"--check: no metric extractor for {name}")


def check(paths: list[str]) -> int:
    """Compare fresh BENCH JSONs against committed baselines; return the
    number of >20% regressions (0 = gate passes)."""
    failures = 0
    for fresh_path in paths:
        base_path = _find_baseline(fresh_path)
        if base_path is None:
            print(f"CHECK SKIP {fresh_path}: no committed baseline")
            continue
        with open(fresh_path) as f:
            fresh = _bench_metrics(fresh_path, json.load(f))
        with open(base_path) as f:
            base = _bench_metrics(base_path, json.load(f))
        shared = sorted(set(fresh) & set(base))
        if not shared:
            print(f"CHECK SKIP {fresh_path}: no overlapping rows with "
                  f"{base_path}")
            continue
        for key in shared:
            b = base[key]
            ratio = fresh[key] / b if b else 1.0
            ok = ratio >= CHECK_THRESHOLD
            tag = "ok  " if ok else "FAIL"
            print(f"CHECK {tag} {os.path.basename(fresh_path)} {key}: "
                  f"fresh={fresh[key]:.4g} baseline={b:.4g} "
                  f"ratio={ratio:.3f} (floor {CHECK_THRESHOLD})")
            failures += not ok
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="")
    ap.add_argument("--check", nargs="+", metavar="FRESH_JSON", default=None,
                    help="benchmark-regression gate: compare fresh BENCH "
                         "JSONs against the committed experiments/** "
                         "baselines; exit 1 on a >20%% throughput "
                         "regression")
    args = ap.parse_args(argv)
    if args.check is not None:
        failures = check(args.check)
        if failures:
            raise SystemExit(f"--check: {failures} benchmark regression(s)")
        print("--check: all benchmarks within threshold")
        return
    parts = []
    for mesh in ("16x16", "2x16x16"):
        rows = load(mesh)
        if not rows:
            continue
        parts.append(f"\n### Dry-run — mesh {mesh} ({len(rows)} cells)\n")
        parts.append(dryrun_table(rows))
        parts.append(f"\n### Roofline — mesh {mesh}\n")
        parts.append(roofline_table(rows))
    ap_rows = load_autoplan()
    if ap_rows:
        parts.append(f"\n### Autoplan telemetry ({len(ap_rows)} archs)\n")
        parts.append(autoplan_table(ap_rows))
    sv_rows = load_serving()
    if sv_rows:
        parts.append(f"\n### Serving throughput ({len(sv_rows)} archs)\n")
        parts.append(serving_table(sv_rows))
    lat_rows = load_latency()
    if lat_rows:
        parts.append(f"\n### Serving latency — TTFT / per-token "
                     f"({len(lat_rows)} archs)\n")
        parts.append(latency_table(lat_rows))
    ld_rows = load_load()
    if ld_rows:
        n_http = sum(r.get("kind") == "http" for r in ld_rows)
        parts.append(f"\n### Serving load — HTTP front-end "
                     f"({n_http} scenarios)\n")
        parts.append(load_table(ld_rows))
    px_rows = load_prefix()
    if px_rows:
        parts.append(f"\n### Serving prefix cache — shared system prompt "
                     f"({len(px_rows)} archs)\n")
        parts.append(prefix_table(px_rows))
    sp_rows = load_spec()
    if sp_rows:
        parts.append(f"\n### Serving speculative decoding — draft-verify "
                     f"({len(sp_rows)} archs)\n")
        parts.append(spec_table(sp_rows))
    kn_all = load_kernels()
    kn_rows = [r for r in kn_all if r.get("kind") != "paged_attention"]
    pa_rows = [r for r in kn_all if r.get("kind") == "paged_attention"]
    if kn_rows:
        parts.append(f"\n### Kernels — fused vs staged qlinear "
                     f"({len(kn_rows)} shapes)\n")
        parts.append(kernels_table(kn_rows))
    if pa_rows:
        parts.append(f"\n### Kernels — paged-attention decode, in-VMEM "
                     f"kernel vs XLA gather ({len(pa_rows)} shapes)\n")
        parts.append(paged_attention_table(pa_rows))
    text = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
