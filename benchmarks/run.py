"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Timings are CPU-relative
(TPU perf lives in the dry-run roofline, EXPERIMENTS.md §Roofline);
the ``derived`` column carries the paper-claim validations.
"""

from __future__ import annotations

import sys


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (
        activation_distributions, error_vs_difficulty, kernel_bench,
        massive_outliers, model_quant, serving_throughput,
        transform_comparison,
    )

    modules = [
        ("figs 1-2 activation distributions", activation_distributions),
        ("fig 3 error vs difficulty", error_vs_difficulty),
        ("fig 4 transform comparison", transform_comparison),
        ("fig 5 massive outliers + eqs 7-9", massive_outliers),
        ("kernel microbench", kernel_bench),
        ("model-level quantization", model_quant),
        ("serving throughput (paged vs batched vs per-slot)",
         serving_throughput),
    ]
    failures = []
    for label, mod in modules:
        print(f"# -- {label} --", flush=True)
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failures.append((label, repr(e)))
            print(f"benchmark_failed_{mod.__name__},0.0,{e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
