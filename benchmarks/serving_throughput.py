"""Serving-engine throughput: batched-slot decode vs the per-slot loop.

The batched ``ServingEngine`` issues ONE ``(max_slots, 1)`` jitted decode
dispatch per tick; the ``PerSlotServingEngine`` baseline issues one
``(1, 1)`` dispatch per ACTIVE slot — same useful FLOPs
(``launch.roofline.serving_tick_flops``), ``max_slots``× the dispatch and
weight-stream overhead.  This module serves an identical request set
through both engines, reports tokens/s and decode dispatches/tick, and
cross-checks the batched tick against the roofline decode-cell shape.

Writes ``experiments/serving/throughput.json`` for benchmarks/report.py
(§Serving table).  CSV rows (benchmarks.run idiom):
``serving_<arch>_<engine>,us_per_token,tok_s=..;dispatches_per_tick=..``.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.launch.roofline import serving_tick_flops
from repro.models.api import get_model
from repro.serving.engine import PerSlotServingEngine, Request, ServingEngine

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "serving", "throughput.json")

ENGINES = {"batched": ServingEngine, "per_slot": PerSlotServingEngine}


def _requests(cfg, n: int, max_new: int) -> list[Request]:
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(4 + i % 5,)),
                    max_new_tokens=max_new) for i in range(n)]


def _serve(engine_cls, model, params, cfg, *, max_slots, max_len, n_requests,
           max_new):
    eng = engine_cls(model, params, cfg, max_slots=max_slots, max_len=max_len)
    for r in _requests(cfg, n_requests, max_new):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run(max_ticks=10_000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    return {
        "tokens": toks,
        "seconds": round(dt, 4),
        "tok_s": round(toks / max(dt, 1e-9), 2),
        "decode_dispatches": eng.decode_dispatches,
        "ticks": eng.ticks,
        "dispatches_per_tick": round(eng.decode_dispatches / max(eng.ticks, 1),
                                     3),
        "outputs": {r.uid: list(r.out_tokens) for r in done},
    }


def bench_arch(arch: str, *, max_slots: int = 4, max_len: int = 64,
               n_requests: int = 8, max_new: int = 8) -> dict:
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    row = {"arch": arch, "max_slots": max_slots, "n_requests": n_requests,
           "max_new": max_new,
           # roofline cross-check: one batched tick == one decode cell of
           # global_batch=max_slots (2·N_active·max_slots useful FLOPs)
           "tick_gflops_roofline": round(
               serving_tick_flops(cfg, max_slots) / 1e9, 6)}
    for name, cls in ENGINES.items():
        # warmup populates the shared jit caches (prefill per prompt
        # length + this engine's decode shape) so timing excludes
        # compiles; max_new=2 reaches every compile at minimal token cost
        _serve(cls, model, params, cfg, max_slots=max_slots, max_len=max_len,
               n_requests=n_requests, max_new=2)
        row[name] = _serve(cls, model, params, cfg, max_slots=max_slots,
                           max_len=max_len, n_requests=n_requests,
                           max_new=max_new)
    row["greedy_tokens_identical"] = (
        row["batched"].pop("outputs") == row["per_slot"].pop("outputs"))
    row["batched_ge_per_slot"] = (
        row["batched"]["tok_s"] >= row["per_slot"]["tok_s"])
    return row


def run(archs=("stablelm_3b",), *, max_slots: int = 4, n_requests: int = 8,
        max_new: int = 8, out_path: str = ARTIFACT) -> list[dict]:
    rows = []
    for arch in archs:
        row = bench_arch(arch, max_slots=max_slots, n_requests=n_requests,
                         max_new=max_new)
        rows.append(row)
        for name in ENGINES:
            r = row[name]
            emit(f"serving_{arch}_{name}",
                 1e6 * r["seconds"] / max(r["tokens"], 1),
                 f"tok_s={r['tok_s']};dispatches_per_tick="
                 f"{r['dispatches_per_tick']}")
        emit(f"serving_{arch}_batched_ge_per_slot", 0.0,
             f"holds={row['batched_ge_per_slot']};greedy_identical="
             f"{row['greedy_tokens_identical']}")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; default stablelm_3b")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--out", default=ARTIFACT)
    args = ap.parse_args(argv)
    run(tuple(args.arch or ("stablelm_3b",)), max_slots=args.max_slots,
        n_requests=args.requests, max_new=args.max_new, out_path=args.out)


if __name__ == "__main__":
    main()
