"""Serving-engine throughput: paged vs batched-slot vs per-slot engines.

Three engines serve an identical request stream:

  * ``paged``    — paged KV pool + ONE ``(n_admit, padded_len)`` batched
    prefill dispatch per admission round (PagedServingEngine);
  * ``batched``  — dense slot-major cache, ONE ``(max_slots, 1)`` decode
    dispatch per tick, per-request batch-1 prefill (ServingEngine);
  * ``per_slot`` — the seed loop, one decode dispatch per active slot.

Besides end-to-end tokens/s and decode dispatches/tick, a PREFILL-phase
run (``max_new=1`` — admission cost only) pins the in-engine batched
prefill against the per-request path, and the paged row reports
page-pool occupancy.  Token counts come from each engine's ``run_stats``
(the engines report them; nothing is re-derived from Request lists).
Roofline cross-checks: ``serving_tick_flops`` for the decode tick,
``serving_prefill_flops`` for the admission dispatch.

Writes ``experiments/serving/BENCH_serving.json`` (``--quick`` → the
``_quick`` sibling) for benchmarks/report.py — the §Serving table and
the ``report.py --check`` benchmark-regression gate compare the
engine-relative throughput ratios, which transfer across machines.
CSV rows (benchmarks.run idiom):
``serving_<arch>_<engine>,us_per_token,tok_s=..;dispatches_per_tick=..``.

A second pass re-runs each engine under ``repro.obs`` span tracing and
writes ``experiments/serving/BENCH_latency.json``: TTFT and per-token
latency percentiles (p50/p90/p99) per engine, plus the deterministic
sample counts and ordering contracts (p99 ≥ p50, every request measured)
that the ``--check`` gate compares — the wall-clock percentiles
themselves do not transfer across machines and are report-only.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.launch.roofline import serving_prefill_flops, serving_tick_flops
from repro.models.api import get_model
from repro.obs import Observability
from repro.serving.engine import (EngineConfig, PagedServingEngine,
                                  PerSlotServingEngine, Request,
                                  ServingEngine)

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "serving", "BENCH_serving.json")
LATENCY_ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                                "experiments", "serving",
                                "BENCH_latency.json")

PAGE_SIZE = 4          # reduced-config scale (max_len 64)
PREFILL_BUCKET = 8

ENGINES = {
    "paged": PagedServingEngine,
    "batched": ServingEngine,
    "per_slot": PerSlotServingEngine,
}


def _config(*, max_slots, max_len, obs=None) -> EngineConfig:
    # one config builds all three engines: the non-paged engines ignore
    # the page-pool fields (docs/api.md)
    return EngineConfig(max_slots=max_slots, max_len=max_len,
                        page_size=PAGE_SIZE, prefill_bucket=PREFILL_BUCKET,
                        obs=obs)


def _requests(cfg, n: int, max_new: int) -> list[Request]:
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(4 + i % 5,)),
                    max_new_tokens=max_new) for i in range(n)]


REPEATS = 3   # timed sections take the best of N runs: single-shot wall
#               clock on the reduced CPU workloads is too noisy for the
#               report.py --check regression gate


def _serve_once(engine_cls, model, params, cfg, *, max_slots, max_len,
                n_requests, max_new):
    eng = engine_cls(model, params, cfg,
                     config=_config(max_slots=max_slots, max_len=max_len))
    for r in _requests(cfg, n_requests, max_new):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run(max_ticks=10_000)
    return eng, done, time.perf_counter() - t0


def _serve(engine_cls, model, params, cfg, *, max_slots, max_len, n_requests,
           max_new, repeats=REPEATS):
    dt = float("inf")
    for _ in range(repeats):
        eng, done, t = _serve_once(engine_cls, model, params, cfg,
                                   max_slots=max_slots, max_len=max_len,
                                   n_requests=n_requests, max_new=max_new)
        dt = min(dt, t)
    st = eng.run_stats
    row = {
        "tokens": st["decode_tokens"],
        "prefill_tokens": st["prefill_tokens"],
        "seconds": round(dt, 4),
        "tok_s": round(st["decode_tokens"] / max(dt, 1e-9), 2),
        "decode_dispatches": st["decode_dispatches"],
        "prefill_dispatches": st["prefill_dispatches"],
        "ticks": st["ticks"],
        "dispatches_per_tick": round(st["dispatches_per_tick"], 3),
        "outputs": {r.uid: list(r.out_tokens) for r in done},
    }
    if "page_occupancy_peak" in st:
        row.update(n_pages=st["n_pages"], page_size=st["page_size"],
                   peak_pages_in_use=st["peak_pages_in_use"],
                   page_occupancy_peak=round(st["page_occupancy_peak"], 4),
                   # resolved decode-attention executor over the pool
                   # ("pallas" on TPU auto; "xla" = the gather fallback
                   # this CPU run measures — docs/paged_attention.md)
                   paged_attention_backend=st["paged_attention_backend"])
    return row


def _prefill_phase(engine_cls, model, params, cfg, *, max_slots, max_len,
                   n_requests, repeats=REPEATS):
    """Admission-only workload (max_new=1): every request finishes at its
    prefill, so wall time ≈ prefill cost.  Returns prompt tokens/s.
    Fields are namespaced so they never clobber the main run's row."""
    dt = float("inf")
    for _ in range(repeats):
        eng = engine_cls(model, params, cfg,
                         config=_config(max_slots=max_slots, max_len=max_len))
        for r in _requests(cfg, n_requests, 1):
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run(max_ticks=10_000)
        dt = min(dt, time.perf_counter() - t0)
    st = eng.run_stats
    return {
        "prefill_phase_tokens": st["prefill_tokens"],
        "prefill_phase_dispatches": st["prefill_dispatches"],
        "prefill_phase_seconds": round(dt, 4),
        "prefill_tok_s": round(st["prefill_tokens"] / max(dt, 1e-9), 2),
    }


def bench_arch(arch: str, *, max_slots: int = 4, max_len: int = 64,
               n_requests: int = 8, max_new: int = 8) -> dict:
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    row = {"arch": arch, "max_slots": max_slots, "n_requests": n_requests,
           "max_new": max_new,
           # roofline cross-checks: one decode tick == a decode cell of
           # global_batch=max_slots; one admission == a prefill cell of
           # (max_slots, bucketed prompt len)
           "tick_gflops_roofline": round(
               serving_tick_flops(cfg, max_slots) / 1e9, 6),
           "prefill_gflops_roofline": round(
               serving_prefill_flops(cfg, max_slots, PREFILL_BUCKET) / 1e9,
               6)}
    for name, cls in ENGINES.items():
        # warmup runs the IDENTICAL workload once so the timed pass hits
        # only warm jit caches: the paged admission compiles per
        # (n_admit_bucket, padded_len) shape, which depends on the
        # scheduling pattern — a shorter warmup run would leak compiles
        # into the timed section
        _serve(cls, model, params, cfg, max_slots=max_slots, max_len=max_len,
               n_requests=n_requests, max_new=max_new, repeats=1)
        _prefill_phase(cls, model, params, cfg, max_slots=max_slots,
                       max_len=max_len, n_requests=n_requests, repeats=1)
        row[name] = _serve(cls, model, params, cfg, max_slots=max_slots,
                           max_len=max_len, n_requests=n_requests,
                           max_new=max_new)
        row[name].update(_prefill_phase(cls, model, params, cfg,
                                        max_slots=max_slots, max_len=max_len,
                                        n_requests=n_requests))
    outs = {name: row[name].pop("outputs") for name in ENGINES}
    row["greedy_tokens_identical"] = (
        outs["paged"] == outs["per_slot"] == outs["batched"])
    row["batched_ge_per_slot"] = (
        row["batched"]["tok_s"] >= row["per_slot"]["tok_s"])
    row["paged_ge_per_slot"] = (
        row["paged"]["tok_s"] >= row["per_slot"]["tok_s"])
    # the in-engine batched prefill vs the per-request batch-1 path
    row["batched_prefill_ge_per_request"] = (
        row["paged"]["prefill_tok_s"] >= row["batched"]["prefill_tok_s"])
    return row


def _lat_fields(summary: dict) -> dict:
    """The report-only percentile triple from a percentile summary."""
    return {"count": summary["count"],
            "mean_s": round(summary["mean"], 6),
            "p50_s": round(summary["p50"], 6),
            "p90_s": round(summary["p90"], 6),
            "p99_s": round(summary["p99"], 6)}


def bench_latency_arch(arch: str, *, max_slots: int = 4, max_len: int = 64,
                       n_requests: int = 8, max_new: int = 8) -> dict:
    """Serve the throughput workload once per engine under span tracing
    (repro.obs) and reduce the trace to TTFT / per-token percentiles.

    The jit caches are already warm from the same-shape throughput pass
    when ``run()`` drives this; standalone callers pay first-run
    compiles inside the percentiles (the contracts still hold)."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    row = {"arch": arch, "max_slots": max_slots, "n_requests": n_requests,
           "max_new": max_new, "engines": {}}
    for name, cls in ENGINES.items():
        # warmup: identical workload so the traced pass measures serving,
        # not compilation (same reasoning as bench_arch)
        _serve(cls, model, params, cfg, max_slots=max_slots, max_len=max_len,
               n_requests=n_requests, max_new=max_new, repeats=1)
        obs = Observability()
        eng = cls(model, params, cfg,
                  config=_config(max_slots=max_slots, max_len=max_len,
                                 obs=obs))
        for r in _requests(cfg, n_requests, max_new):
            eng.submit(r)
        eng.run(max_ticks=10_000)
        s = obs.summary()
        ttft, per_tok = s["ttft_s"], s["per_token_s"]
        row["engines"][name] = {
            "requests": s["counts"]["retired"],
            "decode_tokens": s["counts"]["decode_tokens"],
            "ticks": s["counts"]["ticks"],
            "ttft_s": _lat_fields(ttft),
            "per_token_s": _lat_fields(per_tok),
            "queue_wait_s": _lat_fields(s["queue_wait_s"]),
            # machine-portable contracts for the --check gate: every
            # request got a TTFT sample, every decode token a latency
            # sample, and the percentile ordering holds
            "all_requests_measured": ttft["count"] == n_requests,
            "all_tokens_measured": per_tok["count"]
            == s["counts"]["decode_tokens"],
            "percentiles_ordered": (ttft["p99"] >= ttft["p50"] > 0
                                    and per_tok["p99"] >= per_tok["p50"] > 0),
        }
    return row


def run_latency(archs=("stablelm_3b",), *, max_slots: int = 4,
                n_requests: int = 8, max_new: int = 8,
                out_path: str = LATENCY_ARTIFACT) -> list[dict]:
    rows = []
    for arch in archs:
        row = bench_latency_arch(arch, max_slots=max_slots,
                                 n_requests=n_requests, max_new=max_new)
        rows.append(row)
        for name, e in row["engines"].items():
            emit(f"latency_{arch}_{name}", 1e6 * e["ttft_s"]["p50_s"],
                 f"ttft_p99_us={1e6 * e['ttft_s']['p99_s']:.1f};"
                 f"per_token_p50_us={1e6 * e['per_token_s']['p50_s']:.1f};"
                 f"per_token_p99_us={1e6 * e['per_token_s']['p99_s']:.1f};"
                 f"measured={e['all_requests_measured']}")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def run(archs=("stablelm_3b",), *, max_slots: int = 4, n_requests: int = 8,
        max_new: int = 8, out_path: str = ARTIFACT) -> list[dict]:
    rows = []
    for arch in archs:
        row = bench_arch(arch, max_slots=max_slots, n_requests=n_requests,
                         max_new=max_new)
        rows.append(row)
        for name in ENGINES:
            r = row[name]
            emit(f"serving_{arch}_{name}",
                 1e6 * r["seconds"] / max(r["tokens"], 1),
                 f"tok_s={r['tok_s']};dispatches_per_tick="
                 f"{r['dispatches_per_tick']};prefill_tok_s="
                 f"{r['prefill_tok_s']}")
        emit(f"serving_{arch}_contracts", 0.0,
             f"paged_ge_per_slot={row['paged_ge_per_slot']};"
             f"batched_prefill_ge_per_request="
             f"{row['batched_prefill_ge_per_request']};"
             f"greedy_identical={row['greedy_tokens_identical']};"
             f"page_occupancy_peak="
             f"{row['paged'].get('page_occupancy_peak')}")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; default stablelm_3b")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests/tokens, writes the "
                         "_quick sibling artifact (never truncates the "
                         "committed baseline)")
    ap.add_argument("--out", default="")
    ap.add_argument("--no-latency", action="store_true",
                    help="skip the traced latency pass / BENCH_latency "
                         "artifact")
    ap.add_argument("--latency-out", default="",
                    help="destination for the BENCH_latency artifact (CI "
                         "emits outside the checkout so the committed "
                         "baseline stays the comparison target)")
    args = ap.parse_args(argv)
    suffix = "_quick.json" if args.quick else ".json"
    out = args.out or ARTIFACT.replace(".json", suffix)
    kw = (dict(n_requests=6, max_new=6) if args.quick
          else dict(n_requests=args.requests, max_new=args.max_new))
    archs = tuple(args.arch or ("stablelm_3b",))
    run(archs, max_slots=args.max_slots, out_path=out, **kw)
    if not args.no_latency:
        lat_out = args.latency_out or LATENCY_ARTIFACT.replace(".json",
                                                               suffix)
        run_latency(archs, max_slots=args.max_slots, out_path=lat_out, **kw)


if __name__ == "__main__":
    main()
