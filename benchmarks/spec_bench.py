"""Speculative-decoding benchmark: draft-verify serving, spec on vs off.

One greedy burst is served on the paged engine with speculation off (the
plain one-token-per-tick path) and then with self-draft speculation at
each ``spec_k``, and the runs are compared:

  * **token identity** — greedy outputs are bit-identical across every
    run (``tokens_identical``: speculation is a latency transform, not a
    sampling change — the ISSUE's acceptance pin);
  * **acceptance accounting** — the spec counters reconcile exactly:
    drafted == accepted + rejected, and every decode-phase token was
    emitted through a verify dispatch (``acceptance_accounted``);
  * **accepted tokens per verify dispatch** — the headline: the plain
    engine's ceiling is exactly 1.0 token per decode dispatch; the
    self-draft run must clear ``> 1.5`` at the deepest ``spec_k``
    (``accepted_per_dispatch_exceeds_plain``) while still issuing ONE
    verify dispatch per tick (``one_dispatch_per_tick``);
  * wall-clock tok/s for every run (report-only: does not transfer
    across machines).

Writes ``experiments/serving/BENCH_spec.json`` (``--quick`` → the
``_quick`` sibling) for benchmarks/report.py's §Speculative table and
the ``report.py --check`` regression gate, which compares only the
deterministic counters and contract booleans above.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.models.api import get_model
from repro.serving.engine import EngineConfig, PagedServingEngine, Request

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "serving", "BENCH_spec.json")

MAX_SLOTS = 4
MAX_LEN = 64
PAGE_SIZE = 4          # reduced-config scale (serving_throughput idiom)
PREFILL_BUCKET = 8
SPEC_KS = (1, 2, 4)
HEADLINE_FLOOR = 1.5   # accepted tokens per verify dispatch at max spec_k

REPEATS = 3   # timed sections take the best of N runs (CPU wall clock
#               is too noisy single-shot); counters are deterministic


def _requests(cfg, n: int, max_new: int) -> list[Request]:
    rng = np.random.default_rng(7)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(4 + i % 5,)),
                    max_new_tokens=max_new) for i in range(n)]


def _serve_once(model, params, cfg, *, spec_k, n_requests, max_new):
    eng = PagedServingEngine(
        model, params, cfg,
        config=EngineConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                            page_size=PAGE_SIZE,
                            prefill_bucket=PREFILL_BUCKET, spec_k=spec_k))
    for r in _requests(cfg, n_requests, max_new):
        eng.submit(r)
    t0 = time.perf_counter()
    done = eng.run(max_ticks=10_000)
    return eng, done, time.perf_counter() - t0


def _serve(model, params, cfg, *, spec_k, n_requests, max_new,
           repeats=REPEATS):
    dt = float("inf")
    for _ in range(repeats):
        eng, done, t = _serve_once(model, params, cfg, spec_k=spec_k,
                                   n_requests=n_requests, max_new=max_new)
        dt = min(dt, t)
    st = eng.run_stats
    row = {
        "tokens": st["decode_tokens"],
        "prefill_tokens": st["prefill_tokens"],
        "decode_dispatches": st["decode_dispatches"],
        "ticks": st["ticks"],
        "dispatches_per_tick": st["dispatches_per_tick"],
        "seconds": round(dt, 4),
        "tok_s": round(st["decode_tokens"] / max(dt, 1e-9), 2),
        "outputs": {r.uid: list(map(int, r.out_tokens)) for r in done},
    }
    sp = st["spec"]
    if sp["enabled"]:
        row["spec"] = {k: sp[k] for k in
                       ("k", "drafted", "accepted", "rejected",
                        "acceptance_rate", "emitted_tokens",
                        "verify_dispatches", "draft_dispatches",
                        "draft_prefill_dispatches",
                        "accepted_per_dispatch")}
    return row


def bench_arch(arch: str, *, n_requests: int = 8, max_new: int = 8,
               spec_ks=SPEC_KS) -> dict:
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    row = {"arch": arch, "max_slots": MAX_SLOTS, "n_requests": n_requests,
           "max_new": max_new, "spec_ks": list(spec_ks)}
    for k in (0, *spec_ks):
        # warmup: identical workload so the timed pass hits warm jit
        # caches only (each spec_k verifies a different ragged width, so
        # each mode warms its own compiles)
        _serve(model, params, cfg, spec_k=k, n_requests=n_requests,
               max_new=max_new, repeats=1)
        row["off" if k == 0 else f"k{k}"] = _serve(
            model, params, cfg, spec_k=k, n_requests=n_requests,
            max_new=max_new)
    modes = ["off"] + [f"k{k}" for k in spec_ks]
    outs = {m: row[m].pop("outputs") for m in modes}
    # --check contracts: deterministic, machine-portable
    row["tokens_identical"] = int(
        all(outs[m] == outs["off"] for m in modes[1:]))
    # every decode-phase token (all but each request's prefill-sampled
    # first) was emitted through a verify dispatch, and the draft ledger
    # balances
    row["acceptance_accounted"] = int(all(
        row[m]["spec"]["drafted"] == (row[m]["spec"]["accepted"]
                                      + row[m]["spec"]["rejected"])
        and row[m]["spec"]["emitted_tokens"]
        == row[m]["tokens"] - n_requests
        for m in modes[1:]))
    row["one_dispatch_per_tick"] = int(all(
        row[m]["dispatches_per_tick"] == 1.0 for m in modes))
    deepest = row[f"k{max(spec_ks)}"]["spec"]
    row["accepted_per_dispatch_exceeds_plain"] = int(
        deepest["accepted_per_dispatch"] > HEADLINE_FLOOR)
    return row


def run(archs=("stablelm_3b",), *, n_requests: int = 8, max_new: int = 8,
        spec_ks=SPEC_KS, out_path: str = ARTIFACT) -> list[dict]:
    rows = []
    for arch in archs:
        row = bench_arch(arch, n_requests=n_requests, max_new=max_new,
                         spec_ks=spec_ks)
        rows.append(row)
        for mode in (["off"] + [f"k{k}" for k in spec_ks]):
            r = row[mode]
            sp = r.get("spec")
            extra = ("" if sp is None else
                     f";acceptance_rate={sp['acceptance_rate']};"
                     f"accepted_per_dispatch={sp['accepted_per_dispatch']}")
            emit(f"spec_{arch}_{mode}",
                 1e6 * r["seconds"] / max(r["tokens"], 1),
                 f"tok_s={r['tok_s']};"
                 f"decode_dispatches={r['decode_dispatches']}{extra}")
        emit(f"spec_{arch}_contracts", 0.0,
             f"tokens_identical={row['tokens_identical']};"
             f"acceptance_accounted={row['acceptance_accounted']};"
             f"one_dispatch_per_tick={row['one_dispatch_per_tick']};"
             "accepted_per_dispatch_exceeds_plain="
             f"{row['accepted_per_dispatch_exceeds_plain']}")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; default stablelm_3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests/tokens, writes the "
                         "_quick sibling artifact (never truncates the "
                         "committed baseline)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    suffix = "_quick.json" if args.quick else ".json"
    out = args.out or ARTIFACT.replace(".json", suffix)
    kw = (dict(n_requests=4, max_new=6, spec_ks=(2, 4)) if args.quick
          else dict(n_requests=args.requests, max_new=args.max_new))
    run(tuple(args.arch or ("stablelm_3b",)), out_path=out, **kw)


if __name__ == "__main__":
    main()
