"""Paper Fig. 4 — layer-wise error & difficulties at down_proj under
none / smooth / rotate / smooth_rotate, plus the §IV-C α-sweep.

Expected orderings (the paper's findings):
  * rotate < smooth < none on ordinary layers;
  * rotate > none on the MASSIVE-outlier layers (1, 30) — the paper's
    counterintuitive result;
  * smooth_rotate lowest (or tied-lowest) nearly everywhere, decisively
    so on massive-outlier layers;
  * smoothing migrates difficulty into weights (difficulty_w rises),
    rotation lowers both.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import MASSIVE_LAYERS, emit, make_suite, timeit
from repro.core.difficulty import (
    layerwise_error,
    layerwise_error_transformed,
    quantization_difficulty,
)
from repro.core.transforms import TRANSFORMS, get_transform

KINDS = ("none", "smooth", "rotate", "smooth_rotate")


def run() -> dict:
    suite = [c for c in make_suite() if c.module == "down_proj"]
    t_us = timeit(lambda c=suite[0]: layerwise_error_transformed(
        c.x, c.w, TRANSFORMS["rotate"]))
    table = {}
    for case in suite:
        row = {}
        for kind in KINDS:
            row[kind] = float(layerwise_error_transformed(
                case.x, case.w, get_transform(kind)))
        xh_s, wh_s = TRANSFORMS["smooth"](case.x, case.w)
        xh_r, wh_r = TRANSFORMS["rotate"](case.x, case.w)
        # weight difficulty along INPUT channels (axis 0) — the axis the
        # transforms act on; rotation mixes rows, smoothing scales them
        row["dw_none"] = float(quantization_difficulty(case.w, axis=0))
        row["dw_smooth"] = float(quantization_difficulty(wh_s, axis=0))
        row["dw_rotate"] = float(quantization_difficulty(wh_r, axis=0))
        row["dx_smooth"] = float(quantization_difficulty(xh_s))
        row["dx_rotate"] = float(quantization_difficulty(xh_r))
        table[case.layer] = row

    ordinary = [l for l in table if l not in MASSIVE_LAYERS and l != 31]
    rot_beats_none = np.mean([table[l]["rotate"] < table[l]["none"]
                              for l in ordinary])
    rot_beats_smooth = np.mean([table[l]["rotate"] < table[l]["smooth"]
                                for l in ordinary])
    massive_rot_worse = all(table[l]["rotate"] > table[l]["none"]
                            for l in MASSIVE_LAYERS)
    sr_best = np.mean([table[l]["smooth_rotate"] <= min(
        table[l][k] for k in KINDS) * 1.001 for l in table])
    smooth_migrates = np.mean([table[l]["dw_smooth"] > table[l]["dw_none"]
                               for l in table])
    rot_flattens_w = np.mean([table[l]["dw_rotate"] < table[l]["dw_none"]
                              for l in table])
    emit("fig4_rotate_beats_none_ordinary", t_us, f"frac={rot_beats_none:.2f}")
    emit("fig4_rotate_beats_smooth_ordinary", 0.0,
         f"frac={rot_beats_smooth:.2f}")
    emit("fig4_massive_rotation_worse_than_none", 0.0,
         f"holds={massive_rot_worse}")
    emit("fig4_smooth_rotate_lowest_frac", 0.0, f"frac={sr_best:.2f}")
    emit("fig4_smoothing_migrates_difficulty_to_w", 0.0,
         f"frac={smooth_migrates:.2f}")
    emit("fig4_rotation_flattens_weights", 0.0, f"frac={rot_flattens_w:.2f}")

    # §IV-C: α sweep on o_proj/gate_proj (larger α keeps error below none)
    alpha_rows = {}
    suite_og = [c for c in make_suite() if c.module in ("o_proj", "gate_proj")
                and c.layer in (8, 16, 24)]
    for alpha in (0.5, 0.65, 0.8):
        errs = [float(layerwise_error_transformed(
            c.x, c.w, get_transform("smooth", alpha))) for c in suite_og]
        base = [float(layerwise_error(c.x, c.w)) for c in suite_og]
        alpha_rows[alpha] = float(np.mean([e / b for e, b in zip(errs, base)]))
        emit(f"fig4_alpha_sweep_{alpha}", 0.0,
             f"smooth_error_over_none={alpha_rows[alpha]:.3f}")
    return {"table": table, "massive_rot_worse": massive_rot_worse,
            "sr_best": sr_best, "alpha": alpha_rows}


if __name__ == "__main__":
    run()
