"""The paper's analysis loop (§III–IV), end to end, on a real (small)
trained model: record activations with taps, measure layer-wise error
and quantization difficulty per module, apply all four transforms, and
print a Fig.4-style table.

Run:  PYTHONPATH=src python examples/analyze_quantization.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.difficulty import (
    layerwise_error_transformed,
    quantization_difficulty,
)
from repro.core.transforms import get_transform
from repro.data import synthetic_batches
from repro.launch.train import make_train_step
from repro.models.api import get_model
from repro.optim import adamw
from repro.launch import compat

KINDS = ("none", "smooth", "rotate", "smooth_rotate")


def main():
    key = jax.random.PRNGKey(0)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        cfg = get_config("qwen1.5-4b").reduced(num_layers=4, d_model=128,
                                               d_ff=256, vocab_size=128)
        model = get_model(cfg)
        opt = adamw(3e-3)
        params = model.init(key, cfg)
        state = opt.init(params)
        step = jax.jit(make_train_step(model, cfg, opt))
        for i, batch in enumerate(synthetic_batches(cfg, 8, 64)):
            if i >= 30:
                break
            params, state, _ = step(params, state, batch, jnp.asarray(i),
                                    jax.random.fold_in(key, i))

        # record activations (paper §III-A: hooks → taps)
        toks = next(iter(synthetic_batches(cfg, 2, 128)))["tokens"]
        _, taps = model.forward_with_taps(params, cfg, toks)

        # per layer × module: error under each transform (Fig. 4 table)
        w_of = {
            "k_proj": params["layers"]["attn"]["wq"]["w"],
            "o_proj": params["layers"]["attn"]["wo"]["w"],
            "gate_proj": params["layers"]["mlp"]["wg"]["w"],
            "down_proj": params["layers"]["mlp"]["wd"]["w"],
        }
        hdr = f"{'module':>22s} {'difficulty':>10s} " + "".join(
            f"{k:>14s}" for k in KINDS)
        print(hdr)
        print("-" * len(hdr))
        for module, tap in sorted(taps.items()):
            L = tap.shape[0]
            for layer in range(L):
                x = tap[layer].reshape(-1, tap.shape[-1])
                w = w_of[module][layer].astype(jnp.float32)
                diff = float(quantization_difficulty(x))
                errs = [float(layerwise_error_transformed(
                    x, w, get_transform(k))) for k in KINDS]
                cells = "".join(f"{e:14.4g}" for e in errs)
                best = KINDS[int(np.argmin(errs))]
                print(f"{module + '_' + str(layer):>22s} {diff:10.3f} "
                      f"{cells}  <- {best}")
        print("\n(expect: rotate/smooth_rotate lowest — paper Fig. 4)")


if __name__ == "__main__":
    main()
