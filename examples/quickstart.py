"""Quickstart: the paper's pipeline in 60 lines.

1. Build a small LM, train it briefly;
2. calibrate activation statistics (one forward with taps);
3. fold SmoothRotation transforms + RTN-quantize to W4A4;
4. compare bf16 vs quantized generations.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.core.transforms import TransformPlan
from repro.data import synthetic_batches
from repro.launch.train import make_train_step
from repro.models.api import get_model
from repro.optim import adamw
from repro.serving.fold import collect_calibration, fold_quantize
from repro.launch import compat


def main():
    key = jax.random.PRNGKey(0)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        # -- 1. a small llama-family model, briefly trained ---------------
        cfg = get_config("stablelm-3b").reduced(num_layers=2, d_model=64,
                                                vocab_size=64)
        model = get_model(cfg)
        opt = adamw(3e-3)
        params = model.init(key, cfg)
        state = opt.init(params)
        step = jax.jit(make_train_step(model, cfg, opt))
        for i, batch in enumerate(synthetic_batches(cfg, 8, 32)):
            if i >= 20:
                break
            params, state, m = step(params, state, batch, jnp.asarray(i),
                                    jax.random.fold_in(key, i))
        print(f"trained 20 steps, loss {float(m['loss']):.3f}")

        # -- 2. calibrate (paper §III: absmax per channel per module) -----
        calib = [next(iter(synthetic_batches(cfg, 2, 32, start=s)))
                 for s in range(2)]
        stats = collect_calibration(model, params, cfg, calib)
        print(f"calibrated modules: {sorted(stats)}")

        # -- 3. fold transforms + quantize (paper §IV-E default plan) -----
        policy = QuantPolicy(weight_bits=4, act_bits=4, use_kernels="never")
        qparams = fold_quantize(params, cfg, policy=policy,
                                plan=TransformPlan(), stats=stats)

        # -- 4. compare ----------------------------------------------------
        toks = next(iter(synthetic_batches(cfg, 2, 16)))["tokens"]
        lf = model.forward(params, cfg, toks)
        lq = model.forward(qparams, cfg, toks, policy=policy)
        agree = float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean())
        rel = float(jnp.linalg.norm((lq - lf).astype(jnp.float32))
                    / jnp.linalg.norm(lf.astype(jnp.float32)))
        print(f"W4A4 vs bf16: top-1 agreement {agree:.2f}, "
              f"logit rel err {rel:.3f}")
        w_bits = sum(x.size * (0.5 if x.dtype == jnp.int8 and q else 2)
                     for q, x in [(True, l) for l in jax.tree.leaves(qparams)])
        print("done — see examples/analyze_quantization.py for the "
              "paper's full analysis loop")


if __name__ == "__main__":
    main()
