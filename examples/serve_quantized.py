"""Serving example: continuous-batched engine over a fold+quantized model
with an int8 KV cache — the deployment the paper's technique enables.

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.data import synthetic_batches
from repro.launch.train import make_train_step
from repro.models.api import get_model
from repro.optim import adamw
from repro.serving.engine import Request, ServingEngine
from repro.serving.fold import collect_calibration, fold_quantize
from repro.launch import compat


def main():
    key = jax.random.PRNGKey(0)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        cfg = get_config("qwen1.5-4b").reduced(num_layers=2, d_model=128,
                                               vocab_size=256)
        model = get_model(cfg)
        opt = adamw(3e-3)
        params = model.init(key, cfg)
        state = opt.init(params)
        step = jax.jit(make_train_step(model, cfg, opt))
        for i, batch in enumerate(synthetic_batches(cfg, 8, 64)):
            if i >= 25:
                break
            params, state, _ = step(params, state, batch, jnp.asarray(i),
                                    jax.random.fold_in(key, i))

        # quantize for serving: W4A4 weights, int8 KV
        stats = collect_calibration(
            model, params, cfg,
            [next(iter(synthetic_batches(cfg, 2, 64)))])
        policy = QuantPolicy(weight_bits=4, act_bits=4, kv_cache_bits=8,
                             use_kernels="never")
        qparams = fold_quantize(params, cfg, policy=policy, stats=stats)

        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=(4 + 2 * i,)),
                        max_new_tokens=8,
                        temperature=0.0 if i % 2 == 0 else 0.8)
                for i in range(6)]

        for label, p, pol, kv in (("bf16", params, None, None),
                                  ("W4A4+int8KV", qparams, policy, 8)):
            eng = ServingEngine(model, p, cfg, max_slots=3, max_len=64,
                                policy=pol, kv_bits=kv)
            for r in reqs:
                r.out_tokens, r.done = [], False
                eng.submit(r)
            t0 = time.time()
            done = eng.run(max_ticks=200)
            dt = time.time() - t0
            toks = sum(len(r.out_tokens) for r in done)
            print(f"[{label:12s}] {len(done)} requests, {toks} tokens "
                  f"in {dt:.2f}s ({toks/dt:.1f} tok/s CPU)")
            print(f"   sample: {done[0].out_tokens}")


if __name__ == "__main__":
    main()
