"""End-to-end training driver example: train a ~100M-param dense LM for a
few hundred steps with the production train_step (grad accumulation,
checkpointing, preemption handling), then quantize and compare.

The model (~100M params at d_model=512, L=8, d_ff=2048, V=32k) is the
task-spec "train ~100M model for a few hundred steps" driver.  On CPU
this is slow; --steps and --scale let CI shrink it (defaults are sized
for a few minutes of CPU time; pass --full for the real thing).

Run:  PYTHONPATH=src python examples/train_quantized.py [--full]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.data import synthetic_batches
from repro.launch.train import make_train_step
from repro.models.api import get_model
from repro.optim import adamw, warmup_cosine
from repro.runtime.fault_tolerance import PreemptionHandler, StragglerPolicy
from repro.serving.fold import collect_calibration, fold_quantize
from repro.launch import compat


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params, 300 steps (minutes-hours on CPU)")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_train_quantized")
    args = ap.parse_args(argv)

    if args.full:
        cfg = get_config("stablelm-3b").reduced(
            num_layers=8, d_model=512, num_heads=8, num_kv_heads=8,
            d_ff=2048, vocab_size=32768, head_dim=64)
        steps, batch, seq, microbatches = args.steps or 300, 16, 256, 4
    else:
        cfg = get_config("stablelm-3b").reduced(num_layers=2, d_model=128,
                                                vocab_size=512)
        steps, batch, seq, microbatches = args.steps or 60, 8, 64, 2

    n_params_est = (cfg.vocab_size * cfg.d_model * 2
                    + cfg.num_layers * (4 * cfg.d_model ** 2
                                        + 3 * cfg.d_model * cfg.d_ff))
    print(f"config: {cfg.num_layers}L d={cfg.d_model} ff={cfg.d_ff} "
          f"V={cfg.vocab_size}  (~{n_params_est/1e6:.1f}M params)")

    key = jax.random.PRNGKey(0)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    model = get_model(cfg)
    opt = adamw(warmup_cosine(3e-3, 20, steps))
    preempt = PreemptionHandler()
    straggler = StragglerPolicy()
    ckpt = Checkpointer(args.ckpt, keep=2)

    with compat.set_mesh(mesh):
        params = model.init(key, cfg)
        state = opt.init(params)
        start = 0
        restored = ckpt.restore_latest({"p": params, "s": state})
        if restored:
            (tree, start) = restored
            params, state = tree["p"], tree["s"]
            print(f"resumed from step {start}")
        step_fn = jax.jit(make_train_step(model, cfg, opt,
                                          microbatches=microbatches))
        t_prev = time.time()
        for i, batch_data in enumerate(
                synthetic_batches(cfg, batch, seq, start=start), start=start):
            if i >= steps or preempt.should_stop:
                break
            params, state, m = step_fn(params, state, batch_data,
                                       jnp.asarray(i),
                                       jax.random.fold_in(key, i))
            dt = time.time() - t_prev
            t_prev = time.time()
            if straggler.observe(dt):
                print(f"  [straggler] step {i} took {dt:.2f}s")
            if i % 20 == 0:
                print(f"step {i:4d} loss {float(m['loss']):.4f} "
                      f"({dt:.2f}s/step)")
            if i and i % 50 == 0:
                ckpt.save({"p": params, "s": state}, i)
        ckpt.save({"p": params, "s": state}, i, block=True)
        if preempt.should_stop:
            print("preempted — checkpoint saved, exiting cleanly")
            return

        # quantize the trained model (the paper's serving pipeline)
        calib = [next(iter(synthetic_batches(cfg, 2, seq, start=s)))
                 for s in range(2)]
        stats = collect_calibration(model, params, cfg, calib)
        policy = QuantPolicy(weight_bits=4, act_bits=4, use_kernels="never")
        qparams = fold_quantize(params, cfg, policy=policy, stats=stats)
        toks = calib[0]["tokens"]
        lf = model.forward(params, cfg, toks)
        lq = model.forward(qparams, cfg, toks, policy=policy)
        agree = float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean())
        print(f"final loss {float(m['loss']):.4f}; "
              f"W4A4 top-1 agreement {agree:.2f}")


if __name__ == "__main__":
    main()
