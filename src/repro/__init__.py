"""repro — quantization-first JAX training/serving framework.

Reproduction of "Turning LLM Activations Quantization-Friendly"
(Czako, Kertesz, Szenasi; 2025) as a production-scale system.
See DESIGN.md / EXPERIMENTS.md at the repo root.
"""

__version__ = "1.0.0"
