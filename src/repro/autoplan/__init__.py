"""Difficulty-guided auto-planning: per-layer transform & α search.

Turns the paper's measurement contribution (quantization difficulty
predicts layer-wise error, §IV-B) into the deployment brain: a searched
:class:`LayerwisePlan` that assigns each (layer, module) its own
equivalent transformation and smoothing strength, consumable by
``serving.fold.fold_quantize`` alongside the legacy global plan.

CLI: ``python -m repro.autoplan --arch stablelm-3b --reduced``.
"""

from repro.autoplan.plan import (
    LayerwisePlan,
    ModuleChoice,
    MODULE_ROLES,
    PLANNABLE_MODULES,
)
from repro.autoplan.search import (
    SearchConfig,
    candidate_grid,
    module_weights,
    plan_errors,
    search_plan,
)
from repro.autoplan.telemetry import (
    ModuleTelemetry,
    collect_telemetry,
    summarize,
    write_telemetry,
)

__all__ = [
    "LayerwisePlan", "ModuleChoice", "MODULE_ROLES", "PLANNABLE_MODULES",
    "SearchConfig", "candidate_grid", "module_weights", "plan_errors",
    "search_plan", "ModuleTelemetry", "collect_telemetry", "summarize",
    "write_telemetry",
]
