"""Autoplan CLI: derive and save a per-layer quantization plan.

Usage:
    PYTHONPATH=src python -m repro.autoplan --arch stablelm-3b --reduced
    PYTHONPATH=src python -m repro.autoplan --arch mamba2-780m --reduced \
        --alpha-grid 0.5,0.65,0.8 --top-k 4 --out plan.json

Loads (or randomly initializes) the model, runs the calibration stream
with per-layer sample retention, searches the transform/α grid per
(layer, module), and writes the plan JSON plus a telemetry artifact
under experiments/autoplan/.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.autoplan.plan import LayerwisePlan
from repro.autoplan.search import SearchConfig, plan_errors, search_plan
from repro.autoplan.telemetry import collect_telemetry, summarize, write_telemetry
from repro.checkpoint import Checkpointer
from repro.configs.base import get_config
from repro.core.transforms import TransformPlan
from repro.data import calibration_stream
from repro.launch import compat
from repro.launch.mesh import make_test_mesh
from repro.models.api import get_model
from repro.serving.fold import collect_calibration


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.autoplan")
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--checkpoint", default="",
                    help="checkpoint dir (else random init)")
    ap.add_argument("--out", default="",
                    help="plan JSON path (default experiments/autoplan/"
                         "<arch>_plan.json)")
    ap.add_argument("--telemetry-out", default="",
                    help="telemetry JSON path (default alongside the plan)")
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--keep-samples", type=int, default=128,
                    help="calibration tokens retained per module per layer")
    ap.add_argument("--alpha-grid", default="0.5,0.65,0.7,0.8")
    ap.add_argument("--top-k", type=int, default=3,
                    help="difficulty-prefilter survivors per layer")
    ap.add_argument("--weight-bits", type=int, default=4, choices=[4, 8])
    ap.add_argument("--act-bits", type=int, default=4, choices=[4, 8])
    args = ap.parse_args(argv)

    try:
        alpha_grid = tuple(float(a) for a in args.alpha_grid.split(","))
    except ValueError:
        ap.error(f"--alpha-grid must be comma-separated floats, "
                 f"got {args.alpha_grid!r}")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    search = SearchConfig(
        alpha_grid=alpha_grid, top_k=args.top_k,
        weight_bits=args.weight_bits, act_bits=args.act_bits)

    with compat.set_mesh(make_test_mesh()):
        params = model.init(jax.random.PRNGKey(0), cfg)
        if args.checkpoint:
            restored = Checkpointer(args.checkpoint).restore_latest({"p": params})
            if restored:
                params = restored[0]["p"]
                print(f"restored checkpoint step {restored[1]}")

        t0 = time.time()
        stats = collect_calibration(
            model, params, cfg,
            list(calibration_stream(cfg, n_batches=args.batches,
                                    batch=args.batch, seq=args.seq)),
            keep_samples=args.keep_samples)
        t_calib = time.time() - t0

        t0 = time.time()
        plan, info = search_plan(params, cfg, stats, search=search)
        t_search = time.time() - t0

        out = args.out or os.path.join(
            "experiments", "autoplan", f"{cfg.name}_plan.json")
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        plan.save(out)

        fixed = LayerwisePlan.from_global(TransformPlan(), plan.num_layers,
                                          arch=cfg.name)
        e_auto = plan_errors(plan, params, cfg, stats, search)
        e_fixed = plan_errors(fixed, params, cfg, stats, search)
        tel = collect_telemetry(plan, params, cfg, stats)
        tel_out = args.telemetry_out or os.path.join(
            os.path.dirname(out), f"{cfg.name}_telemetry.json")
        write_telemetry(tel_out, cfg.name, tel, extra={
            "error_auto": {m: v.tolist() for m, v in e_auto.items()},
            "error_fixed": {m: v.tolist() for m, v in e_fixed.items()},
        })

    print(plan.summary())
    print()
    print(summarize(tel))
    a, f = (sum(float(np.sum(v)) for v in e.values())
            for e in (e_auto, e_fixed))
    print(f"\nsummed layerwise error: auto={a:.4g}  fixed §V={f:.4g} "
          f"({'auto wins' if a <= f else 'FIXED WINS — check search'})")
    print(f"calibration {t_calib:.1f}s, search {t_search:.1f}s")
    print(f"plan → {out}\ntelemetry → {tel_out}")


if __name__ == "__main__":
    main()
