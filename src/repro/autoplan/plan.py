"""Per-layer, per-module transform plans (the autoplan subsystem's IR).

The paper's §IV-C/§IV-E finding is that the best equivalent
transformation — and the best smoothing strength α — varies by module
class AND by layer (massive-outlier layers want SmoothRotation, the rest
plain rotation; out_proj ≈ 0.7 / gate_proj ≈ 0.65 α sweet spots).  The
repo's original :class:`~repro.core.transforms.TransformPlan` is one
global per-module-class policy; a :class:`LayerwisePlan` refines it to a
(layer × module) grid while staying losslessly convertible back to the
global plan when uniform.

JSON schema (``LayerwisePlan.to_json``)::

    {
      "schema": 1,
      "arch": "stablelm-3b-reduced",         # informational
      "num_layers": 2,
      "base": {"attn_in": "rotate", ..., "alpha": 0.5},
      "modules": {
        "down_proj": [
          {"kind": "smooth_rotate", "alpha": 0.7},   # layer 0
          {"kind": "rotate", "alpha": 0.5}           # layer 1
        ],
        ...
      }
    }
"""

from __future__ import annotations

import dataclasses
import json
from typing import Mapping, Sequence

from repro.core.transforms import TransformKind, TransformPlan

__all__ = ["ModuleChoice", "LayerwisePlan", "MODULE_ROLES", "PLANNABLE_MODULES"]

SCHEMA_VERSION = 1

# module name → TransformPlan role (mirrors TransformPlan.kind_for)
MODULE_ROLES: dict[str, str] = {
    "q_proj": "attn_in", "k_proj": "attn_in", "v_proj": "attn_in",
    "kv_up": "attn_in",
    "o_proj": "attn_out", "out_proj": "attn_out",
    "gate_proj": "mlp_in", "up_proj": "mlp_in", "in_proj": "mlp_in",
    "down_proj": "mlp_out",
}

# canonical tap/module names the search plans over (one per calibration tap)
PLANNABLE_MODULES = ("k_proj", "o_proj", "gate_proj", "down_proj",
                     "in_proj", "out_proj", "kv_up")


@dataclasses.dataclass(frozen=True)
class ModuleChoice:
    """One (transform kind, α) cell of the plan grid."""

    kind: TransformKind
    alpha: float = 0.5

    def to_json(self) -> dict:
        return {"kind": self.kind, "alpha": self.alpha}

    @classmethod
    def from_json(cls, obj: Mapping) -> "ModuleChoice":
        return cls(kind=obj["kind"], alpha=float(obj.get("alpha", 0.5)))


@dataclasses.dataclass(frozen=True)
class LayerwisePlan:
    """layer × module → (TransformKind, α), with a global fallback.

    ``modules`` maps a module/tap name to a per-layer tuple of choices
    (length ``num_layers``); any module absent from the mapping falls
    back to ``base`` (the repo's global :class:`TransformPlan`), which
    also covers weight stacks whose layer count differs from the planned
    stack (e.g. MoE leading dense layers, hybrid shared blocks).
    """

    num_layers: int
    modules: Mapping[str, tuple[ModuleChoice, ...]] = dataclasses.field(
        default_factory=dict)
    base: TransformPlan = TransformPlan()
    arch: str = ""

    def __post_init__(self):
        frozen = {m: tuple(cs) for m, cs in dict(self.modules).items()}
        for m, cs in frozen.items():
            if len(cs) != self.num_layers:
                raise ValueError(
                    f"module '{m}' has {len(cs)} choices for "
                    f"{self.num_layers} layers")
        object.__setattr__(self, "modules", frozen)

    # -- lookups ------------------------------------------------------------

    def choice_for(self, module: str, layer: int) -> ModuleChoice:
        per_layer = self.modules.get(module)
        if per_layer is None:
            return ModuleChoice(self.base.kind_for(module), self.base.alpha)
        return per_layer[layer]

    def choices_for(self, module: str) -> tuple[ModuleChoice, ...]:
        """Per-layer choices for ``module`` (base-filled when unplanned)."""
        per_layer = self.modules.get(module)
        if per_layer is None:
            c = ModuleChoice(self.base.kind_for(module), self.base.alpha)
            return (c,) * self.num_layers
        return per_layer

    # -- global-plan interop -------------------------------------------------

    def is_uniform(self) -> bool:
        """True when every planned module uses one choice for all layers."""
        return all(len(set(cs)) <= 1 for cs in self.modules.values())

    def to_global(self) -> TransformPlan:
        """Collapse to the legacy global plan (requires uniformity).

        Per-role kinds come from the role's representative module; a
        single α is required across smoothed modules (the global plan has
        one α field).
        """
        if not self.is_uniform():
            raise ValueError("plan is layer-dependent; no global equivalent")
        roles: dict[str, TransformKind] = {}
        alphas = set()
        for module, choices in self.modules.items():
            c = choices[0]
            roles[MODULE_ROLES.get(module, "attn_in")] = c.kind
            if c.kind in ("smooth", "smooth_rotate"):
                alphas.add(round(c.alpha, 6))
        if len(alphas) > 1:
            raise ValueError(f"multiple α values {sorted(alphas)}; the global "
                             "TransformPlan holds a single α")
        return TransformPlan(
            attn_in=roles.get("attn_in", self.base.attn_in),
            attn_out=roles.get("attn_out", self.base.attn_out),
            mlp_in=roles.get("mlp_in", self.base.mlp_in),
            mlp_out=roles.get("mlp_out", self.base.mlp_out),
            alpha=alphas.pop() if alphas else self.base.alpha,
        )

    @classmethod
    def from_global(cls, plan: TransformPlan, num_layers: int,
                    modules: Sequence[str] = PLANNABLE_MODULES,
                    arch: str = "") -> "LayerwisePlan":
        """Broadcast a global plan onto the (layer × module) grid."""
        grid = {m: tuple(ModuleChoice(plan.kind_for(m), plan.alpha)
                         for _ in range(num_layers)) for m in modules}
        return cls(num_layers=num_layers, modules=grid, base=plan, arch=arch)

    # -- (de)serialization ---------------------------------------------------

    def to_json(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "arch": self.arch,
            "num_layers": self.num_layers,
            "base": {
                "attn_in": self.base.attn_in, "attn_out": self.base.attn_out,
                "mlp_in": self.base.mlp_in, "mlp_out": self.base.mlp_out,
                "alpha": self.base.alpha,
            },
            "modules": {m: [c.to_json() for c in cs]
                        for m, cs in sorted(self.modules.items())},
        }

    @classmethod
    def from_json(cls, obj: Mapping) -> "LayerwisePlan":
        if obj.get("schema") != SCHEMA_VERSION:
            raise ValueError(f"unsupported plan schema {obj.get('schema')!r}")
        base = TransformPlan(**obj.get("base", {}))
        modules = {m: tuple(ModuleChoice.from_json(c) for c in cs)
                   for m, cs in obj.get("modules", {}).items()}
        return cls(num_layers=int(obj["num_layers"]), modules=modules,
                   base=base, arch=obj.get("arch", ""))

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "LayerwisePlan":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # -- display -------------------------------------------------------------

    def summary(self) -> str:
        lines = [f"LayerwisePlan(arch={self.arch or '?'}, "
                 f"layers={self.num_layers})"]
        for m, cs in sorted(self.modules.items()):
            cells = " ".join(
                f"{c.kind}" + (f"@{c.alpha:g}" if c.kind in
                               ("smooth", "smooth_rotate") else "")
                for c in cs)
            lines.append(f"  {m:10s} {cells}")
        return "\n".join(lines)
