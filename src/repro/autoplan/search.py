"""Difficulty-guided per-layer transform & α search (autoplan's brain).

For every planned module the search evaluates a candidate grid

    {none, rotate} ∪ {smooth(α), smooth_rotate(α) : α ∈ alpha_grid}

on the calibration activations retained per layer
(:class:`~repro.core.calibration.CalibStats.act_samples`):

1. **Pre-filter** — the paper's quantization-difficulty metric (std of
   channel magnitudes, §II-B) of the *transformed* activations is cheap
   (no matmuls, no fake-quant) and correlates r > 0.97 with layer-wise
   error (§IV-B), so per layer only the ``top_k`` lowest-difficulty
   candidates survive.  The base plan's own choice is force-included so
   the searched plan can never score worse than the fixed §V plan.
2. **Score** — survivors are scored with the exact Eq. (2) layer-wise
   error ``||XW − Q(X̂)Q(Ŵ)||_F²`` against the UNtransformed product,
   vmapped/jitted over the layer axis (one compiled program per
   transform kind, layers batched).

Smoothing scales use the *calibrated* absmax (Eq. 4 offline variant) so
search-time transforms match exactly what ``fold_quantize`` will fold.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.autoplan.plan import (
    LayerwisePlan,
    ModuleChoice,
)
from repro.configs.base import ModelConfig
from repro.core.calibration import CalibStats, smoothing_scales_from_stats
from repro.core.difficulty import (
    layerwise_error_transformed,
    quantization_difficulty,
)
from repro.core.hadamard import apply_hadamard
from repro.core.quantizer import QuantConfig
from repro.core.transforms import TransformPlan

__all__ = ["SearchConfig", "candidate_grid", "module_weights",
           "search_plan", "plan_errors", "transform_xw"]


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Knobs of the per-layer candidate search."""

    alpha_grid: tuple[float, ...] = (0.5, 0.65, 0.7, 0.8)
    top_k: int = 3                 # difficulty-prefilter survivors per layer
    weight_bits: int = 4
    act_bits: int = 4

    @property
    def act_cfg(self) -> QuantConfig:
        return QuantConfig(bits=self.act_bits, granularity="per_token")

    @property
    def w_cfg(self) -> QuantConfig:
        return QuantConfig(bits=self.weight_bits, granularity="per_channel")


def candidate_grid(cfg: SearchConfig) -> tuple[ModuleChoice, ...]:
    out = [ModuleChoice("none"), ModuleChoice("rotate")]
    for a in cfg.alpha_grid:
        out.append(ModuleChoice("smooth", a))
    for a in cfg.alpha_grid:
        out.append(ModuleChoice("smooth_rotate", a))
    return tuple(out)


# ---------------------------------------------------------------------------
# module → representative weight stacks
# ---------------------------------------------------------------------------


def _w(leaf) -> jax.Array:
    return leaf["w"] if isinstance(leaf, dict) else leaf


def _experts_as_linear(w: jax.Array) -> jax.Array:
    """(L, E, c_in, f) expert stack → (L, c_in, E·f): the block input sees
    the union of expert columns (routing picks a subset; scoring on the
    union is the calibration-free upper bound)."""
    L, E, c_in, f = w.shape
    return jnp.swapaxes(w, 1, 2).reshape(L, c_in, E * f)


def module_weights(params, cfg: ModelConfig) -> dict[str, jax.Array]:
    """Stacked (L, c_in, c_out) weight per planned module/tap name.

    Sibling linears sharing one input tap (q/k/v; gate/up) are
    concatenated along c_out so the search scores their joint error —
    the folded transform is shared across them anyway.
    """
    out: dict[str, jax.Array] = {}
    if cfg.family in ("dense", "audio", "vlm"):
        attn, mlp = params["layers"]["attn"], params["layers"]["mlp"]
        out["k_proj"] = jnp.concatenate(
            [_w(attn["wq"]), _w(attn["wk"]), _w(attn["wv"])], axis=-1)
        out["o_proj"] = _w(attn["wo"])
        out["gate_proj"] = jnp.concatenate(
            [_w(mlp["wg"]), _w(mlp["wu"])], axis=-1)
        out["down_proj"] = _w(mlp["wd"])
    elif cfg.family == "moe":
        attn, moe = params["moe_layers"]["attn"], params["moe_layers"]["moe"]
        if cfg.kv_lora_rank:
            out["k_proj"] = jnp.concatenate(
                [_w(attn["wq"]), _w(attn["wdkv"])], axis=-1)
            out["kv_up"] = _w(attn["wukv"])
        else:
            out["k_proj"] = jnp.concatenate(
                [_w(attn["wq"]), _w(attn["wk"]), _w(attn["wv"])], axis=-1)
        out["o_proj"] = _w(attn["wo"])
        gate = [_experts_as_linear(_w(moe["wg"])),
                _experts_as_linear(_w(moe["wu"]))]
        if "shared" in moe:
            gate += [_w(moe["shared"]["wg"]), _w(moe["shared"]["wu"])]
        if "dense" in moe:
            gate += [_w(moe["dense"]["wg"]), _w(moe["dense"]["wu"])]
        out["gate_proj"] = jnp.concatenate(gate, axis=-1)
    elif cfg.family in ("ssm", "hybrid"):
        layers = params["layers"]
        out["in_proj"] = _w(layers["in_proj"])
        out["out_proj"] = _w(layers["out_proj"])
    else:
        raise ValueError(cfg.family)
    return out


# ---------------------------------------------------------------------------
# per-candidate transform + metrics (vmapped over the layer axis)
# ---------------------------------------------------------------------------


def transform_xw(x: jax.Array, w: jax.Array, am: jax.Array,
                 kind: str, alpha: float):
    """(x̂, ŵ) for one layer per the candidate; scales from calibrated
    absmax (the offline Eq. 4 fold_quantize applies)."""
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    if kind in ("smooth", "smooth_rotate"):
        s = smoothing_scales_from_stats(am, w, alpha)
        x = x / s
        w = w * s[:, None]
    if kind in ("rotate", "smooth_rotate"):
        x = apply_hadamard(x)
        w = apply_hadamard(w, axis=0)
    return x, w


def _difficulty_one(x, w, am, *, kind: str, alpha: float):
    xh, _ = transform_xw(x, w, am, kind, alpha)
    return quantization_difficulty(xh)


def _error_one(x, w, am, *, kind: str, alpha: float,
               act_cfg: QuantConfig, w_cfg: QuantConfig):
    return layerwise_error_transformed(
        x, w, lambda xx, ww: transform_xw(xx, ww, am, kind, alpha),
        act_cfg, w_cfg)


# alpha stays TRACED (it only feeds smoothing arithmetic): one compiled
# program per transform kind, reused across the whole α grid


@functools.partial(jax.jit, static_argnames=("kind",))
def _difficulty_layers(x, w, am, alpha, *, kind: str):
    return jax.vmap(lambda xl, wl, al: _difficulty_one(
        xl, wl, al, kind=kind, alpha=alpha))(x, w, am)


@functools.partial(jax.jit, static_argnames=("kind", "act_cfg", "w_cfg"))
def _error_layers(x, w, am, alpha, *, kind: str,
                  act_cfg: QuantConfig, w_cfg: QuantConfig):
    return jax.vmap(lambda xl, wl, al: _error_one(
        xl, wl, al, kind=kind, alpha=alpha,
        act_cfg=act_cfg, w_cfg=w_cfg))(x, w, am)


# ---------------------------------------------------------------------------
# the search proper
# ---------------------------------------------------------------------------


def _module_inputs(stats: Mapping[str, CalibStats], module: str,
                   w: jax.Array):
    """(samples, absmax) for a module, shaped (L, n, C) / (L, C), or None
    when the calibration did not retain samples for it."""
    st = stats.get(module)
    if st is None or st.act_samples is None:
        return None
    x, am = st.act_samples, st.act_absmax
    if x.ndim == 2:                       # unscanned module → 1-layer stack
        x, am = x[None], am[None]
    if x.shape[0] != w.shape[0] or x.shape[-1] != w.shape[-2]:
        return None
    return x, am


def search_plan(params, cfg: ModelConfig, stats: Mapping[str, CalibStats],
                search: SearchConfig = SearchConfig(),
                base: TransformPlan = TransformPlan(),
                ) -> tuple[LayerwisePlan, dict]:
    """Derive a per-layer plan from calibration samples.

    Returns (plan, info); ``info[module]`` holds the full difficulty and
    error matrices (candidates × layers, numpy) for telemetry/reports.
    """
    weights = module_weights(params, cfg)
    # planned layer count = the scanned stack's leading dim (for MoE this
    # is num_layers − first_dense_layers; leading dense layers keep base)
    n_layers = next(iter(weights.values())).shape[0]
    cands = candidate_grid(search)
    modules: dict[str, tuple[ModuleChoice, ...]] = {}
    info: dict[str, dict] = {}

    for module, w in weights.items():
        if w.shape[0] != n_layers:
            continue
        xam = _module_inputs(stats, module, w)
        if xam is None:
            continue                       # no samples → base plan applies
        x, am = xam
        L = w.shape[0]
        usable = list(cands)
        base_choice = ModuleChoice(base.kind_for(module), base.alpha)
        if cfg.family == "moe" and module == "gate_proj":
            # expert stacks never smooth (no per-expert division in the
            # dispatch path — DESIGN.md §5); plan only what the fold can
            # deploy there: per-layer rotation on/off
            usable = [c for c in usable if c.kind in ("none", "rotate")]
            base_choice = ModuleChoice(
                "rotate" if "rotate" in base_choice.kind else "none")
        if base_choice.kind not in ("smooth", "smooth_rotate"):
            base_choice = ModuleChoice(base_choice.kind)  # α is irrelevant
        # force-include the base plan's own choice: the searched plan can
        # then never be worse than the fixed plan under this metric
        if base_choice not in usable:
            usable.append(base_choice)

        diff = np.full((len(usable), L), np.inf, np.float64)
        for ci, c in enumerate(usable):
            diff[ci] = np.asarray(
                _difficulty_layers(x, w, am, c.alpha, kind=c.kind),
                np.float64)

        # difficulty pre-filter: per layer keep top_k candidates (+ base)
        k = min(search.top_k, len(usable))
        order = np.argsort(diff, axis=0)          # (C, L) candidate ranks
        survive = np.zeros_like(diff, bool)
        for l in range(L):
            survive[order[:k, l], l] = True
        survive[usable.index(base_choice), :] = True

        err = np.full((len(usable), L), np.inf, np.float64)
        for ci, c in enumerate(usable):
            layers = np.nonzero(survive[ci])[0]
            if layers.size == 0:
                continue
            idx = jnp.asarray(layers)
            e = _error_layers(x[idx], w[idx], am[idx], c.alpha, kind=c.kind,
                              act_cfg=search.act_cfg, w_cfg=search.w_cfg)
            err[ci, layers] = np.asarray(e, np.float64)

        best = err.argmin(axis=0)
        modules[module] = tuple(usable[best[l]] for l in range(L))
        info[module] = {
            "candidates": [dataclasses.asdict(c) for c in usable],
            "difficulty": diff,
            "error": err,
            "best": best,
        }

    plan = LayerwisePlan(num_layers=n_layers, modules=modules,
                         base=base, arch=cfg.name)
    return plan, info


def plan_errors(plan: LayerwisePlan, params, cfg: ModelConfig,
                stats: Mapping[str, CalibStats],
                search: SearchConfig = SearchConfig()) -> dict[str, np.ndarray]:
    """Eq. (2) error per (module, layer) under a given plan — the shared
    yardstick autoplan_quality uses to compare auto vs fixed plans."""
    weights = module_weights(params, cfg)
    out: dict[str, np.ndarray] = {}
    for module, w in weights.items():
        xam = _module_inputs(stats, module, w)
        if xam is None:
            continue
        x, am = xam
        L = w.shape[0]
        errs = np.zeros(L, np.float64)
        choices = [plan.choice_for(module, l) for l in range(L)]
        for choice in set(choices):
            layers = np.asarray([l for l in range(L) if choices[l] == choice])
            idx = jnp.asarray(layers)
            e = _error_layers(x[idx], w[idx], am[idx], choice.alpha,
                              kind=choice.kind, act_cfg=search.act_cfg,
                              w_cfg=search.w_cfg)
            errs[layers] = np.asarray(e, np.float64)
        out[module] = errs
    return out
