"""Pre/post-transform activation profiles per module (autoplan telemetry).

For every planned module and layer, records the paper's three flatness
lenses on the calibration samples — quantization difficulty (std of
channel magnitudes, §II-B), excess kurtosis (FlatQuant's lens), and a
flatness ratio (max/median of the sorted channel-magnitude curve) —
before and after the plan's chosen transform.  The JSON artifacts land
in ``experiments/autoplan/`` and feed ``benchmarks/report.py``.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.autoplan.plan import LayerwisePlan
from repro.autoplan.search import _module_inputs, module_weights, transform_xw
from repro.configs.base import ModelConfig
from repro.core.difficulty import channel_magnitudes, kurtosis

__all__ = ["ModuleTelemetry", "collect_telemetry", "telemetry_to_json",
           "write_telemetry", "summarize"]


@dataclasses.dataclass
class ModuleTelemetry:
    """Per-layer activation profiles for one module, pre/post transform."""

    module: str
    kinds: list[str]                 # chosen transform per layer
    alphas: list[float]
    difficulty_pre: list[float]
    difficulty_post: list[float]
    kurtosis_pre: list[float]
    kurtosis_post: list[float]
    flatness_pre: list[float]        # max/median channel magnitude
    flatness_post: list[float]


def _profiles(x: jax.Array):
    """(difficulty, kurtosis, flatness) of one layer's (n, C) samples."""
    mags = channel_magnitudes(x)
    diff = jnp.std(mags)                 # quantization_difficulty, reusing mags
    flat = jnp.max(mags) / jnp.maximum(jnp.median(mags), 1e-12)
    return diff, kurtosis(x), flat


def collect_telemetry(plan: LayerwisePlan, params, cfg: ModelConfig,
                      stats: Mapping) -> dict[str, ModuleTelemetry]:
    out: dict[str, ModuleTelemetry] = {}
    for module, w in module_weights(params, cfg).items():
        xam = _module_inputs(stats, module, w)
        if xam is None:
            continue
        x, am = xam
        L = x.shape[0]
        tel = ModuleTelemetry(module=module, kinds=[], alphas=[],
                              difficulty_pre=[], difficulty_post=[],
                              kurtosis_pre=[], kurtosis_post=[],
                              flatness_pre=[], flatness_post=[])
        for l in range(L):
            choice = plan.choice_for(module, l)
            xh, _ = transform_xw(x[l], w[l], am[l], choice.kind, choice.alpha)
            d0, k0, f0 = _profiles(x[l])
            d1, k1, f1 = _profiles(xh)
            tel.kinds.append(choice.kind)
            tel.alphas.append(float(choice.alpha))
            tel.difficulty_pre.append(float(d0))
            tel.difficulty_post.append(float(d1))
            tel.kurtosis_pre.append(float(k0))
            tel.kurtosis_post.append(float(k1))
            tel.flatness_pre.append(float(f0))
            tel.flatness_post.append(float(f1))
        out[module] = tel
    return out


def telemetry_to_json(arch: str, tel: Mapping[str, ModuleTelemetry],
                      extra: dict | None = None) -> dict:
    obj = {"arch": arch,
           "modules": {m: dataclasses.asdict(t) for m, t in tel.items()}}
    if extra:
        obj.update(extra)
    return obj


def write_telemetry(path: str, arch: str, tel: Mapping[str, ModuleTelemetry],
                    extra: dict | None = None) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(telemetry_to_json(arch, tel, extra), f, indent=2,
                  sort_keys=True)
    return path


def summarize(tel: Mapping[str, ModuleTelemetry]) -> str:
    """Human-readable mean difficulty reduction per module."""
    lines = ["module      mean difficulty pre → post   (reduction)"]
    for m, t in sorted(tel.items()):
        pre = float(np.mean(t.difficulty_pre))
        post = float(np.mean(t.difficulty_post))
        red = 0.0 if pre == 0 else 100.0 * (1 - post / max(pre, 1e-12))
        lines.append(f"{m:11s} {pre:12.4f} → {post:9.4f}   ({red:+.1f}%)")
    return "\n".join(lines)
