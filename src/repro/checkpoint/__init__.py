"""Atomic, keep-K, elastic checkpointing."""
from repro.checkpoint.checkpointer import Checkpointer, save_pytree, restore_pytree
