"""Fault-tolerant checkpointing: atomic sharded save/restore, keep-K GC,
latest-resume, and ELASTIC re-sharding (checkpoints are mesh-agnostic).

Format: one directory per step, one .npy per pytree leaf (path-encoded
filenames) + a manifest.  Writes go to ``<dir>.tmp`` then a single
atomic rename — a preempted job can never leave a half-written
checkpoint that restore would pick up.  Restore lays global arrays out
under WHATEVER mesh/sharding the passed template uses, so a job restarted
on a different topology (elastic scaling) reshards transparently.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import ml_dtypes  # registers bfloat16 etc. with numpy
import numpy as np

__all__ = ["Checkpointer", "save_pytree", "restore_pytree"]

_SEP = "__"


def _flatten(tree) -> dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        out[key] = leaf
    return out


def save_pytree(tree, directory: str):
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        # ml_dtypes (bfloat16, ...) round-trip poorly through np.save —
        # store a raw byte view; the manifest carries shape + dtype name
        np.save(os.path.join(tmp, key + ".npy"),
                np.ascontiguousarray(arr).view(np.uint8))
        manifest[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)  # atomic publish


def restore_pytree(template, directory: str):
    """Restore into the TEMPLATE's structure & shardings (elastic)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = _flatten(template)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    restored_flat = {}
    for key in flat_t:
        raw = np.load(os.path.join(directory, key + ".npy"))
        meta = manifest[key]
        arr = raw.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
        restored_flat[key] = arr
    out_leaves = []
    for (path, leaf) in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        arr = restored_flat[key]
        target = leaf
        if hasattr(target, "sharding") and hasattr(target, "dtype"):
            arr = jax.device_put(arr.astype(target.dtype), target.sharding)
        out_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


class Checkpointer:
    """Keep-K checkpoint manager with async save and latest-resume."""

    def __init__(self, root: str, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None
        os.makedirs(root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def save(self, tree, step: int, *, block: bool = False):
        """Device-get happens synchronously (consistent snapshot); disk IO
        can run on a background thread (async_save)."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            with self._lock:
                save_pytree(host_tree, self._step_dir(step))
                self._gc()

        if self.async_save and not block:
            self.wait()
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def restore_latest(self, template):
        self.wait()
        steps = self.steps()
        if not steps:
            return None
        step = steps[-1]
        return restore_pytree(template, self._step_dir(step)), step

    def restore(self, template, step: int):
        self.wait()
        return restore_pytree(template, self._step_dir(step))
