"""Architecture configs (one module per assigned arch) + shape cells."""
from repro.configs.base import (
    ModelConfig,
    ShapeCell,
    SHAPES,
    get_config,
    list_archs,
    input_specs,
)
