"""Snowflake Arctic-480B base [hf:Snowflake/snowflake-arctic-base; hf] —
128-expert top-2 MoE with a parallel dense residual FFN per layer."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, experts_per_tok=2, dense_residual=True,
    capacity_factor=2.0,
)
