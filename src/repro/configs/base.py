"""Model configuration schema, input-shape cells, and the arch registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

import jax
import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "get_config", "list_archs",
           "input_specs"]

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (hashable → usable as jit static)."""

    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 → d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # expert FFN width (0 → d_ff)
    dense_residual: bool = False      # Arctic: dense FFN in parallel w/ MoE
    first_dense_layers: int = 0       # DeepSeek: leading dense layers
    capacity_factor: float = 2.0

    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0             # 0 → standard GQA attention
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (Zamba2) ---
    attn_every: int = 0               # shared attn block after every k SSM blocks

    # --- misc ---
    qkv_bias: bool = False            # Qwen1.5
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    embeds_input: bool = False        # audio/vlm: frontend supplies embeddings
    attn_window: int = 0              # 0 = full causal; >0 = sliding window
    remat: bool = True                # activation checkpointing on layer scan
    dtype: str = "bfloat16"

    # --- performance options (§Perf hillclimb; defaults = paper-faithful
    #     baseline so the before/after is measurable) ---
    attn_impl: str = "naive"          # "naive" | "flash" (online-softmax)
    attn_bf16_io: bool = False        # cast probs→bf16 before P·V (halves
    #                                   backward collective bytes)
    seq_parallel: bool = False        # shard sequence over 'model' between
    #                                   blocks (Korthikanti-style SP)
    remat_policy: str = "full"        # "full" | "dots_no_batch" (save
    #                                   linear outputs, recompute attention
    #                                   internals — the flash-bwd contract)
    decode_flash: bool = False        # shard_map'd distributed online-
    #                                   softmax decode over sequence-
    #                                   sharded KV (§Perf cell C it2)

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Natively sub-quadratic in context (SSM state decode)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        base = dict(
            num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
            vocab_size=256, head_dim=16,
        )
        if self.num_kv_heads == self.num_heads:
            base["num_kv_heads"] = 4
        if self.num_experts:
            base.update(num_experts=4, experts_per_tok=min(2, self.experts_per_tok),
                        moe_d_ff=64, capacity_factor=2.0)
        if self.kv_lora_rank:
            base.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                        v_head_dim=16, head_dim=0)
        if self.ssm_state:
            base.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
        if self.attn_every:
            base.update(attn_every=2, num_layers=4)
        base.update(
            num_shared_experts=min(self.num_shared_experts, 1),
            first_dense_layers=min(self.first_dense_layers, 1),
            dense_residual=self.dense_residual,
            qkv_bias=self.qkv_bias,
            embeds_input=self.embeds_input,
            family=self.family,
            name=self.name + "-reduced",
            remat=False,
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the dry-run matrix."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

ARCHS = [
    "musicgen_large", "mamba2_780m", "arctic_480b", "deepseek_v2_lite_16b",
    "llama3_405b", "minitron_8b", "stablelm_3b", "qwen15_4b",
    "internvl2_26b", "zamba2_12b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({  # display names (CONFIG.name) → module names
    "qwen1.5-4b": "qwen15_4b",
    "zamba2-1.2b": "zamba2_12b",
    "musicgen-large": "musicgen_large",
    "mamba2-780m": "mamba2_780m",
    "arctic-480b": "arctic_480b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "llama3-405b": "llama3_405b",
    "minitron-8b": "minitron_8b",
    "stablelm-3b": "stablelm_3b",
    "internvl2-26b": "internvl2_26b",
})


def list_archs() -> list[str]:
    return list(ARCHS)


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIAS.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def input_specs(cfg: ModelConfig, cell: ShapeCell, *,
                microbatch: int = 0) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    train: token ids + labels (or frontend embeddings for audio/vlm,
    which are stubs per the task spec).  prefill: token ids.  decode:
    one new token per sequence + a KV/state cache created by
    ``models.api.make_cache`` (the cache specs come from there).
    """
    b, s = cell.global_batch, cell.seq_len
    ids = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cell.kind == "train":
        if cfg.family in ("audio", "vlm") and cfg.embeds_input:
            return {
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "labels": ids,
            }
        return {"tokens": ids, "labels": ids}
    if cell.kind == "prefill":
        return {"tokens": ids}
    # decode: one token per sequence; cache built separately
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
