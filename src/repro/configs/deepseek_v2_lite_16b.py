"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf] — MLA (kv_lora=512) +
64-routed/top-6 + 2 shared experts; first layer dense.

Note: the assignment sheet lists both "64e top-6" and "160 routed";
DeepSeek-V2-Lite itself has 64 routed experts — we follow the 64e spec
(DESIGN.md §5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    num_experts=64, experts_per_tok=6, num_shared_experts=2,
    first_dense_layers=1,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    capacity_factor=2.0,
)
