"""InternVL2-26B [arXiv:2404.16821; hf] — InternLM2-20B backbone;
InternViT frontend is a stub supplying patch embeddings (task spec)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553, embeds_input=True,
)
