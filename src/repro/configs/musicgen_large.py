"""MusicGen-large backbone [arXiv:2306.05284; hf] — decoder-only over
EnCodec tokens; frontend stub supplies frame embeddings (task spec)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, embeds_input=True,
)
