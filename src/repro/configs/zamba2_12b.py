"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone + ONE shared
attention+FFN block invoked every 6 SSM blocks (weight sharing)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    attn_every=6,
)
