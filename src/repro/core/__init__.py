"""Core library: the paper's contribution as composable JAX modules.

- quantizer   : symmetric RTN quantization (Eq. 1), int4 packing
- hadamard    : Hadamard construction + fast Kronecker-structured apply
- transforms  : smoothing / rotation / SmoothRotation (Eqs. 3-4, SIV-E)
- difficulty  : quantization-difficulty metric + layer-wise error (Eq. 2)
- outliers    : synthetic activations with systematic/massive outliers (Eq. 6)
- calibration : offline absmax collection -> smoothing scales
- qlinear     : serving-time quantized linear (W4A4/W8A8)
"""

from repro.core.quantizer import (
    QuantConfig,
    quantize,
    dequantize,
    fake_quantize,
    pack_int4,
    unpack_int4,
    qmax,
)
from repro.core.hadamard import (
    hadamard_matrix,
    hadamard_factorization,
    apply_hadamard,
    plan_hadamard,
)
from repro.core.transforms import (
    TransformPlan,
    smoothing_scales,
    smooth,
    rotate,
    smooth_rotate,
    get_transform,
    TRANSFORMS,
)
from repro.core.difficulty import (
    channel_magnitudes,
    quantization_difficulty,
    flatness_profile,
    kurtosis,
    layerwise_error,
    layerwise_error_transformed,
)
from repro.core.outliers import (
    OutlierSpec,
    synth_activations,
    massive_outlier_token,
    synth_weight,
)
from repro.core.calibration import (
    CalibStats,
    update_stats,
    collect_stats,
    smoothing_scales_from_stats,
)
from repro.core.qlinear import QuantizedWeight, quantize_weight, qlinear, QuantPolicy
