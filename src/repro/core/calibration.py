"""Offline calibration for the serving quantization pipeline.

Collects per-channel absolute maxima of module *input activations* over a
calibration stream (paper §III-C computes them online from the current
sample; production folds them offline) and derives SmoothQuant scales
(Eq. (4)).  Models expose a ``with_taps`` forward mode returning the
inputs of every quantizable linear, stacked over scanned layers, so one
forward pass calibrates all modules of all layers at once.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping

import jax
import jax.numpy as jnp

__all__ = ["CalibStats", "update_stats", "collect_stats", "smoothing_scales_from_stats"]


@dataclasses.dataclass
class CalibStats:
    """Running per-channel absmax for one module family.

    ``act_absmax`` has shape (layers, c_in) for scanned stacks or (c_in,)
    for unscanned modules; maxima accumulate across calibration batches.
    """

    act_absmax: jax.Array
    n_batches: int = 0

    def merge(self, new_absmax: jax.Array) -> "CalibStats":
        return CalibStats(
            act_absmax=jnp.maximum(self.act_absmax, new_absmax),
            n_batches=self.n_batches + 1,
        )


def _tap_absmax(tap: jax.Array) -> jax.Array:
    """Reduce a tap of shape (..., tokens, c_in) [leading layer dim kept]
    to per-channel absmax.  Taps from scanned layers are (L, B, T, C) →
    (L, C); unscanned are (B, T, C) → (C,)."""
    x = jnp.abs(tap.astype(jnp.float32))
    reduce_axes = tuple(range(x.ndim - 1)) if x.ndim <= 3 else tuple(range(1, x.ndim - 1))
    return jnp.max(x, axis=reduce_axes)


def update_stats(stats: dict[str, CalibStats] | None,
                 taps: Mapping[str, jax.Array]) -> dict[str, CalibStats]:
    """Fold one batch of taps into running stats (creates on first call)."""
    out = dict(stats or {})
    for name, tap in taps.items():
        am = _tap_absmax(tap)
        if name in out:
            out[name] = out[name].merge(am)
        else:
            out[name] = CalibStats(act_absmax=am, n_batches=1)
    return out


def collect_stats(tap_fn: Callable[[dict], Mapping[str, jax.Array]],
                  batches: Iterable[dict]) -> dict[str, CalibStats]:
    """Run ``tap_fn`` (params-closed forward returning taps) over a
    calibration stream and accumulate per-module absmax stats."""
    stats: dict[str, CalibStats] | None = None
    for batch in batches:
        stats = update_stats(stats, tap_fn(batch))
    if stats is None:
        raise ValueError("empty calibration stream")
    return stats


def smoothing_scales_from_stats(act_absmax: jax.Array, w: jax.Array,
                                alpha: float = 0.5, eps: float = 1e-8) -> jax.Array:
    """Eq. (4) from calibrated absmax. ``w`` is (c_in, c_out) or stacked
    (L, c_in, c_out); ``act_absmax`` (c_in,) or (L, c_in)."""
    aw = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1)
    s = jnp.power(jnp.maximum(act_absmax, eps), alpha) / jnp.power(
        jnp.maximum(aw, eps), 1.0 - alpha
    )
    return jnp.maximum(s, eps)
