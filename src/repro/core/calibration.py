"""Offline calibration for the serving quantization pipeline.

Collects per-channel absolute maxima of module *input activations* over a
calibration stream (paper §III-C computes them online from the current
sample; production folds them offline) and derives SmoothQuant scales
(Eq. (4)).  Models expose a ``with_taps`` forward mode returning the
inputs of every quantizable linear, stacked over scanned layers, so one
forward pass calibrates all modules of all layers at once.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping

import jax
import jax.numpy as jnp

__all__ = ["CalibStats", "update_stats", "collect_stats", "smoothing_scales_from_stats"]


@dataclasses.dataclass
class CalibStats:
    """Running per-channel absmax for one module family.

    ``act_absmax`` has shape (layers, c_in) for scanned stacks or (c_in,)
    for unscanned modules; maxima accumulate across calibration batches.
    ``act_samples`` (optional, autoplan search) retains a capped number
    of raw activation tokens per layer — (layers, n, c_in) / (n, c_in) —
    so per-layer transform candidates can be scored on Eq. (2) error.
    """

    act_absmax: jax.Array
    n_batches: int = 0
    act_samples: jax.Array | None = None

    def merge(self, new_absmax: jax.Array,
              new_samples: jax.Array | None = None,
              keep_samples: int = 0) -> "CalibStats":
        samples = self.act_samples
        if keep_samples and new_samples is not None:
            if samples is None:
                samples = new_samples
            else:
                samples = jnp.concatenate([samples, new_samples], axis=-2)
            total = samples.shape[-2]
            if total > keep_samples:
                # evenly thin the concatenation so EVERY batch keeps
                # contributing (a prefix cut would freeze the retained
                # set once the first batch fills the cap)
                idx = jnp.round(jnp.linspace(0, total - 1,
                                             keep_samples)).astype(jnp.int32)
                samples = samples[..., idx, :]
        return CalibStats(
            act_absmax=jnp.maximum(self.act_absmax, new_absmax),
            n_batches=self.n_batches + 1,
            act_samples=samples,
        )


def _tap_absmax(tap: jax.Array) -> jax.Array:
    """Reduce a tap of shape (..., tokens, c_in) [leading layer dim kept]
    to per-channel absmax.  Taps from scanned layers are (L, B, T, C) →
    (L, C); unscanned are (B, T, C) → (C,)."""
    x = jnp.abs(tap.astype(jnp.float32))
    reduce_axes = (tuple(range(x.ndim - 1)) if x.ndim <= 3
                   else tuple(range(1, x.ndim - 1)))
    return jnp.max(x, axis=reduce_axes)


def _tap_samples(tap: jax.Array, n: int) -> jax.Array:
    """Flatten a tap to (layers?, tokens, c_in) and keep ≤ n evenly-spaced
    tokens spanning the WHOLE range (not a prefix), so every position in
    the batch contributes — including late-sequence massive-outlier
    tokens."""
    x = tap.astype(jnp.float32)
    if x.ndim <= 3:                        # (B, T, C) or (T, C)
        x = x.reshape(-1, x.shape[-1])
    else:                                  # (L, B, T, C)
        x = x.reshape(x.shape[0], -1, x.shape[-1])
    total = x.shape[-2]
    if total <= n:
        return x
    idx = jnp.round(jnp.linspace(0, total - 1, n)).astype(jnp.int32)
    return x[..., idx, :]


def update_stats(stats: dict[str, CalibStats] | None,
                 taps: Mapping[str, jax.Array],
                 keep_samples: int = 0) -> dict[str, CalibStats]:
    """Fold one batch of taps into running stats (creates on first call).

    ``keep_samples > 0`` additionally retains up to that many activation
    tokens per module (per layer) for the autoplan error search.
    """
    out = dict(stats or {})
    for name, tap in taps.items():
        am = _tap_absmax(tap)
        sm = _tap_samples(tap, keep_samples) if keep_samples else None
        if name in out:
            out[name] = out[name].merge(am, sm, keep_samples)
        else:
            out[name] = CalibStats(act_absmax=am, n_batches=1, act_samples=sm)
    return out


def collect_stats(tap_fn: Callable[[dict], Mapping[str, jax.Array]],
                  batches: Iterable[dict],
                  keep_samples: int = 0) -> dict[str, CalibStats]:
    """Run ``tap_fn`` (params-closed forward returning taps) over a
    calibration stream and accumulate per-module absmax stats."""
    stats: dict[str, CalibStats] | None = None
    for batch in batches:
        stats = update_stats(stats, tap_fn(batch), keep_samples)
    if stats is None:
        raise ValueError("empty calibration stream")
    return stats


def smoothing_scales_from_stats(act_absmax: jax.Array, w: jax.Array,
                                alpha: float = 0.5, eps: float = 1e-8) -> jax.Array:
    """Eq. (4) from calibrated absmax. ``w`` is (c_in, c_out) or stacked
    (L, c_in, c_out); ``act_absmax`` (c_in,) or (L, c_in)."""
    aw = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1)
    s = jnp.power(jnp.maximum(act_absmax, eps), alpha) / jnp.power(
        jnp.maximum(aw, eps), 1.0 - alpha
    )
    return jnp.maximum(s, eps)
