"""Quantization-difficulty metric and layer-wise error (paper §II-B, §IV-B).

The paper's primary metric contribution: *quantization difficulty* of a
tensor = the standard deviation of its channel magnitudes (per-channel
Frobenius norms), building on FlatQuant's sorted-channel-magnitude
visualization.  Layer-wise quantization error (Eq. (2)) is
``||XW − Q(X)Q(W)||_F²``.  §IV-B reports correlation > 0.97 between the
error and the *square* of activation difficulty (variance of channel
magnitudes) once massive-outlier layers are excluded.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.quantizer import QuantConfig, fake_quantize

__all__ = [
    "channel_magnitudes",
    "quantization_difficulty",
    "flatness_profile",
    "kurtosis",
    "layerwise_error",
    "layerwise_error_transformed",
]


def channel_magnitudes(x: jax.Array, axis: int = -1) -> jax.Array:
    """Per-channel Frobenius norms along ``axis`` (rest flattened).

    Channels are the c_in dimension: the LAST axis for activations
    (tokens × c_in) and the FIRST axis for weights (c_in × c_out) —
    the axis equivalent transformations act on (paper §II-C).
    """
    if axis not in (-1, x.ndim - 1):
        x = jnp.moveaxis(x, axis, -1)
    x2 = x.reshape(-1, x.shape[-1])
    return jnp.sqrt(jnp.sum(jnp.square(x2.astype(jnp.float32)), axis=0))


@partial(jax.jit, static_argnames=("axis",))
def quantization_difficulty(x: jax.Array, axis: int = -1) -> jax.Array:
    """std of channel magnitudes — the paper's difficulty metric."""
    return jnp.std(channel_magnitudes(x, axis))


@partial(jax.jit, static_argnames=("axis",))
def flatness_profile(x: jax.Array, axis: int = -1) -> jax.Array:
    """Sorted (descending) channel magnitudes — FlatQuant-style curve."""
    return jnp.sort(channel_magnitudes(x, axis))[::-1]


@jax.jit
def kurtosis(x: jax.Array) -> jax.Array:
    """Excess kurtosis of the flattened tensor (FlatQuant's flatness lens)."""
    v = x.reshape(-1).astype(jnp.float32)
    mu = jnp.mean(v)
    c = v - mu
    m2 = jnp.mean(c**2)
    m4 = jnp.mean(c**4)
    return m4 / jnp.maximum(m2**2, 1e-20) - 3.0


@partial(jax.jit, static_argnames=("act_cfg", "w_cfg"))
def layerwise_error(
    x: jax.Array,
    w: jax.Array,
    act_cfg: QuantConfig = QuantConfig(bits=4, granularity="per_token"),
    w_cfg: QuantConfig = QuantConfig(bits=4, granularity="per_channel"),
) -> jax.Array:
    """Eq. (2): ||XW − Q(X)Q(W)||_F² with RTN fake-quant, no clipping."""
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    yq = fake_quantize(x.astype(jnp.float32), act_cfg) @ fake_quantize(
        w.astype(jnp.float32), w_cfg
    )
    return jnp.sum(jnp.square(y - yq))


def layerwise_error_transformed(
    x: jax.Array,
    w: jax.Array,
    transform,
    act_cfg: QuantConfig = QuantConfig(bits=4, granularity="per_token"),
    w_cfg: QuantConfig = QuantConfig(bits=4, granularity="per_channel"),
) -> jax.Array:
    """Eq. (2) evaluated on (X̂, Ŵ) = transform(X, W).

    ``transform`` maps (x, w) → (x̂, ŵ) with x̂ŵ ≡ xw (an equivalent
    transformation, Eq. (3)); the error is measured against the ORIGINAL
    product XW, so transforms are compared on true output fidelity.
    """
    xh, wh = transform(x, w)
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    yq = fake_quantize(xh.astype(jnp.float32), act_cfg) @ fake_quantize(
        wh.astype(jnp.float32), w_cfg
    )
    return jnp.sum(jnp.square(y - yq))
