"""Hadamard matrix construction and fast structured application.

The paper (§II-D) builds rotation matrices R = H/sqrt(d) from Hadamard
matrices via Sylvester construction for d = 2^p and Kronecker products
with known small Hadamard matrices otherwise (QuIP#-style, e.g.
H_11008 = H_64 ⊗ H_172).

TPU adaptation (DESIGN.md §3): every d we need factors as a Kronecker
product of (a) powers of two (Sylvester) and (b) Paley-I matrices of
order q+1 for primes q ≡ 3 (mod 4). ``X @ (A ⊗ B)`` is evaluated as two
small dense matmuls over a reshaped X — O(d·(a+b)) work, MXU-friendly —
instead of materializing the d×d rotation. A block-diagonal fallback
(grouped Hadamard over the largest power-of-two divisor) covers any d
outside the factorizable set and is reported as such.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HadamardPlan",
    "sylvester",
    "paley",
    "hadamard_matrix",
    "hadamard_factorization",
    "plan_hadamard",
    "apply_hadamard",
    "random_sign_flip",
]


# ---------------------------------------------------------------------------
# Constructions
# ---------------------------------------------------------------------------


def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    if n < 4:
        return True
    if n % 2 == 0:
        return False
    f = 3
    while f * f <= n:
        if n % f == 0:
            return False
        f += 2
    return True


@functools.lru_cache(maxsize=None)
def sylvester(d: int) -> np.ndarray:
    """Sylvester Hadamard matrix of order d = 2^p (entries ±1)."""
    if d & (d - 1) or d < 1:
        raise ValueError(f"Sylvester construction needs a power of two, got {d}")
    h = np.ones((1, 1), dtype=np.int8)
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    return h


@functools.lru_cache(maxsize=None)
def paley(q: int) -> np.ndarray:
    """Paley-I Hadamard matrix of order q+1 for prime q ≡ 3 (mod 4)."""
    if not _is_prime(q) or q % 4 != 3:
        raise ValueError(f"Paley-I needs a prime q ≡ 3 (mod 4), got {q}")
    # Quadratic residue character chi(x) over GF(q).
    residues = np.zeros(q, dtype=np.int8)
    residues[[(i * i) % q for i in range(1, q)]] = 1
    chi = np.where(residues > 0, 1, -1).astype(np.int8)
    chi[0] = 0
    # Jacobsthal matrix Q[i, j] = chi(i - j).
    idx = (np.arange(q)[:, None] - np.arange(q)[None, :]) % q
    Q = chi[idx]
    h = np.empty((q + 1, q + 1), dtype=np.int8)
    h[0, :] = 1
    h[1:, 0] = -1
    # H = I + S with S = [[0, 1], [-1, Q]] skew (Qᵀ = −Q for q ≡ 3 mod 4),
    # giving H Hᵀ = (q+1) I.
    h[1:, 1:] = Q + np.eye(q, dtype=np.int8)
    return h


@functools.lru_cache(maxsize=None)
def hadamard_factorization(d: int) -> tuple[tuple[str, int], ...]:
    """Factor d into Hadamard-constructible Kronecker factors.

    Returns a tuple of ("sylvester"|"paley"|"block", size) pairs whose
    sizes multiply to d.  Strategy: strip the odd part m of d; if m == 1
    it is pure Sylvester; otherwise search for a prime q ≡ 3 (mod 4) with
    q + 1 = m · 2^k dividing d (QuIP#-style Kronecker with one Paley
    factor), recursing on composite odd parts (e.g. 27 → Paley 108, or
    9 → two H_12 factors).  Falls back to ("block", 2^a) meaning a
    block-diagonal (grouped) Hadamard of the largest power-of-two divisor.
    """
    if d < 1:
        raise ValueError(f"need d >= 1, got {d}")
    a = (d & -d).bit_length() - 1  # exponent of 2
    m = d >> a
    if m == 1:
        return (("sylvester", d),)
    # Try a single Paley factor q+1 = m * 2^k for k <= a.
    for k in range(a + 1):
        size = m << k
        if _is_prime(size - 1) and (size - 1) % 4 == 3:
            factors: list[tuple[str, int]] = [("paley", size)]
            rest = d // size
            if rest > 1:
                factors.append(("sylvester", rest))
            return tuple(factors)
    # Try splitting the odd part into two composite halves (e.g. 9 = 3·3
    # → H_12 ⊗ H_12 when enough 2s are available).
    for m1 in range(3, int(math.isqrt(m)) + 1, 2):
        if m % m1 == 0:
            m2 = m // m1
            for k1 in range(a + 1):
                s1 = m1 << k1
                if not (_is_prime(s1 - 1) and (s1 - 1) % 4 == 3):
                    continue
                for k2 in range(a - k1 + 1):
                    s2 = m2 << k2
                    if _is_prime(s2 - 1) and (s2 - 1) % 4 == 3:
                        factors = [("paley", s1), ("paley", s2)]
                        rest = d // (s1 * s2)
                        if rest > 1:
                            factors.append(("sylvester", rest))
                        return tuple(factors)
    # Fallback: grouped Hadamard over the power-of-two part.
    if a == 0:
        raise ValueError(f"no Hadamard construction available for d={d}")
    return (("block", 1 << a),)


def _factor_matrix(kind: str, size: int) -> np.ndarray:
    if kind == "sylvester":
        return sylvester(size)
    if kind == "paley":
        return paley(size - 1)
    if kind == "block":
        return sylvester(size)
    raise ValueError(kind)


def hadamard_matrix(d: int, dtype=np.float32) -> np.ndarray:
    """Dense orthonormal rotation R = H/sqrt(d) of size d×d.

    For a ("block", b) factorization this returns the block-diagonal
    orthonormal matrix diag(H_b/sqrt(b), ...) — still orthogonal, spreads
    outliers within groups of b (documented fallback, DESIGN.md §3).
    """
    factors = hadamard_factorization(d)
    if factors[0][0] == "block":
        b = factors[0][1]
        blk = sylvester(b).astype(np.float64) / math.sqrt(b)
        out = np.zeros((d, d), dtype=np.float64)
        for i in range(d // b):
            out[i * b : (i + 1) * b, i * b : (i + 1) * b] = blk
        return out.astype(dtype)
    h = np.ones((1, 1), dtype=np.float64)
    for kind, size in factors:
        h = np.kron(h, _factor_matrix(kind, size).astype(np.float64))
    return (h / math.sqrt(d)).astype(dtype)


def random_sign_flip(d: int, key: jax.Array) -> jax.Array:
    """Random ±1 diagonal (composes with H for randomized rotations)."""
    return jax.random.rademacher(key, (d,), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Fast structured application
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HadamardPlan:
    """Plan for applying a d×d orthonormal Hadamard rotation fast.

    ``factors`` are the Kronecker factors (left-to-right); ``block`` is
    set when the factorization fell back to a grouped transform.
    """

    d: int
    factors: tuple[tuple[str, int], ...]
    block: bool

    @property
    def factor_sizes(self) -> tuple[int, ...]:
        return tuple(size for _, size in self.factors)


_MAX_FAST_FACTOR = 512  # largest dense factor materialized by the fast path


@functools.lru_cache(maxsize=None)
def plan_hadamard(d: int) -> HadamardPlan:
    """Factorization with Sylvester factors split to ≤ 512 (MXU-sized
    GEMMs, bounded VMEM) — H_{2^{a+b}} = H_{2^a} ⊗ H_{2^b} exactly."""
    raw = hadamard_factorization(d)
    factors: list[tuple[str, int]] = []
    for kind, size in raw:
        if kind == "sylvester":
            while size > _MAX_FAST_FACTOR:
                factors.append(("sylvester", _MAX_FAST_FACTOR))
                size //= _MAX_FAST_FACTOR
            if size > 1:
                factors.append(("sylvester", size))
        else:
            factors.append((kind, size))
    return HadamardPlan(d=d, factors=tuple(factors), block=raw[0][0] == "block")


def _factor_rotations(plan: HadamardPlan, dtype) -> list[jnp.ndarray]:
    mats = []
    for kind, size in plan.factors:
        m = _factor_matrix(kind, size).astype(np.float32) / math.sqrt(size)
        mats.append(jnp.asarray(m, dtype=dtype))
    return mats


def apply_hadamard(x: jax.Array, d: int | None = None, *, axis: int = -1,
                   inverse: bool = False, skip_last: bool = False) -> jax.Array:
    """Compute ``x @ R`` (or ``x @ Rᵀ``) along ``axis`` without d×d GEMM.

    For a Kronecker factorization H = H_a ⊗ H_b, uses
    ``(X reshaped to [..., a, b]) ×_a H_a ×_b H_b`` — two small matmuls.
    H is symmetric only for Sylvester; Paley factors are not, so
    ``inverse=True`` applies the transposed factors (Rᵀ = R⁻¹ by
    orthogonality).  For block plans, applies H_b within groups.

    ``skip_last=True`` applies every Kronecker factor EXCEPT the last
    (power-of-two, contiguous-groups) one — the fused Pallas kernel
    (kernels/hadamard_kernel.py) applies that one in VMEM, and partial ∘
    kernel == full transform.
    """
    if axis != -1:
        x = jnp.moveaxis(x, axis, -1)
    dd = x.shape[-1] if d is None else d
    if x.shape[-1] != dd:
        raise ValueError(f"axis size {x.shape[-1]} != plan size {dd}")
    plan = plan_hadamard(dd)
    mats = _factor_rotations(plan, x.dtype)
    lead = x.shape[:-1]
    if plan.block:
        if skip_last:
            out = x  # the single grouped factor is the kernel's job
        else:
            b = plan.factor_sizes[0]
            xr = x.reshape(*lead, dd // b, b)
            h = mats[0]
            xr = jnp.einsum("...gb,bc->...gc", xr, h.T if inverse else h)
            out = xr.reshape(*lead, dd)
    else:
        sizes = plan.factor_sizes
        xr = x.reshape(*lead, *sizes)
        n_lead = len(lead)
        n_apply = len(mats) - 1 if skip_last else len(mats)
        for i, h in enumerate(mats[:n_apply]):
            hm = h.T if inverse else h
            ax = n_lead + i
            # contract factor axis i with hm: move axis to last, matmul, move back
            xr = jnp.moveaxis(jnp.moveaxis(xr, ax, -1) @ hm, -1, ax)
        out = xr.reshape(*lead, dd)
    if axis != -1:
        out = jnp.moveaxis(out, -1, axis)
    return out


def kernel_fusable_factor(d: int) -> int:
    """Size of the trailing power-of-two factor the fused kernel applies
    (0 if the plan's last factor is not Sylvester — pure-Paley dims)."""
    plan = plan_hadamard(d)
    kind, size = plan.factors[-1]
    return size if kind in ("sylvester", "block") else 0
