"""Synthetic activation generator calibrated to the paper's observations.

Real LLaMA2-7B activations are unavailable offline, so the analysis
benchmarks reproduce the paper's *claims* on synthetic tensors exhibiting
the two outlier types the paper identifies (§IV-A):

  * systematic outliers — a small set of channels hot across ALL tokens
    (attention / gate-up projection inputs);
  * massive outliers    — token-specific spikes with |o| > 1000, almost
    exclusively at down_proj inputs of particular layers (LLaMA2-7B:
    layers 1 and 30).

The generator mirrors paper Eq. (6): a massive-outlier token t has
t_j = o_j for j ∈ O and t_j = ε ~ N(0, σ²) elsewhere.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OutlierSpec", "synth_activations", "massive_outlier_token", "synth_weight"]


@dataclasses.dataclass(frozen=True)
class OutlierSpec:
    """Statistical profile of one module's input activations."""

    n_tokens: int = 128
    d: int = 1024
    base_std: float = 0.3          # ε scale of the bulk
    n_systematic: int = 6          # hot channels across all tokens
    systematic_scale: float = 20.0
    systematic_jitter: float = 0.5  # per-channel magnitude spread (±frac)
    n_massive_tokens: int = 0      # tokens carrying massive outliers
    n_massive_dims: int = 2        # |O| per massive token
    massive_value: float = 1500.0  # paper reports >1000 at down_proj 1/30


def synth_activations(key: jax.Array, spec: OutlierSpec) -> jax.Array:
    """Sample an (n_tokens, d) activation tensor with the given profile."""
    k_base, k_sys_ch, k_sys_val, k_mt, k_md, k_mv, k_sign = jax.random.split(key, 7)
    x = jax.random.normal(k_base, (spec.n_tokens, spec.d)) * spec.base_std
    if spec.n_systematic:
        ch = jax.random.choice(k_sys_ch, spec.d, (spec.n_systematic,), replace=False)
        # systematic channels: consistent sign & magnitude across tokens,
        # with mild per-token variation (matches Fig. 1 left panel).
        j = spec.systematic_jitter
        mag = spec.systematic_scale * (
            1.0 - j + 2 * j * jax.random.uniform(k_sys_val,
                                                 (spec.n_systematic,))
        )
        tok_jitter = 1.0 + 0.1 * jax.random.normal(
            k_mv, (spec.n_tokens, spec.n_systematic))
        sign = jax.random.rademacher(k_sign, (spec.n_systematic,), dtype=x.dtype)
        x = x.at[:, ch].set(mag * sign * tok_jitter)
    if spec.n_massive_tokens:
        toks = jax.random.choice(
            k_mt, spec.n_tokens, (spec.n_massive_tokens,), replace=False
        )
        dims = jax.random.choice(
            k_md, spec.d, (spec.n_massive_tokens, spec.n_massive_dims), replace=False
        )
        vals = spec.massive_value * (
            0.8 + 0.4 * jax.random.uniform(k_mv, dims.shape)
        )
        x = x.at[toks[:, None], dims].set(vals)
    return x


def massive_outlier_token(key: jax.Array, d: int, outlier_dims, outlier_vals,
                          sigma: float = 0.3) -> jax.Array:
    """Paper Eq. (6): one token with massive outliers o_j at j ∈ O."""
    t = jax.random.normal(key, (d,)) * sigma
    return t.at[jnp.asarray(outlier_dims)].set(jnp.asarray(outlier_vals, t.dtype))


def synth_weight(key: jax.Array, c_in: int, c_out: int, std: float = 0.02,
                 n_hot_rows: int = 0, hot_scale: float = 5.0) -> jax.Array:
    """Weight matrix; optionally a few hot input-channels (rows)."""
    k_w, k_r, k_v = jax.random.split(key, 3)
    w = jax.random.normal(k_w, (c_in, c_out)) * std
    if n_hot_rows:
        rows = jax.random.choice(k_r, c_in, (n_hot_rows,), replace=False)
        w = w.at[rows].mul(hot_scale)
    return w
