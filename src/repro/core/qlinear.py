"""Quantized linear execution: the serving-time W4A4/W8A8 matmul.

A :class:`QuantizedWeight` stores the offline-folded, RTN-quantized
weight (optionally nibble-packed int4) plus per-output-channel scales.
:func:`qlinear` applies, at runtime:

    [optional online Hadamard on x]  →  per-token RTN quantize
    →  integer matmul (int8 MXU, int32 accumulate)
    →  dequantize with (per-token Δ_a) ⊗ (per-channel Δ_w) epilogue.

Backend dispatch resolves in ``repro.kernels.ops.resolve_backend``
(docs/kernels.md): on TPU the whole chain is ONE fused Pallas kernel
per linear; elsewhere (and for the multi-pod dry-run on CPU) the
XLA-native integer ``dot_general`` path below lowers and shards under
pjit identically.  Both paths share the pure-jnp oracle in
``repro/kernels/ref.py`` for correctness tests.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import hadamard as hd
from repro.core.quantizer import QuantConfig, pack_int4, quantize, unpack_int4

__all__ = ["QuantizedWeight", "quantize_weight", "qlinear", "QuantPolicy"]


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Static (hashable) quantization policy for a model's linears."""

    weight_bits: int = 4
    act_bits: int = 4
    pack_weights: bool = True        # nibble-pack int4 storage
    online_hadamard: bool = True     # fused H on down/o-proj inputs
    quantize_lm_head: bool = False
    kv_cache_bits: int | None = 8    # None = bf16 cache
    use_kernels: Literal["auto", "never", "interpret"] = "auto"

    @property
    def enabled(self) -> bool:
        return True


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedWeight:
    """Folded + quantized weight. Pytree of arrays; metadata is static.

    w_q    : int8 codes, (c_in, c_out) unpacked or (c_in/2, c_out) packed
             along c_in nibbles when ``packed``.
    scale  : float32 per-output-channel Δ_w, (1, c_out).
    packed, bits, had_dim are static metadata (not traced).
    """

    w_q: jax.Array
    scale: jax.Array
    smooth: jax.Array | None = None   # per-channel s (Eq. 4): runtime x/s
    had_mask: jax.Array | None = None  # per-layer rotation gate (LayerwisePlan
    #                                    stacks mixing rotated/unrotated layers;
    #                                    scalar per layer after the scan slice)
    bits: int = dataclasses.field(metadata=dict(static=True), default=4)
    packed: bool = dataclasses.field(metadata=dict(static=True), default=False)
    had_dim: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def c_out(self) -> int:
        return self.w_q.shape[-1]

    @property
    def c_in(self) -> int:
        return self.w_q.shape[-2] * (2 if self.packed else 1)


def quantize_weight(w: jax.Array, bits: int = 4, pack: bool = True,
                    had_dim: int = 0, smooth: jax.Array | None = None
                    ) -> QuantizedWeight:
    """Per-channel symmetric RTN on an already-folded weight.

    ``had_dim``: if nonzero, the consumer must apply an online Hadamard of
    that size to the activation before the matmul (W was pre-multiplied
    by Rᵀ at fold time).  ``smooth``: SmoothQuant scales — runtime divides
    the activation channel-wise (W already row-multiplied at fold time).
    Packing is int4-only and along c_in (the contraction axis) so the
    kernel unpacks contiguous nibbles.
    """
    cfg = QuantConfig(bits=bits, granularity="per_channel")
    q, scale = quantize(w, cfg)  # q int8 (c_in, c_out); scale (1, c_out)
    packed = bool(pack and bits == 4 and q.shape[-2] % 2 == 0)
    if packed:
        # pack along c_in: pairs of rows -> transpose trick via reshape
        qt = jnp.swapaxes(q, -1, -2)           # (c_out, c_in)
        qt = pack_int4(qt)                     # (c_out, c_in/2)
        q = jnp.swapaxes(qt, -1, -2)           # (c_in/2, c_out)
    return QuantizedWeight(w_q=q, scale=scale.reshape(1, -1).astype(jnp.float32),
                           smooth=smooth, bits=bits, packed=packed,
                           had_dim=had_dim)


def _unpack(qw: QuantizedWeight) -> jax.Array:
    if not qw.packed:
        return qw.w_q
    qt = jnp.swapaxes(qw.w_q, -1, -2)
    qt = unpack_int4(qt)
    return jnp.swapaxes(qt, -1, -2)


def qlinear(x: jax.Array, qw: QuantizedWeight, policy: QuantPolicy) -> jax.Array:
    """Apply the quantized linear. x: (..., c_in) bf16/f32 → (..., c_out).

    Dispatch resolves in ``repro.kernels.ops.resolve_backend`` — ONE
    place for every call site (models, serving engine, benchmarks):

      use_kernels="auto"      → fused Pallas kernel on TPU, XLA elsewhere
      use_kernels="interpret" → fused kernel via the Pallas interpreter
      use_kernels="never"     → XLA-native integer path below

    The fused path (kernels/fused_qlinear.py) applies smooth + online
    Hadamard + quantize + int matmul in ONE ``pallas_call``, including
    ``had_mask``-gated mixed layerwise stacks (the gate is a traced
    scalar multiplexed in-kernel).  The XLA-native path (CPU dry-run,
    pjit sharding) performs the same arithmetic with int32-accumulated
    ``dot_general``; both share the ``repro/kernels/ref.py`` oracle.
    """
    lead = x.shape[:-1]
    from repro.kernels import ops  # local import: kernels layer on core

    mode = ops.resolve_backend(policy.use_kernels)
    if mode != "xla":
        x2 = x.reshape(-1, x.shape[-1])
        y2 = ops.fused_qlinear(x2, qw, act_bits=policy.act_bits,
                               interpret=(mode == "interpret"))
        return y2.reshape(*lead, qw.c_out).astype(x.dtype)

    if qw.smooth is not None:
        x = x / qw.smooth.astype(x.dtype)
    if qw.had_dim:
        xr = hd.apply_hadamard(x, qw.had_dim)
        # had_mask gates the online rotation per layer (mixed layerwise
        # plans); the activation quantizer below sees the SELECTED x, so
        # un-rotated layers quantize their original channel distribution.
        x = xr if qw.had_mask is None else jnp.where(qw.had_mask > 0, xr, x)
    x2 = x.reshape(-1, x.shape[-1])
    aq, a_scale = quantize(x2, QuantConfig(bits=policy.act_bits,
                                           granularity="per_token"))
    w_int = _unpack(qw)
    acc = jax.lax.dot_general(
        aq, w_int, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    y2 = acc.astype(jnp.float32) * a_scale * qw.scale
    return y2.reshape(*lead, qw.c_out).astype(x.dtype)
