"""Symmetric integer quantization (paper §II-A, Eq. (1)).

Implements symmetric RTN quantization on a uniform grid of ``2^{b-1}-1``
positive levels:  X_int = round(X / Δ),  Δ = max|X| / (2^{b-1} - 1),
with per-token (rows) or per-channel (columns) granularity and no
clipping (the paper deliberately keeps outliers unclipped, §III-B).

Both a "fake-quant" path (quantize→dequantize in float, used by the
analysis benchmarks and QAT) and a "real" path (int8-carried values +
scales, consumed by the Pallas serving kernels) are provided.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

__all__ = [
    "QuantConfig",
    "qmax",
    "quantize",
    "dequantize",
    "fake_quantize",
    "pack_int4",
    "unpack_int4",
]

Granularity = Literal["per_token", "per_channel", "per_tensor"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization settings for one tensor class.

    bits: grid width (4 or 8 here; any b >= 2 supported).
    granularity: which axis owns its own Δ. ``per_token`` = one scale per
      row (activations), ``per_channel`` = one per column (weights),
      matching paper §III-B.
    stochastic: stochastic rounding (used by gradient compression, not by
      the paper's RTN analysis).
    """

    bits: int = 4
    granularity: Granularity = "per_token"
    stochastic: bool = False

    @property
    def levels(self) -> int:
        return 2 ** (self.bits - 1) - 1


def qmax(bits: int) -> int:
    """Largest positive integer on the symmetric b-bit grid."""
    return 2 ** (bits - 1) - 1


def _scale_reduce_axes(ndim: int, granularity: Granularity) -> tuple[int, ...]:
    if granularity == "per_tensor":
        return tuple(range(ndim))
    if granularity == "per_token":
        return (ndim - 1,)  # reduce channels; one scale per leading index
    if granularity == "per_channel":
        return tuple(range(ndim - 1))  # reduce tokens; one scale per column
    raise ValueError(granularity)


def compute_scale(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Δ per Eq. (1): max|X| over the granularity axes / (2^{b-1}-1)."""
    axes = _scale_reduce_axes(x.ndim, cfg.granularity)
    absmax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    # Guard: all-zero rows/channels get Δ=1 to avoid 0/0 (quantizes to 0).
    absmax = jnp.where(absmax == 0, 1.0, absmax)
    return (absmax / cfg.levels).astype(jnp.float32)


def _round(x: jax.Array, cfg: QuantConfig, key: jax.Array | None) -> jax.Array:
    if cfg.stochastic:
        if key is None:
            raise ValueError("stochastic rounding needs a PRNG key")
        noise = jax.random.uniform(key, x.shape, dtype=x.dtype) - 0.5
        return jnp.floor(x + 0.5 + noise)
    return jnp.round(x)


@partial(jax.jit, static_argnames=("cfg",))
def quantize(x: jax.Array, cfg: QuantConfig = QuantConfig(),
             key: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Quantize to the integer grid. Returns (int8 codes, float32 Δ).

    Codes live in [-levels, levels] regardless of bits (carried as int8;
    nibble-packing for storage is ``pack_int4``).
    """
    scale = compute_scale(x, cfg)
    q = _round(x.astype(jnp.float32) / scale, cfg, key)
    q = jnp.clip(q, -cfg.levels, cfg.levels)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


@partial(jax.jit, static_argnames=("cfg",))
def fake_quantize(x: jax.Array, cfg: QuantConfig = QuantConfig(),
                  key: jax.Array | None = None) -> jax.Array:
    """Q(X) = round(X/Δ)·Δ in the input dtype (paper's analysis path)."""
    q, scale = quantize(x, cfg, key)
    return dequantize(q, scale, x.dtype)


# ---------------------------------------------------------------------------
# int4 nibble packing (storage format for W4; DESIGN.md §3)
# ---------------------------------------------------------------------------


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int8 codes in [-8, 7] pairwise along the last axis.

    Layout: byte = (q[..., 1::2] << 4) | (q[..., 0::2] & 0xF); the last
    axis must be even. Halves HBM footprint for 4-bit weights.
    """
    if q.shape[-1] % 2:
        raise ValueError("last axis must be even to pack nibbles")
    lo = q[..., 0::2].astype(jnp.uint8) & 0xF
    hi = (q[..., 1::2].astype(jnp.uint8) & 0xF) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` (sign-extending the nibbles)."""
    b = packed.astype(jnp.int8)
    # low nibble: shift left then arithmetic shift right to sign-extend
    lo = jnp.left_shift(b, 4)
    lo = jnp.right_shift(lo, 4)
    hi = jnp.right_shift(b, 4)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
