"""Equivalent transformations (paper §II-C, §III-C/D, §IV-E).

Y = XW = (XA)(A⁻¹W): design A to minimize quantization error.

  * smoothing    : A⁻¹ = diag(s), s_j = max|X_j|^α / max|W_j|^{1−α}
                   (SmoothQuant, Eq. (4); α = 0.5 default)
  * rotation     : A = R (orthonormal Hadamard), Ŵ = RᵀW, X̂ = XR
  * smooth_rotate: the paper's hybrid — scale first, THEN rotate both:
                   X̃ = X diag(s)⁻¹ R,  W̃ = Rᵀ diag(s) W
                   (§IV-E; spreads outlier mass over ~2d dimensions,
                   max|t̃| ≈ Σ_i sqrt(|o_i|·max|W_i|/d), Eq. (9))

All functions return (x̂, ŵ) such that x̂ @ ŵ == x @ w up to float
round-off — property-tested in tests/test_transforms.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal

import jax
import jax.numpy as jnp

from repro.core.hadamard import apply_hadamard

__all__ = [
    "TransformKind",
    "TransformPlan",
    "smoothing_scales",
    "smooth",
    "rotate",
    "smooth_rotate",
    "get_transform",
    "TRANSFORMS",
]

TransformKind = Literal["none", "smooth", "rotate", "smooth_rotate"]


def smoothing_scales(x: jax.Array, w: jax.Array, alpha: float = 0.5,
                     eps: float = 1e-8) -> jax.Array:
    """SmoothQuant Eq. (4) per-channel migration scales s (shape [c_in]).

    α controls how much difficulty moves from activations to weights; the
    paper uses the uncalibrated online α = 0.5 sweet spot but notes
    out_proj ≈ 0.7 / gate_proj ≈ 0.65 can be better (§IV-C).
    """
    ax = jnp.max(jnp.abs(x.reshape(-1, x.shape[-1]).astype(jnp.float32)), axis=0)
    aw = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=1)
    s = jnp.power(jnp.maximum(ax, eps), alpha) / jnp.power(
        jnp.maximum(aw, eps), 1.0 - alpha
    )
    return jnp.maximum(s, eps)


def smooth(x: jax.Array, w: jax.Array, alpha: float = 0.5,
           scales: jax.Array | None = None):
    """Channel-wise scaling: x̂ = x/s, ŵ = s⊙w (rows of W scaled)."""
    s = smoothing_scales(x, w, alpha) if scales is None else scales
    return x / s.astype(x.dtype), w * s[:, None].astype(w.dtype)


def rotate(x: jax.Array, w: jax.Array):
    """Hadamard rotation: x̂ = xR, ŵ = RᵀW (fast Kronecker apply).

    Both sides are the SAME contraction Σ_i T[i,·] R[i,k] (x along its
    channel axis, W along axis 0), which gives (XR)(RᵀW) = XW for any
    orthogonal R — including non-symmetric Paley factors.
    """
    return apply_hadamard(x), apply_hadamard(w, axis=0)


def smooth_rotate(x: jax.Array, w: jax.Array, alpha: float = 0.5,
                  scales: jax.Array | None = None):
    """The paper's hybrid (§IV-E): smoothing first, rotation second."""
    xs, ws = smooth(x, w, alpha, scales)
    return rotate(xs, ws)


def _identity(x, w):
    return x, w


TRANSFORMS: dict[str, Callable] = {
    "none": _identity,
    "smooth": smooth,
    "rotate": rotate,
    "smooth_rotate": smooth_rotate,
}


def get_transform(kind: TransformKind, alpha: float = 0.5) -> Callable:
    if kind in ("smooth", "smooth_rotate"):
        fn = TRANSFORMS[kind]
        return lambda x, w: fn(x, w, alpha)
    return TRANSFORMS[kind]


# ---------------------------------------------------------------------------
# Per-module transform policy (the framework's serving configuration)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformPlan:
    """Which equivalent transformation each module class receives.

    Default follows the paper's §V recommendation: SmoothRotation on
    down_proj (the massive-outlier site), rotation elsewhere.
    """

    attn_in: TransformKind = "rotate"        # q/k/v projections input
    attn_out: TransformKind = "rotate"       # o_proj input
    mlp_in: TransformKind = "rotate"         # gate/up projections input
    mlp_out: TransformKind = "smooth_rotate"  # down_proj input (§V)
    alpha: float = 0.5

    def kind_for(self, module: str) -> TransformKind:
        table = {
            "q_proj": self.attn_in, "k_proj": self.attn_in,
            "v_proj": self.attn_in, "o_proj": self.attn_out,
            "gate_proj": self.mlp_in, "up_proj": self.mlp_in,
            "down_proj": self.mlp_out,
            "in_proj": self.mlp_in, "out_proj": self.attn_out,
        }
        return table.get(module, "rotate")
