"""Data pipeline: synthetic streams, memmap token files, calibration."""
from repro.data.pipeline import (
    synthetic_batches,
    calibration_stream,
    TokenFileDataset,
)
