"""Data pipeline: deterministic synthetic LM streams + memmap token files.

Production notes: batches are generated per-host and sharded by the pjit
in_shardings (jax moves host shards to devices); determinism comes from
folding the step index into the seed, which makes the stream resumable
from any checkpoint step (fault tolerance: a restarted job re-reads the
exact same batch sequence).  Straggler mitigation hooks live in
repro.runtime (batch-level timeout + re-dispatch policy).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["synthetic_batches", "TokenFileDataset", "calibration_stream"]


def _batch_for(cfg: ModelConfig, rng: np.random.Generator, batch: int, seq: int):
    """Markov-ish synthetic tokens (learnable structure, so train loss
    demonstrably decreases) or frontend embeddings for audio/vlm."""
    # token stream with local structure: next ≈ prev + small step mod V
    V = cfg.vocab_size
    steps = rng.integers(-3, 4, size=(batch, seq))
    start = rng.integers(0, V, size=(batch, 1))
    toks = (start + np.cumsum(steps, axis=1)) % V
    toks = toks.astype(np.int32)
    if cfg.embeds_input and cfg.family in ("audio", "vlm"):
        d = cfg.d_model
        table = rng.standard_normal((256, d)).astype(np.float32) * 0.05
        emb = table[toks % 256].astype(np.float32)
        return {"embeds": jnp.asarray(emb, jnp.bfloat16),
                "labels": jnp.asarray(toks)}
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


def synthetic_batches(cfg: ModelConfig, batch: int, seq: int, *,
                      start: int = 0, seed: int = 17) -> Iterator[dict]:
    step = start
    while True:
        rng = np.random.default_rng(seed + step)  # resumable determinism
        yield _batch_for(cfg, rng, batch, seq)
        step += 1


def calibration_stream(cfg: ModelConfig, n_batches: int = 4, batch: int = 2,
                       seq: int = 64, seed: int = 23) -> Iterator[dict]:
    """Small stream for the quantization calibration pass (paper §III-A
    uses one WikiText-2 sample; we default to 4 batches)."""
    for i in range(n_batches):
        rng = np.random.default_rng(seed + i)
        yield _batch_for(cfg, rng, batch, seq)


@dataclasses.dataclass
class TokenFileDataset:
    """Flat binary token file (uint16/uint32 memmap), the standard
    pre-tokenized LM format.  Sequential chunking with a per-epoch
    shuffle of chunk order; per-host sharding by host_id stride."""

    path: str
    seq_len: int
    dtype: str = "uint16"
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self.n_chunks = (len(self._data) - 1) // self.seq_len

    def batches(self, batch: int, *, start_step: int = 0, seed: int = 0
                ) -> Iterator[dict]:
        per_host = batch // self.num_hosts
        step = start_step
        while True:
            rng = np.random.default_rng(seed + step)
            idx = rng.integers(0, self.n_chunks, size=(per_host,))
            idx = idx * self.seq_len
            toks = np.stack([self._data[i:i + self.seq_len + 1] for i in idx])
            toks = toks.astype(np.int32)
            yield {"tokens": jnp.asarray(toks[:, :-1]),
                   "labels": jnp.asarray(toks[:, 1:])}
            step += 1


def write_token_file(path: str, tokens: np.ndarray, dtype: str = "uint16"):
    tokens.astype(dtype).tofile(path)
    return os.path.getsize(path)
