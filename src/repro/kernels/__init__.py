"""Pallas TPU kernels for the quantized serving path (+ jnp oracles).

quantize_kernel : per-token RTN quantize (VPU lane reduction)
quant_matmul    : int8 MXU matmul, int32 accum, fused dual-scale dequant,
                  int4 nibble-packed weight variant
hadamard_kernel : fused online Hadamard transform + quantize
ops             : backend dispatch (TPU kernels / XLA-native / interpret)
ref             : pure-jnp oracles (the correctness contract)
"""
