"""Pallas TPU kernel: the ONE-pass fused quantized linear (decode hot path).

The paper's hybrid pipeline (smoothing before rotation, Eq. 4) puts a

    x / s  →  online Hadamard  →  per-token RTN quantize  →  int matmul
    →  (Δ_a ⊗ Δ_w) dequant

chain on every quantized linear at serving time.  The staged kernels
(`hadamard_kernel.py` + `quant_matmul.py`) cost THREE activation HBM
round trips per linear: the XLA pre-rotation writes x', the fused
hadamard-quant kernel re-reads x' and writes int8 codes + scales, and
the quant-matmul kernel re-reads the codes.  This kernel collapses the
whole chain into ONE ``pallas_call``:

  * the activation tile (block_n, k) is read from HBM ONCE per row
    block — its BlockSpec index is constant over the m/k grid axes, so
    the pipeline never refetches it;
  * smooth-divide, the trailing power-of-two Hadamard factor H_b (held
    in VMEM, applied as an MXU matmul over contiguous b-groups exactly
    like ``fused_hadamard_quant``), and the per-token absmax quantize
    run on the first visit of each row block, writing int8 codes and
    f32 scales into VMEM *scratch* — never to HBM;
  * a traced ``had_mask`` scalar gates the rotation IN-KERNEL, so mixed
    layerwise autoplan stacks (rotated and un-rotated layers sharing
    one scanned QuantizedWeight) stay on the fused path;
  * the int8 (or int4-nibble-packed) weight streams through VMEM in
    (block_k, block_m) tiles accumulating into an f32←i32 scratch, and
    the dual-scale dequant epilogue writes the bf16 output ONCE.

Kronecker dims whose rotation has leading factors (e.g. 4096 = H_512 ⊗
H_8, 1536 = Paley_12 ⊗ H_128) keep those factors as XLA matmuls before
the kernel — smoothing must precede them, so it moves to XLA too — and
fuse the trailing power-of-two factor; pure-Paley trailing factors
(e.g. d = 12) rotate fully in XLA and fuse quantize + matmul.  Either
way there is exactly ONE ``pallas_call`` per quantized linear
(docs/kernels.md has the full accounting table).

Decode-shaped inputs — the serving engine's ``(max_slots, 1)`` tick
flattens to n = max_slots rows — are padded up to one (8, k) tile
instead of degrading to divisor-1 blocks; padded rows quantize to zero
codes (absmax 0 → Δ = 1) and are sliced off the output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hadamard import (
    apply_hadamard,
    kernel_fusable_factor,
    plan_hadamard,
)
from repro.core.qlinear import QuantizedWeight
from repro.core.quantizer import qmax
from repro.kernels.hadamard_kernel import vmem_rotation_factor
from repro.kernels.quant_matmul import _round_up, _unpack_nibbles

__all__ = ["fused_qlinear"]

# Indirection so the dispatch-count tests can assert "one kernel launch
# per qlinear" by wrapping it (fused_qlinear is deliberately NOT wrapped
# in a module-level jax.jit: callers jit the surrounding model step).
_pallas_call = pl.pallas_call


def _kernel(*refs, k_steps: int, levels: int, block: int, block_k: int,
            packed: bool, has_smooth: bool, has_had: bool, has_mask: bool):
    it = iter(refs)
    x_ref = next(it)
    s_ref = next(it) if has_smooth else None
    h_ref = next(it) if has_had else None
    hm_ref = next(it) if has_mask else None
    w_ref, ws_ref, o_ref = next(it), next(it), next(it)
    acc_ref, xq_ref, xs_ref = next(it), next(it), next(it)

    j, kk = pl.program_id(1), pl.program_id(2)

    @pl.when((j == 0) & (kk == 0))
    def _transform_quantize():
        # first visit of this row block: smooth → H_b → quantize, codes
        # and scales land in VMEM scratch and are reused by every (j, kk)
        x = x_ref[...].astype(jnp.float32)              # (bn, k)
        if has_smooth:
            x = x / s_ref[...]                          # (1, k) broadcast
        if has_had:
            bn, k = x.shape
            xr = x.reshape(bn * (k // block), block)
            xt = jax.lax.dot_general(
                xr, h_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(bn, k)
            # had_mask multiplexes rotated/un-rotated layers of a mixed
            # layerwise stack without leaving the fused path
            x = jnp.where(hm_ref[0, 0] > 0, xt, x) if has_mask else xt
        absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = jnp.where(absmax == 0, 1.0, absmax) / levels
        xq_ref[...] = jnp.clip(jnp.round(x / scale), -levels, levels
                               ).astype(jnp.int8)
        xs_ref[...] = scale.astype(jnp.float32)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    aq = xq_ref[:, pl.ds(kk * block_k, block_k)]
    wq = _unpack_nibbles(w_ref[...]) if packed else w_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        aq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * xs_ref[...]
                      * ws_ref[...]).astype(o_ref.dtype)


def fused_qlinear(x: jax.Array, qw: QuantizedWeight, *, act_bits: int = 4,
                  interpret: bool = False, block_n: int = 8,
                  block_m: int = 256, block_k: int = 512) -> jax.Array:
    """[smooth] → [online Hadamard] → quantize → int matmul → dequant,
    ONE ``pallas_call``.  x: (n, c_in) float → (n, c_out) in x.dtype.

    Numerics match ``qlinear``'s XLA path (same full rotation, same
    int32 accumulation); ``ref.fused_qlinear_ref`` is the oracle.
    """
    n, k = x.shape
    if k != qw.c_in:
        raise ValueError(f"x has {k} channels, weight expects {qw.c_in}")
    out_dtype = x.dtype
    smooth, had_mask = qw.smooth, qw.had_mask
    last = kernel_fusable_factor(qw.had_dim) if qw.had_dim else 0

    if qw.had_dim and last < 2:
        # pure-Paley trailing factor: the rotation has no contiguous
        # power-of-two group structure — smooth + full rotation in XLA,
        # quantize + matmul fuse (2 HBM round trips instead of 3)
        if smooth is not None:
            x = x / smooth.astype(x.dtype)
        xr = apply_hadamard(x, qw.had_dim)
        x = xr if had_mask is None else jnp.where(had_mask > 0, xr, x)
        smooth = had_mask = None
        block = 0
    elif qw.had_dim and len(plan_hadamard(qw.had_dim).factors) > 1:
        # multi-factor Kronecker: leading factors (and smoothing, which
        # must precede them) run in XLA; the trailing power-of-two
        # factor fuses.  The mask gates BOTH stages consistently: an
        # un-rotated layer feeds the raw (smoothed) x through and the
        # kernel skips H_b for it via the same scalar.
        if smooth is not None:
            x = x / smooth.astype(x.dtype)
        xpre = apply_hadamard(x, qw.had_dim, skip_last=True)
        x = xpre if had_mask is None else jnp.where(had_mask > 0, xpre, x)
        smooth = None
        block = last
    else:
        block = last  # 0 (no rotation) or the single fully-fused factor

    has_smooth = smooth is not None
    has_had = block >= 2
    has_mask = has_had and had_mask is not None
    levels = qmax(act_bits)

    # --- tiling: pad to tile boundaries instead of degenerate divisors ---
    unit = max(block, 128) if has_had else 128  # block | unit (powers of 2)
    bn = min(block_n, _round_up(n, 8))
    bm = min(block_m, _round_up(m_ := qw.c_out, 128))
    # bk must stay a multiple of unit: Hadamard groups may not straddle
    # the padded region, and packed nibble pairs may not straddle blocks
    # (unit is even) — guards caller-overridden odd/unaligned block_k
    bk = _round_up(min(block_k, _round_up(k, unit)), unit)
    n_p, m_p, k_p = _round_up(n, bn), _round_up(m_, bm), _round_up(k, bk)

    x_p = jnp.pad(x, ((0, n_p - n), (0, k_p - k)))
    row_pad = (k_p - k) // 2 if qw.packed else k_p - k
    w_p = jnp.pad(qw.w_q, ((0, row_pad), (0, m_p - m_)))
    ws_p = jnp.pad(qw.scale, ((0, 0), (0, m_p - m_)))

    inputs = [x_p]
    in_specs = [pl.BlockSpec((bn, k_p), lambda i, j, kk: (i, 0))]
    if has_smooth:
        s_p = jnp.pad(smooth.astype(jnp.float32).reshape(1, k),
                      ((0, 0), (0, k_p - k)), constant_values=1.0)
        inputs.append(s_p)
        in_specs.append(pl.BlockSpec((1, k_p), lambda i, j, kk: (0, 0)))
    if has_had:
        inputs.append(vmem_rotation_factor(block))
        in_specs.append(pl.BlockSpec((block, block), lambda i, j, kk: (0, 0)))
    if has_mask:
        inputs.append(jnp.asarray(had_mask, jnp.float32).reshape(1, 1))
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)))
    wblk = bk // 2 if qw.packed else bk
    inputs += [w_p, ws_p]
    in_specs += [pl.BlockSpec((wblk, bm), lambda i, j, kk: (kk, j)),
                 pl.BlockSpec((1, bm), lambda i, j, kk: (0, j))]

    y = _pallas_call(
        functools.partial(
            _kernel, k_steps=k_p // bk, levels=levels, block=block,
            block_k=bk, packed=qw.packed, has_smooth=has_smooth,
            has_had=has_had, has_mask=has_mask),
        grid=(n_p // bn, m_p // bm, k_p // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_p, m_p), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bn, bm), jnp.int32),     # f32←i32 accumulator
            pltpu.VMEM((bn, k_p), jnp.int8),     # per-row int8 codes
            pltpu.VMEM((bn, 1), jnp.float32),    # per-token Δ_a
        ],
        interpret=interpret,
    )(*inputs)
    return y[:n, :m_]
