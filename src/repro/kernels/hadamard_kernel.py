"""Pallas TPU kernel: fused online Hadamard transform + per-token quantize.

The serving path's down_proj/o_proj inputs need an *online* rotation
(QuaRot-style) before quantization.  GPU implementations use warp-level
FWHT butterflies; the TPU-native formulation (DESIGN.md §3) applies the
power-of-two Hadamard factor H_b as a dense (b × b) matmul on the MXU —
each (block_n, d) activation tile is reshaped to (block_n · d/b, b),
multiplied by H_b/√b held in VMEM, per-token |·|-reduced, scaled, rounded
and written as int8 codes — transform + quantize in ONE HBM round-trip
instead of two.

The grouped (block-diagonal) transform with b ≤ 512 is exactly the
rotation the serving fold applies on the weight side (see
serving/fold.py), so numerical equivalence holds end-to-end.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hadamard import sylvester
from repro.core.quantizer import qmax

__all__ = ["fused_hadamard_quant", "vmem_rotation_factor"]


def vmem_rotation_factor(block: int) -> jax.Array:
    """H_block/√block as f32 — the VMEM-resident trailing rotation factor
    shared by this kernel and the one-pass ``fused_qlinear``."""
    return jnp.asarray(sylvester(block).astype("float32") / math.sqrt(block))


def _fhq_kernel(x_ref, h_ref, q_ref, s_ref, *, levels: int, block: int):
    bn, d = x_ref.shape
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...]                       # (block, block) = H/√b in VMEM
    xr = x.reshape(bn * (d // block), block)
    xt = jax.lax.dot_general(
        xr, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).reshape(bn, d)
    absmax = jnp.max(jnp.abs(xt), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax) / levels
    q = jnp.clip(jnp.round(xt / scale), -levels, levels)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block", "bits", "block_n", "interpret")
)
def fused_hadamard_quant(x: jax.Array, *, block: int = 128, bits: int = 4,
                         block_n: int = 8, interpret: bool = False):
    """x: (n, d) float, block | d, block = 2^p ≤ 512.

    Returns (codes int8 (n, d), per-token scales f32 (n, 1)).
    VMEM: block_n·d·4 (f32 tile) + block²·4 (H) + block_n·d (codes)
    — e.g. 8 × 16384 × 4 + 128² × 4 ≈ 0.6 MiB.
    """
    n, d = x.shape
    if d % block or block & (block - 1):
        raise ValueError(f"block {block} must be a power of two dividing d={d}")
    n_p = -(-n // block_n) * block_n  # pad ragged/tiny-n (decode) row counts
    if n_p != n:
        x = jnp.pad(x, ((0, n_p - n), (0, 0)))
    h = vmem_rotation_factor(block)
    grid = (n_p // block_n,)
    q, s = pl.pallas_call(
        functools.partial(_fhq_kernel, levels=qmax(bits), block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block, block), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_p, d), jnp.int8),
            jax.ShapeDtypeStruct((n_p, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, h)
    return q[:n], s[:n]
