"""Dispatching wrappers around the Pallas kernels.

``backend="auto"`` resolves to the Pallas kernels on TPU and to the
XLA-native integer path elsewhere (CPU dry-run/tests), keeping one call
site in the model code.  ``interpret=True`` forces the kernels through
the Pallas interpreter (CPU correctness tests).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.qlinear import QuantizedWeight
from repro.kernels import ref
from repro.kernels.hadamard_kernel import fused_hadamard_quant as _fhq_kernel
from repro.kernels.quant_matmul import quant_matmul as _qmm_kernel
from repro.kernels.quant_matmul import quant_matmul_packed as _qmm_packed_kernel
from repro.kernels.quantize_kernel import quantize_per_token as _q_kernel

__all__ = [
    "use_pallas",
    "quantize_per_token",
    "quant_matmul",
    "fused_hadamard_quant",
    "fused_quant_matmul",
]

Backend = Literal["auto", "pallas", "xla"]


def use_pallas(backend: Backend = "auto") -> bool:
    if backend == "pallas":
        return True
    if backend == "xla":
        return False
    return jax.default_backend() == "tpu"


def quantize_per_token(x, *, bits: int = 4, backend: Backend = "auto",
                       interpret: bool = False):
    if interpret or use_pallas(backend):
        return _q_kernel(x, bits=bits, interpret=interpret)
    return ref.quantize_per_token_ref(x, bits)


def quant_matmul(aq, wq, a_scale, w_scale, *, packed: bool = False,
                 backend: Backend = "auto", interpret: bool = False,
                 out_dtype=jnp.bfloat16):
    if interpret or use_pallas(backend):
        fn = _qmm_packed_kernel if packed else _qmm_kernel
        return fn(aq, wq, a_scale, w_scale, out_dtype=out_dtype,
                  interpret=interpret)
    if packed:
        from repro.core.quantizer import unpack_int4

        wq = jnp.swapaxes(unpack_int4(jnp.swapaxes(wq, -1, -2)), -1, -2)
    acc = ref.int_matmul_ref(aq, wq)
    return (acc.astype(jnp.float32) * a_scale * w_scale).astype(out_dtype)


def fused_hadamard_quant(x, *, block: int = 128, bits: int = 4,
                         backend: Backend = "auto", interpret: bool = False):
    if interpret or use_pallas(backend):
        return _fhq_kernel(x, block=block, bits=bits, interpret=interpret)
    return ref.fused_hadamard_quant_ref(x, block, bits)


def fused_quant_matmul(x, qw: QuantizedWeight, *, act_bits: int = 4,
                       backend: Backend = "auto", interpret: bool = False):
    """[smooth] → [online Hadamard] → quantize → int matmul, fused.

    The full-d Kronecker rotation is split: all factors but the last run
    as XLA matmuls; the trailing power-of-two factor is fused with the
    per-token quantization in one Pallas pass (DESIGN.md §3).  Numerics
    match ``qlinear``'s XLA path (same full rotation).
    """
    from repro.core.hadamard import apply_hadamard, kernel_fusable_factor

    if qw.smooth is not None:
        x = x / qw.smooth.astype(x.dtype)
    if qw.had_dim:
        last = kernel_fusable_factor(qw.had_dim)
        if last >= 2:
            x = apply_hadamard(x, qw.had_dim, skip_last=True)
            aq, a_scale = fused_hadamard_quant(x, block=last, bits=act_bits,
                                               backend=backend,
                                               interpret=interpret)
        else:  # pure-Paley trailing factor: full rotation in XLA
            x = apply_hadamard(x, qw.had_dim)
            aq, a_scale = quantize_per_token(x, bits=act_bits, backend=backend,
                                             interpret=interpret)
    else:
        aq, a_scale = quantize_per_token(x, bits=act_bits, backend=backend,
                                         interpret=interpret)
    return quant_matmul(aq, qw.w_q, a_scale, qw.scale, packed=qw.packed,
                        backend=backend, interpret=interpret)
