"""Dispatching wrappers around the Pallas kernels — the ONE place the
serving stack resolves which backend executes a quantized matmul.

:func:`resolve_backend` maps ``QuantPolicy.use_kernels`` to an execution
mode; ``qlinear`` (core/qlinear.py), the serving engine and the
benchmarks all route through it so no call site hard-codes a path:

    use_kernels="auto"      → "pallas" on TPU, "xla" elsewhere
    use_kernels="never"     → "xla"   (integer dot_general; pjit/shard ok)
    use_kernels="interpret" → "interpret" (Pallas interpreter on CPU)

:func:`fused_qlinear` is the one-pass serving kernel (ONE ``pallas_call``
per quantized linear — kernels/fused_qlinear.py); the staged
:func:`fused_quant_matmul` composition below is kept as the 3-round-trip
baseline the kernel benchmark compares against.  ``backend="auto"`` on
the per-stage wrappers resolves to the Pallas kernels on TPU and to the
XLA-native integer path elsewhere; ``interpret=True`` forces the Pallas
interpreter (CPU correctness tests).
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.qlinear import QuantizedWeight
from repro.kernels import ref
from repro.kernels.fused_qlinear import fused_qlinear as _fql_kernel
from repro.kernels.hadamard_kernel import fused_hadamard_quant as _fhq_kernel
from repro.kernels.paged_attention import paged_attention as _pa_kernel
from repro.kernels.quant_matmul import quant_matmul as _qmm_kernel
from repro.kernels.quant_matmul import quant_matmul_packed as _qmm_packed_kernel
from repro.kernels.quantize_kernel import quantize_per_token as _q_kernel

__all__ = [
    "use_pallas",
    "resolve_backend",
    "dispatch_resolutions",
    "KernelCircuitBreaker",
    "breaker",
    "quantize_per_token",
    "quant_matmul",
    "fused_hadamard_quant",
    "fused_quant_matmul",
    "fused_qlinear",
    "paged_attention",
]

Backend = Literal["auto", "pallas", "xla"]
KernelMode = Literal["pallas", "xla", "interpret"]


def use_pallas(backend: Backend = "auto") -> bool:
    if backend == "pallas":
        return True
    if backend == "xla":
        return False
    return jax.default_backend() == "tpu"


# Dispatch-layer instrumentation (repro.obs / docs/observability.md):
# every resolve_backend() outcome is tallied here, so the obs layer can
# report how often each executing backend was CHOSEN process-wide.
# Resolution happens at trace time — once per compiled program, plus
# once per engine tick for the engines' per-dispatch attribution — so
# these are resolution counts, not kernel-launch counts (the engines'
# "dispatch.*" registry counters carry the per-launch attribution).
_resolve_counts: dict[str, int] = {}


def dispatch_resolutions(reset: bool = False) -> dict[str, int]:
    """Snapshot {mode: times resolve_backend returned it}; ``reset``
    zeroes the tally (tests isolate themselves with it)."""
    out = dict(_resolve_counts)
    if reset:
        _resolve_counts.clear()
    return out


class KernelCircuitBreaker:
    """Per-op circuit breaker over the Pallas kernel path.

    State machine (docs/resilience.md):

        closed ──failure──▶ open ──cooldown resolutions──▶ half_open
        half_open ──probe succeeds──▶ closed (recovery)
        half_open ──probe fails────▶ open  (cooldown restarts)

    An op is a dispatch family name ("decode", "prefill").  While an op
    is ``open``, breaker-aware :func:`resolve_backend` calls return the
    XLA fallback instead of pallas/interpret; each such resolution
    counts down toward a ``half_open`` re-probe, where ONE native
    dispatch is attempted again.  The breaker is process-wide — like the
    jit caches, every engine over the same kernels shares the verdict —
    and only consulted when the caller passes ``op=`` (legacy
    resolutions are untouched).
    """

    def __init__(self, cooldown: int = 8):
        self.cooldown = cooldown
        self._state: dict[str, str] = {}        # op → closed|open|half_open
        self._until_probe: dict[str, int] = {}
        self.trips: dict[str, int] = {}
        self.recoveries: dict[str, int] = {}

    def allow_native(self, op: str) -> bool:
        """May this resolution take the native (pallas/interpret) path?
        An ``open`` op counts the refusal toward its re-probe window."""
        st = self._state.get(op, "closed")
        if st != "open":
            return True
        left = self._until_probe.get(op, 0) - 1
        if left <= 0:
            self._state[op] = "half_open"
            return True
        self._until_probe[op] = left
        return False

    def record_failure(self, op: str) -> None:
        self._state[op] = "open"
        self._until_probe[op] = self.cooldown
        self.trips[op] = self.trips.get(op, 0) + 1

    def record_success(self, op: str) -> bool:
        """Close a half-open op after a successful native probe; returns
        True exactly when a recovery happened (no-op while closed)."""
        if self._state.get(op) != "half_open":
            return False
        self._state[op] = "closed"
        self._until_probe[op] = 0
        self.recoveries[op] = self.recoveries.get(op, 0) + 1
        return True

    def state(self) -> dict:
        """Snapshot {op: {state, trips, recoveries, until_probe}} for
        every op the breaker has ever seen."""
        ops = (set(self._state) | set(self.trips) | set(self.recoveries))
        return {op: {"state": self._state.get(op, "closed"),
                     "trips": self.trips.get(op, 0),
                     "recoveries": self.recoveries.get(op, 0),
                     "until_probe": self._until_probe.get(op, 0)}
                for op in sorted(ops)}

    def reset(self) -> None:
        self._state.clear()
        self._until_probe.clear()
        self.trips.clear()
        self.recoveries.clear()


#: process-wide breaker instance — the engines report/record through it
#: and ``resolve_backend(op=...)`` consults it (tests reset() around it)
breaker = KernelCircuitBreaker()


def resolve_backend(use_kernels: Literal["auto", "never", "interpret"]
                    = "auto", op: str | None = None) -> KernelMode:
    """Map a ``QuantPolicy.use_kernels`` setting to the executing backend.

    This is the single dispatch authority (docs/kernels.md): tests pin
    the table and monkeypatch :func:`use_pallas` to emulate TPU hosts.

    With ``op=`` the process-wide :data:`breaker` is consulted: while
    that op's circuit is open, a pallas/interpret resolution is forced
    to "xla" (tallied under ``breaker_fallback`` as well, so
    :func:`dispatch_resolutions` surfaces how often the fallback was
    chosen) and counts toward the half-open re-probe.
    """
    if use_kernels == "interpret":
        mode: KernelMode = "interpret"
    elif use_kernels == "never":
        mode = "xla"
    elif use_kernels == "auto":
        mode = "pallas" if use_pallas("auto") else "xla"
    else:
        raise ValueError(f"unknown use_kernels setting: {use_kernels!r}")
    if (op is not None and mode in ("pallas", "interpret")
            and not breaker.allow_native(op)):
        mode = "xla"
        _resolve_counts["breaker_fallback"] = (
            _resolve_counts.get("breaker_fallback", 0) + 1)
    _resolve_counts[mode] = _resolve_counts.get(mode, 0) + 1
    return mode


def fused_qlinear(x, qw: QuantizedWeight, *, act_bits: int = 4,
                  interpret: bool = False):
    """One-``pallas_call`` quantized linear: smooth → online Hadamard
    (had_mask-gated in-kernel) → quantize → int matmul → dequant.
    x: (n, c_in) → (n, c_out).  See kernels/fused_qlinear.py."""
    return _fql_kernel(x, qw, act_bits=act_bits, interpret=interpret)


def paged_attention(q, layer_kv: dict, page_table, lengths, *,
                    interpret: bool = False):
    """One-``pallas_call`` paged GQA decode attention: pages of one
    layer's shared pool are DMA'd into VMEM through the page-table
    indirection (scalar prefetch) and reduced with an online softmax —
    no contiguous gather ever lands in HBM.  q: (b, 1, hq, d) →
    (b, 1, hq, d).  See kernels/paged_attention.py; the XLA parity
    fallback is ``models.common.paged_view`` + ``attention_scores``
    (oracle: ``ref.paged_attention_ref``)."""
    return _pa_kernel(q, layer_kv, page_table, lengths, interpret=interpret)


def quantize_per_token(x, *, bits: int = 4, backend: Backend = "auto",
                       interpret: bool = False):
    if interpret or use_pallas(backend):
        return _q_kernel(x, bits=bits, interpret=interpret)
    return ref.quantize_per_token_ref(x, bits)


def quant_matmul(aq, wq, a_scale, w_scale, *, packed: bool = False,
                 backend: Backend = "auto", interpret: bool = False,
                 out_dtype=jnp.bfloat16):
    if interpret or use_pallas(backend):
        fn = _qmm_packed_kernel if packed else _qmm_kernel
        return fn(aq, wq, a_scale, w_scale, out_dtype=out_dtype,
                  interpret=interpret)
    if packed:
        from repro.core.quantizer import unpack_int4

        wq = jnp.swapaxes(unpack_int4(jnp.swapaxes(wq, -1, -2)), -1, -2)
    acc = ref.int_matmul_ref(aq, wq)
    return (acc.astype(jnp.float32) * a_scale * w_scale).astype(out_dtype)


def fused_hadamard_quant(x, *, block: int = 128, bits: int = 4,
                         backend: Backend = "auto", interpret: bool = False):
    if interpret or use_pallas(backend):
        return _fhq_kernel(x, block=block, bits=bits, interpret=interpret)
    return ref.fused_hadamard_quant_ref(x, block, bits)


def fused_quant_matmul(x, qw: QuantizedWeight, *, act_bits: int = 4,
                       backend: Backend = "auto", interpret: bool = False):
    """[smooth] → [online Hadamard] → quantize → int matmul, STAGED.

    The full-d Kronecker rotation is split: all factors but the last run
    as XLA matmuls; the trailing power-of-two factor is fused with the
    per-token quantization in one Pallas pass (DESIGN.md §3).  Numerics
    match ``qlinear``'s XLA path (same full rotation).

    This is the 3-HBM-round-trip composition (rotation write → codes
    write → codes re-read) that :func:`fused_qlinear` collapses into one
    kernel; it remains as the benchmark baseline and a stage-level
    correctness cross-check (benchmarks/kernel_bench.py).
    """
    from repro.core.hadamard import apply_hadamard, kernel_fusable_factor

    if qw.smooth is not None:
        x = x / qw.smooth.astype(x.dtype)
    if qw.had_dim:
        last = kernel_fusable_factor(qw.had_dim)
        if last >= 2:
            x = apply_hadamard(x, qw.had_dim, skip_last=True)
            aq, a_scale = fused_hadamard_quant(x, block=last, bits=act_bits,
                                               backend=backend,
                                               interpret=interpret)
        else:  # pure-Paley trailing factor: full rotation in XLA
            x = apply_hadamard(x, qw.had_dim)
            aq, a_scale = quantize_per_token(x, bits=act_bits, backend=backend,
                                             interpret=interpret)
    else:
        aq, a_scale = quantize_per_token(x, bits=act_bits, backend=backend,
                                         interpret=interpret)
    return quant_matmul(aq, qw.w_q, a_scale, qw.scale, packed=qw.packed,
                        backend=backend, interpret=interpret)
