"""Pallas TPU kernel: paged-attention GQA decode over the shared page pool.

The paged serving engine (docs/serving.md) backs attention KV with
fixed-size pages from a shared ``(L, n_pages, page, hkv, d)`` pool,
indexed by a per-slot page table.  The XLA decode path materializes each
slot's pages into a contiguous ``(b, width·page, hkv, d)`` view per
layer (``common.paged_view`` — an HBM gather) and re-reads that view in
attention: every cached byte crosses HBM **three** times per layer per
tick (pool read → contiguous write → attention read), and int8 pools
additionally inflate the intermediate to bf16.

This kernel indexes pages **in-VMEM** instead.  The page table and the
per-slot length vector ride in as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index maps — which
run *before* the kernel body and drive the pipeline's DMAs — can look up
``table[slot, j]`` and fetch exactly that physical pool page into VMEM.
No contiguous view ever exists:

  * grid ``(b, hkv, width)``: each (slot, kv-head) pair walks its
    logical pages in order, carrying an online-softmax (max, sum, acc)
    accumulator in VMEM scratch — FlashAttention-style, the
    ``(g, width·page)`` probability row never materializes;
  * per-page **length-prefix masking**: positions ``≥ lengths[slot]``
    score ``-1e30`` (the engine allocates pages contiguously, so page
    validity ≡ the length prefix — same contract as ``paged_view``);
    pages wholly past the prefix (or unassigned, table ``-1``) are
    skipped via ``pl.when``;
  * int8 pools dequantize **in-kernel** from the paged scale leaves
    (``(n_pages, page, hkv, 1)`` f32, fetched through the same table
    indirection), mirroring ``paged_view``'s
    ``(codes·scale) → bf16`` numerics bit for bit;
  * the GQA group's ``(g, d)`` query block is resident across the page
    walk (its block index is constant over ``j``), and the output block
    is written once on the final page.

HBM traffic per layer per tick drops from
``3 × (pool bytes) [+ bf16 inflation]`` to ``1 × (pool bytes)`` —
``benchmarks/kernel_bench.py`` carries the exact accounting and the
TPU-v5e roofline model; docs/paged_attention.md has the design note.

Numerics: online softmax in f32 (running max/sum), matching
``attention_scores``'s masked-softmax reference to f32 reassociation
(greedy decode is token-identical in practice —
tests/test_serving_paged.py pins it engine-to-engine).  The serving
engines reach this kernel through ``ops.resolve_backend`` /
``common.paged_attn_backend``: ``auto`` → compiled on TPU hosts,
``interpret`` → the Pallas interpreter (CPU CI), ``never``/ineligible →
the XLA ``paged_view`` gather path as the parity fallback.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention"]

# Indirection so dispatch-count tests can assert "one kernel launch per
# layer" by wrapping it (mirrors kernels/fused_qlinear.py; deliberately
# NOT jitted at module level — callers jit the surrounding decode step).
_pallas_call = pl.pallas_call


def _kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, *refs, page: int,
            quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    i, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        # -1e30 (not -inf): matches the reference mask value and keeps
        # exp(m - m_new) finite when a row's first page is fully masked
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = len_ref[i]
    live = (tab_ref[i, j] >= 0) & (j * page < valid)

    @pl.when(live)
    def _page_step():
        # one logical page of this (slot, kv-head): blocks were DMA'd by
        # the table-driven index maps, so k/v arrive already "gathered"
        k = k_ref[0, :, 0, :]                      # (page, d)
        v = v_ref[0, :, 0, :]
        if quantized:
            # in-kernel dequant from the paged scale leaves — identical
            # staging to paged_view: (int8 · f32 scale) → bf16 → f32
            k = (k.astype(jnp.float32) * ks_ref[0, :, 0, :]
                 ).astype(jnp.bfloat16)
            v = (v.astype(jnp.float32) * vs_ref[0, :, 0, :]
                 ).astype(jnp.bfloat16)
        q = q_ref[0, 0].astype(jnp.float32)        # (g, d)
        d = q.shape[-1]
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * (d ** -0.5)   # (g, page)
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < valid, s, -1e30)
        # online-softmax update (running max / sum / weighted accumulator)
        m_new = jnp.maximum(m_ref[...], s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_ref[...] - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _epilogue():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention(q: jax.Array, layer_kv: dict, page_table: jax.Array,
                    lengths: jax.Array, *, interpret: bool = False
                    ) -> jax.Array:
    """GQA decode attention over one layer's paged KV pool, in-VMEM.

    q: ``(b, 1, hq, d)`` decode queries (one new token per slot).
    layer_kv: dict(k, v[, k_scale, v_scale]) with POOL shapes
    ``(n_pages, page, hkv, d)`` (scales ``(n_pages, page, hkv, 1)`` f32
    when int8).  page_table: ``(b, width)`` int32, ``-1`` = unassigned
    (skipped).  lengths: ``(b,)`` int32 — the number of VALID positions
    per slot *including* the token written this tick.

    Returns ``(b, 1, hq, d)`` in ``q.dtype``.  Rows whose length is 0
    return zeros (inactive slots decode garbage that is never sampled).
    """
    b, sq, hq, d = q.shape
    if sq != 1:
        raise ValueError(f"paged_attention is a decode kernel (sq=1), got "
                         f"sq={sq}")
    kp, vp = layer_kv["k"], layer_kv["v"]
    quantized = layer_kv.get("k_scale") is not None
    n_pages, page, hkv, _ = kp.shape
    if hq % hkv:
        raise ValueError(f"hq={hq} not a multiple of hkv={hkv}")
    g = hq // hkv
    width = page_table.shape[1]
    qg = q[:, 0].reshape(b, hkv, g, d)
    table = jnp.asarray(page_table, jnp.int32)
    # scalar lengths (attn_apply's single-sequence contract) broadcast
    # to the per-slot vector the scalar-prefetch operand expects
    lens = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))

    def page_map(i, h, j, t, ln):
        # the table drives the DMA: physical page of logical page j;
        # dead entries (-1) clamp to page 0 — fetched but never read
        # (the pl.when(live) gate skips the body)
        return (jnp.maximum(t[i, j], 0), 0, h, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda i, h, j, t, ln: (i, h, 0, 0)),
        pl.BlockSpec((1, page, 1, d), page_map),
        pl.BlockSpec((1, page, 1, d), page_map),
    ]
    inputs = [qg, kp, vp]
    if quantized:
        in_specs += [pl.BlockSpec((1, page, 1, 1), page_map),
                     pl.BlockSpec((1, page, 1, 1), page_map)]
        inputs += [layer_kv["k_scale"], layer_kv["v_scale"]]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, width),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda i, h, j, t, ln: (i, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),   # running max m
            pltpu.VMEM((g, 1), jnp.float32),   # running sum l
            pltpu.VMEM((g, d), jnp.float32),   # weighted accumulator
        ],
    )
    out = _pallas_call(
        functools.partial(_kernel, page=page, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(table, lens, *inputs)
    return out.reshape(b, 1, hq, d)
