"""Pallas TPU kernel: integer matmul with fused dual-scale dequant.

Computes Y = (Aq · Wq) ⊙ (Δ_a ⊗ Δ_w) where Aq (n,k) and Wq (k,m) are int8
codes, Δ_a per-token, Δ_w per-output-channel.  Tiles (block_n × block_k)
· (block_k × block_m) through VMEM with an f32←i32 accumulator scratch,
k as the innermost ("arbitrary") grid dimension, and the scale outer
product fused into the epilogue on the last k step — one HBM write of the
bf16 output, no intermediate int32 round-trip.

A packed variant unpacks int4 nibbles (two codes per int8 byte along k)
in VMEM right before the MXU dot, halving Wq HBM traffic — the dominant
serving cost (DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["quant_matmul", "quant_matmul_packed"]


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def _round_up(a: int, m: int) -> int:
    return _cdiv(a, m) * m


def _pad2(a: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to (rows, cols)."""
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _blocks(n: int, m: int, k: int, block_n: int, block_m: int,
            block_k: int) -> tuple[int, int, int]:
    """Hardware-aligned block sizes: pad to tile boundaries instead of the
    old largest-divisor heuristic, which degenerated to divisor-1 (scalar-
    ish grids) for prime/odd dims and for the engine's tiny-n decode rows.
    Sublane/lane minimums: 8 rows, 128 lanes."""
    bn = min(block_n, _round_up(n, 8))
    bm = min(block_m, _round_up(m, 128))
    bk = min(block_k, _round_up(k, 128))
    bk += bk % 2  # packed nibble pairs must not straddle blocks
    return bn, bm, bk


def _qmm_kernel(aq_ref, wq_ref, as_ref, ws_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        aq_ref[...], wq_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * as_ref[...] * ws_ref[...]
        ).astype(o_ref.dtype)


def _unpack_nibbles(packed: jax.Array) -> jax.Array:
    """(bk/2, bm) int8 bytes → (bk, bm) int8 codes, pairs along axis 0."""
    lo = jnp.right_shift(jnp.left_shift(packed, 4), 4)  # sign-extended low
    hi = jnp.right_shift(packed, 4)
    bk2, bm = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bm)


def _qmm_packed_kernel(aq_ref, wq_ref, as_ref, ws_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wq = _unpack_nibbles(wq_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        aq_ref[...], wq, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * as_ref[...] * ws_ref[...]
        ).astype(o_ref.dtype)


def _call(kernel, aq, wq, a_scale, w_scale, *, k: int, m: int, n: int,
          block_n: int, block_m: int, block_k: int, packed: bool,
          out_dtype, interpret: bool):
    k_steps = _cdiv(k, block_k)
    grid = (_cdiv(n, block_n), _cdiv(m, block_m), k_steps)
    wk_block = block_k // 2 if packed else block_k
    return pl.pallas_call(
        functools.partial(kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((wk_block, block_m), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_n, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_n, block_m), jnp.int32)],
        interpret=interpret,
    )(aq, wq, a_scale, w_scale)


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_m", "block_k", "out_dtype", "interpret"),
)
def quant_matmul(aq: jax.Array, wq: jax.Array, a_scale: jax.Array,
                 w_scale: jax.Array, *, block_n: int = 128, block_m: int = 128,
                 block_k: int = 512, out_dtype=jnp.bfloat16,
                 interpret: bool = False) -> jax.Array:
    """Unpacked int8 × int8 → out_dtype.  aq (n,k), wq (k,m)."""
    n, k = aq.shape
    _, m = wq.shape
    bn, bm, bk = _blocks(n, m, k, block_n, block_m, block_k)
    n_p, m_p, k_p = _round_up(n, bn), _round_up(m, bm), _round_up(k, bk)
    y = _call(_qmm_kernel, _pad2(aq, n_p, k_p), _pad2(wq, k_p, m_p),
              _pad2(a_scale, n_p, 1), _pad2(w_scale, 1, m_p),
              k=k_p, m=m_p, n=n_p, block_n=bn, block_m=bm, block_k=bk,
              packed=False, out_dtype=out_dtype, interpret=interpret)
    return y[:n, :m]


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_m", "block_k", "out_dtype", "interpret"),
)
def quant_matmul_packed(aq: jax.Array, wq_packed: jax.Array, a_scale: jax.Array,
                        w_scale: jax.Array, *, block_n: int = 128,
                        block_m: int = 128, block_k: int = 512,
                        out_dtype=jnp.bfloat16, interpret: bool = False) -> jax.Array:
    """int4-packed weights: wq_packed (k/2, m) bytes, k codes along rows.

    Blocks are 128-lane aligned (even), so nibble pairs never straddle a
    block boundary; padded rows are zero bytes = two zero codes.
    """
    n, k = aq.shape
    _, m = wq_packed.shape
    bn, bm, bk = _blocks(n, m, k, block_n, block_m, block_k)
    n_p, m_p, k_p = _round_up(n, bn), _round_up(m, bm), _round_up(k, bk)
    y = _call(_qmm_packed_kernel, _pad2(aq, n_p, k_p),
              _pad2(wq_packed, k_p // 2, m_p), _pad2(a_scale, n_p, 1),
              _pad2(w_scale, 1, m_p), k=k_p, m=m_p, n=n_p, block_n=bn,
              block_m=bm, block_k=bk, packed=True, out_dtype=out_dtype,
              interpret=interpret)
    return y[:n, :m]
