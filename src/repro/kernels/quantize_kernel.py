"""Pallas TPU kernel: per-token symmetric RTN quantization.

One HBM pass per activation tile: read a (block_n, d) tile into VMEM,
lane-reduce |x| per row on the VPU, scale, round, clip, emit int8 codes
and f32 per-token scales.  This is the activation-quantization stage of
the W4A4 serving path when the Hadamard transform is folded (no online
rotation needed); otherwise use fused_hadamard_quant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantizer import qmax

__all__ = ["quantize_per_token"]


def _quantize_kernel(x_ref, q_ref, s_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax) / levels
    q = jnp.clip(jnp.round(x / scale), -levels, levels)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "block_n", "interpret"))
def quantize_per_token(x: jax.Array, *, bits: int = 4, block_n: int = 8,
                       interpret: bool = False):
    """x: (n, d) float → (codes int8 (n, d), scales f32 (n, 1)).

    BlockSpec keeps whole rows in VMEM (per-token absmax is a full-row
    reduction); block_n rows per grid step bounds VMEM at
    block_n × d × (2B in + 1B out) — e.g. 8 × 53248 ≈ 1.2 MiB.
    """
    n, d = x.shape
    n_p = -(-n // block_n) * block_n  # pad ragged/tiny-n (decode) row counts
    if n_p != n:
        x = jnp.pad(x, ((0, n_p - n), (0, 0)))
    grid = (n_p // block_n,)
    q, s = pl.pallas_call(
        functools.partial(_quantize_kernel, levels=qmax(bits)),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_p, d), jnp.int8),
            jax.ShapeDtypeStruct((n_p, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:n], s[:n]
