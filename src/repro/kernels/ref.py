"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors one kernel's semantics exactly (same rounding, same
accumulation dtype) so tests can ``assert_allclose(kernel, ref)`` across
shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hadamard import (
    apply_hadamard,
    kernel_fusable_factor,
    plan_hadamard,
)
from repro.core.quantizer import qmax, unpack_int4

__all__ = [
    "quantize_per_token_ref",
    "quant_matmul_ref",
    "fused_hadamard_quant_ref",
    "fused_qlinear_ref",
    "int_matmul_ref",
    "paged_attention_ref",
]


def quantize_per_token_ref(x: jax.Array, bits: int = 4):
    """Per-token symmetric RTN: (codes int8, scales f32 (rows, 1))."""
    levels = qmax(bits)
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax) / levels
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -levels, levels)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def int_matmul_ref(aq: jax.Array, wq: jax.Array) -> jax.Array:
    """int8 × int8 → int32 accumulate (the MXU contract)."""
    return jax.lax.dot_general(
        aq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def quant_matmul_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                     act_bits: int = 4, out_dtype=jnp.bfloat16) -> jax.Array:
    """Fused per-token quantize → int matmul → dual-scale dequant.

    x: (n, k) float; w_q: (k, m) int8 codes (already unpacked);
    w_scale: (1, m) f32.  Matches quant_matmul kernel semantics.
    """
    aq, a_scale = quantize_per_token_ref(x, act_bits)
    acc = int_matmul_ref(aq, w_q)
    return (acc.astype(jnp.float32) * a_scale * w_scale).astype(out_dtype)


def fused_qlinear_ref(x: jax.Array, qw, act_bits: int = 4) -> jax.Array:
    """Oracle for the one-pass ``kernels.fused_qlinear``: same staging
    (XLA leading Kronecker factors in x.dtype, trailing factor + smooth +
    quantize in f32), same had_mask gating, same int32 accumulation.

    ``qw`` is a ``repro.core.qlinear.QuantizedWeight`` (duck-typed here
    to keep the oracle import-free of the execution layer).
    """
    n, k = x.shape
    smooth, had_mask = qw.smooth, qw.had_mask
    last = kernel_fusable_factor(qw.had_dim) if qw.had_dim else 0
    if qw.had_dim and last < 2:          # pure-Paley trailing: XLA rotation
        if smooth is not None:
            x = x / smooth.astype(x.dtype)
        xr = apply_hadamard(x, qw.had_dim)
        x = xr if had_mask is None else jnp.where(had_mask > 0, xr, x)
        smooth = had_mask = None
        block = 0
    elif qw.had_dim and len(plan_hadamard(qw.had_dim).factors) > 1:
        if smooth is not None:           # leading factors (and smooth) in XLA
            x = x / smooth.astype(x.dtype)
        xpre = apply_hadamard(x, qw.had_dim, skip_last=True)
        x = xpre if had_mask is None else jnp.where(had_mask > 0, xpre, x)
        smooth = None
        block = last
    else:
        block = last
    xf = x.astype(jnp.float32)
    if smooth is not None:
        xf = xf / smooth.astype(jnp.float32)[None, :]
    if block >= 2:
        xt = apply_hadamard(xf.reshape(n, k // block, block),
                            block).reshape(n, k)
        xf = xt if had_mask is None else jnp.where(had_mask > 0, xt, xf)
    aq, a_scale = quantize_per_token_ref(xf, act_bits)
    w = qw.w_q
    if qw.packed:
        w = jnp.swapaxes(unpack_int4(jnp.swapaxes(w, -1, -2)), -1, -2)
    acc = int_matmul_ref(aq, w)
    return (acc.astype(jnp.float32) * a_scale * qw.scale).astype(x.dtype)


def fused_hadamard_quant_ref(x: jax.Array, block: int, bits: int = 4):
    """Online grouped Hadamard (within ``block``-sized groups) followed by
    per-token RTN quantize; returns (codes int8, scales f32).

    The transform runs in f32 — matching the kernel, whose MXU dot
    accumulates bf16 inputs into f32 (preferred_element_type)."""
    n, d = x.shape
    xr = x.astype(jnp.float32).reshape(n, d // block, block)
    xt = apply_hadamard(xr, block)  # block is a power of two → Sylvester
    return quantize_per_token_ref(xt.reshape(n, d), bits)


def paged_attention_ref(q: jax.Array, layer_kv: dict, page_table: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """Gather-then-attend oracle for ``paged_attention`` (the XLA path).

    Mirrors ``models.common.paged_view`` + ``attention_scores`` exactly:
    pages gathered in logical order into a contiguous (b, width·page, hkv,
    d) view (table entries clamped to page 0 — stale reads rely on the
    length mask), int8 pools dequantized (codes·scale)→bf16, masked
    softmax in f32, length-prefix mask at -1e30.
    """
    idx = jnp.maximum(jnp.asarray(page_table, jnp.int32), 0)
    k, v = layer_kv["k"][idx], layer_kv["v"][idx]      # (b, w, page, hkv, d)
    if layer_kv.get("k_scale") is not None:
        k = (k.astype(jnp.float32) * layer_kv["k_scale"][idx]
             ).astype(jnp.bfloat16)
        v = (v.astype(jnp.float32) * layer_kv["v_scale"][idx]
             ).astype(jnp.bfloat16)
    b, w, page = k.shape[0], k.shape[1], k.shape[2]
    k = k.reshape(b, w * page, *k.shape[3:])
    v = v.reshape(b, w * page, *v.shape[3:])
    hq, d = q.shape[2], q.shape[3]
    hkv = k.shape[2]
    qg = q.reshape(b, 1, hkv, hq // hkv, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    mask = jnp.arange(w * page)[None] < jnp.asarray(lengths).reshape(-1, 1)
    s = jnp.where(mask[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, 1, hq, d).astype(q.dtype)
