"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors one kernel's semantics exactly (same rounding, same
accumulation dtype) so tests can ``assert_allclose(kernel, ref)`` across
shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hadamard import apply_hadamard
from repro.core.quantizer import qmax

__all__ = [
    "quantize_per_token_ref",
    "quant_matmul_ref",
    "fused_hadamard_quant_ref",
    "int_matmul_ref",
]


def quantize_per_token_ref(x: jax.Array, bits: int = 4):
    """Per-token symmetric RTN: (codes int8, scales f32 (rows, 1))."""
    levels = qmax(bits)
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax) / levels
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -levels, levels)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def int_matmul_ref(aq: jax.Array, wq: jax.Array) -> jax.Array:
    """int8 × int8 → int32 accumulate (the MXU contract)."""
    return jax.lax.dot_general(
        aq, wq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def quant_matmul_ref(x: jax.Array, w_q: jax.Array, w_scale: jax.Array,
                     act_bits: int = 4, out_dtype=jnp.bfloat16) -> jax.Array:
    """Fused per-token quantize → int matmul → dual-scale dequant.

    x: (n, k) float; w_q: (k, m) int8 codes (already unpacked);
    w_scale: (1, m) f32.  Matches quant_matmul kernel semantics.
    """
    aq, a_scale = quantize_per_token_ref(x, act_bits)
    acc = int_matmul_ref(aq, w_q)
    return (acc.astype(jnp.float32) * a_scale * w_scale).astype(out_dtype)


def fused_hadamard_quant_ref(x: jax.Array, block: int, bits: int = 4):
    """Online grouped Hadamard (within ``block``-sized groups) followed by
    per-token RTN quantize; returns (codes int8, scales f32).

    The transform runs in f32 — matching the kernel, whose MXU dot
    accumulates bf16 inputs into f32 (preferred_element_type)."""
    n, d = x.shape
    xr = x.astype(jnp.float32).reshape(n, d // block, block)
    xt = apply_hadamard(xr, block)  # block is a power of two → Sylvester
    return quantize_per_token_ref(xt.reshape(n, d), bits)
