"""Distribution & launch: meshes, sharding rules, dry-run, roofline,
train/serve drivers."""
