"""jax version compatibility for mesh construction/activation.

The codebase targets the explicit-sharding API (``jax.set_mesh``,
``jax.sharding.AxisType``); older jax releases (≤ 0.4.x) predate both.
These wrappers resolve to the modern API when present and degrade to the
legacy equivalents (``jax.make_mesh`` without ``axis_types``; the
``Mesh`` context manager) otherwise, so tests and CPU dry-runs work on
whichever jax the container bakes in.
"""

from __future__ import annotations

from typing import Sequence

import jax

__all__ = ["make_mesh", "set_mesh", "get_abstract_mesh", "shard_map",
           "jit_shardings"]

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_GET_ABSTRACT = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_TOP_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types when the API supports them."""
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def set_mesh(mesh):
    """Context manager activating ``mesh`` (``jax.set_mesh`` or legacy
    ``with mesh:`` resource-env entry)."""
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The ambient mesh, or None when none is active.

    Modern jax exposes ``jax.sharding.get_abstract_mesh``; legacy jax
    tracks the ``with mesh:`` resource env in thread-local state.
    """
    if _HAS_GET_ABSTRACT:
        mesh = jax.sharding.get_abstract_mesh()
        return mesh if mesh is not None and mesh.axis_names else None
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def shard_map(f, *, mesh=None, in_specs, out_specs):
    """``jax.shard_map`` over the ambient mesh, without replication checks.

    Legacy jax has only ``jax.experimental.shard_map.shard_map`` (which
    requires an explicit mesh and spells the check flag ``check_rep``).
    """
    if _HAS_TOP_SHARD_MAP:
        kw = {} if mesh is None else {"mesh": mesh}
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             check_vma=False, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    if mesh is None:
        mesh = get_abstract_mesh()
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def jit_shardings(mesh, tree):
    """A PartitionSpec tree usable as jit in_/out_shardings.

    Modern jax accepts raw PartitionSpecs under an ambient ``set_mesh``;
    legacy jax requires concrete ``NamedSharding`` objects (``None``
    leaves meaning "replicated" included).
    """
    if _HAS_SET_MESH:
        return tree
    from jax.sharding import NamedSharding, PartitionSpec

    def to_sharding(spec):
        return NamedSharding(mesh, spec if isinstance(spec, PartitionSpec)
                             else PartitionSpec())

    return jax.tree.map(
        to_sharding, tree,
        is_leaf=lambda s: s is None or isinstance(s, PartitionSpec))
