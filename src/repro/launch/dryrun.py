import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the REAL step function (train_step with
gradient accumulation for train shapes; fold+quantized serve prefill /
decode for inference shapes), lowers it under the production mesh with
explicit in_shardings, compiles, and records:

  * memory_analysis()  — per-device argument/temp/output bytes (fits?)
  * cost_analysis()    — raw XLA numbers (per-device, loop bodies once)
  * hlo_analysis       — trip-corrected FLOPs / HBM / collective bytes
  * roofline terms     — compute/memory/collective seconds + dominant

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and are
summarized into EXPERIMENTS.md §Dry-run/§Roofline by benchmarks/report.

NOTE the XLA_FLAGS line above MUST precede any jax import (jax locks the
device count at first backend init) — which is why this module sets it
at line 1-2 and why tests/benchmarks never import this module.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeCell, get_config, list_archs
from repro.core.qlinear import QuantPolicy
from repro.core.transforms import TransformPlan
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import dp_size, make_production_mesh
from repro.launch.roofline import roofline
from repro.launch.sharding import batch_spec, cache_specs, param_specs
from repro.models.api import get_model
from repro.optim import adamw
from repro.serving.fold import fold_quantize
from repro.launch import compat

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# long-context needs sub-quadratic attention: full-attention archs run the
# documented sliding-window VARIANT (DESIGN.md §5); SSM/hybrid run native.
WINDOW_FOR_LONG = 8192


def effective_config(cfg: ModelConfig, cell: ShapeCell, *,
                     opt: str = "") -> tuple[ModelConfig, str]:
    """opt: comma-joined subset of {flash, bf16io, sp, µN} — the §Perf
    beyond-paper optimizations (baseline = none of them)."""
    note = ""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        cfg = dataclasses.replace(cfg, attn_window=WINDOW_FOR_LONG)
        note = (f"windowed-attention variant (window={WINDOW_FOR_LONG}): "
                "pure full-attention arch cannot decode 500k natively")
    opts = set(filter(None, opt.split(",")))
    if "flash" in opts and not cfg.attn_window:
        cfg = dataclasses.replace(cfg, attn_impl="flash")
        note += " +flash"
    if "bf16io" in opts:
        cfg = dataclasses.replace(cfg, attn_bf16_io=True)
        note += " +bf16io"
    if "sp" in opts:
        cfg = dataclasses.replace(cfg, seq_parallel=True)
        note += " +sp"
    if "noremat" in opts:
        cfg = dataclasses.replace(cfg, remat=False)
        note += " +noremat"
    if "rematdots" in opts:
        cfg = dataclasses.replace(cfg, remat_policy="dots_no_batch")
        note += " +rematdots"
    if "flashdecode" in opts:
        cfg = dataclasses.replace(cfg, decode_flash=True)
        note += " +flashdecode"
    for o in opts:
        if o.startswith("group") and o != "flashdecode":
            cfg = dataclasses.replace(cfg, remat_policy=o)
            note += f" +{o}"
    return cfg, note


def microbatches_for(cfg: ModelConfig, cell: ShapeCell, mesh) -> int:
    if cell.kind != "train":
        return 1
    b_dev = max(1, cell.global_batch // dp_size(mesh))
    # target ≤2 sequences per microbatch per device for the big models
    big = cfg.d_model >= 6144 or cfg.num_layers >= 48
    target = 2 if big else 8
    mb = max(1, b_dev // target)
    while cell.global_batch % (mb * dp_size(mesh)) and mb > 1:
        mb -= 1
    return mb


def synthetic_stats(cfg: ModelConfig):
    """Abstract-friendly calibration stats (ones) for fold tracing."""
    import numpy as np

    from repro.core.calibration import CalibStats

    L = cfg.num_layers
    ones = lambda *shape: jnp.ones(shape, jnp.float32)
    if cfg.family in ("dense", "audio", "vlm"):
        return {
            "k_proj": CalibStats(ones(L, cfg.d_model)),
            "o_proj": CalibStats(ones(L, cfg.num_heads * cfg.head_dim)),
            "gate_proj": CalibStats(ones(L, cfg.d_model)),
            "down_proj": CalibStats(ones(L, cfg.d_ff)),
        }
    if cfg.family == "moe":
        Lm = cfg.num_layers - cfg.first_dense_layers
        o_dim = (cfg.num_heads * cfg.v_head_dim if cfg.kv_lora_rank
                 else cfg.num_heads * cfg.head_dim)
        st = {
            "k_proj": CalibStats(ones(Lm, cfg.d_model)),
            "o_proj": CalibStats(ones(Lm, o_dim)),
            "gate_proj": CalibStats(ones(Lm, cfg.d_model)),
            "down_proj": CalibStats(ones(Lm, cfg.d_ff)),
        }
        if cfg.kv_lora_rank:
            st["kv_up"] = CalibStats(ones(Lm, cfg.kv_lora_rank))
        return st
    return {  # ssm / hybrid
        "in_proj": CalibStats(ones(cfg.num_layers, cfg.d_model)),
        "out_proj": CalibStats(ones(cfg.num_layers, cfg.d_inner)),
    }


def build_cell(arch: str, cell: ShapeCell, mesh, *, quantized: bool = True,
               microbatches: int | None = None, opt: str = ""):
    """Returns (fn, arg_shapes, in_shardings, note)."""
    cfg, note = effective_config(get_config(arch), cell, opt=opt)
    model = get_model(cfg)
    policy = QuantPolicy(weight_bits=4, act_bits=4, use_kernels="never",
                         kv_cache_bits=8)
    b, s = cell.global_batch, cell.seq_len

    params_shape = jax.eval_shape(lambda k: model.init(k, cfg),
                                  jax.random.PRNGKey(0))

    if cell.kind == "train":
        from repro.launch.train import make_train_step

        import jax.numpy as _jnp

        moment = _jnp.bfloat16 if "bf16mom" in opt else _jnp.float32
        if "bf16mom" in opt:
            note += " +bf16mom"
        opt_ = adamw(3e-4, moment_dtype=moment)
        mb = microbatches or microbatches_for(cfg, cell, mesh)
        note += f" microbatches={mb}"
        opt_shape = jax.eval_shape(opt_.init, params_shape)
        step_fn = make_train_step(model, cfg, opt_, microbatches=mb)
        bspec = batch_spec(mesh, b)
        if cfg.embeds_input and cfg.family in ("audio", "vlm"):
            batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                    jnp.bfloat16),
                     "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            bspecs = {"embeds": P(*bspec, None), "labels": bspec}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            bspecs = {"tokens": bspec, "labels": bspec}
        pspecs = param_specs(params_shape, cfg, mesh)
        ospecs = param_specs(opt_shape, cfg, mesh)
        args = (params_shape, opt_shape, batch,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
        shardings = (pspecs, ospecs, bspecs, P(), P())
        return step_fn, args, shardings, note, cfg

    # --- serving cells: fold+quantized weights (the paper's pipeline) ---
    if quantized:
        stats = synthetic_stats(cfg)
        serve_params = jax.eval_shape(
            lambda p: fold_quantize(p, cfg, policy=policy,
                                    plan=TransformPlan(), stats=stats),
            params_shape)
        note += " W4A4+smooth_rotate serve params, int8 KV"
    else:
        serve_params = params_shape
        note += " bf16 serve params"

    cache_shape = jax.eval_shape(
        lambda: model.make_cache(cfg, b, s, bits=policy.kv_cache_bits))
    pspecs = param_specs(serve_params, cfg, mesh)
    cspecs = cache_specs(cfg, mesh, cache_shape)
    if cell.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)

        def fn(p, t, c):
            return model.prefill(p, cfg, t, c, policy=policy)
    else:
        tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)

        def fn(p, t, c):
            return model.decode_step(p, cfg, t, c, policy=policy)

    args = (serve_params, tokens, cache_shape)
    shardings = (pspecs, batch_spec(mesh, b), cspecs)
    return fn, args, shardings, note, cfg


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, *,
             quantized: bool = True, save_dir: str | None = None,
             microbatches: int | None = None, verbose: bool = True,
             opt: str = "", strategy: str = "2d"):
    from repro.launch.sharding import set_strategy

    set_strategy(strategy)
    cell = SHAPES[shape_name]
    t0 = time.time()
    with compat.set_mesh(mesh):
        fn, args, shardings, note, cfg = build_cell(
            arch, cell, mesh, quantized=quantized, microbatches=microbatches,
            opt=opt)
        if strategy != "2d":
            note += f" strategy={strategy}"
        donate = (0, 1) if cell.kind == "train" else (2,)
        lowered = jax.jit(fn, in_shardings=compat.jit_shardings(mesh, shardings),
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    metrics = analyze_hlo(compiled.as_text())
    chips = mesh.devices.size
    pod = mesh.shape.get("pod", 1)
    rep = roofline(metrics, cfg, cell, mesh_name=mesh_name, chips=chips,
                   pod_size=pod, notes=note.strip())
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "note": note.strip(),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "peak_gb_estimate": (mem.argument_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 + mem.output_size_in_bytes
                                 - mem.alias_size_in_bytes) / 1e9,
        },
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed",
                                    "utilization")},
        "hlo": {
            "flops_per_device": metrics.flops,
            "flops_by_dtype": metrics.flops_by_dtype,
            "hbm_gb_per_device": metrics.hbm_bytes / 1e9,
            "collective_raw_gb": metrics.collective_bytes / 1e9,
            "wire_gb_per_device": metrics.wire_bytes / 1e9,
            "wire_by_group_gb": {str(g): v / 1e9 for g, v
                                 in metrics.wire_bytes_by_group.items()},
            "n_collectives": len(metrics.collectives),
            "while_trips": metrics.while_trips,
        },
        "roofline": rep.row(),
    }
    if save_dir:
        os.makedirs(save_dir, exist_ok=True)
        path = os.path.join(save_dir, f"{arch}__{shape_name}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    if verbose:
        r = result["roofline"]
        print(f"  {arch:22s} {shape_name:12s} compile={t_compile:6.1f}s "
              f"mem={result['memory']['peak_gb_estimate']:7.2f}GB/dev "
              f"compute={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s "
              f"coll={r['collective_s']:.4f}s dcn={r['dcn_s']:.4f}s "
              f"→ {r['dominant']}", flush=True)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--bf16-serve", action="store_true",
                    help="serve cells with bf16 weights (baseline compare)")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--opt", default="",
                    help="comma list: flash,bf16io,sp (§Perf options)")
    ap.add_argument("--strategy", default="2d", choices=["2d", "fsdp"])
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args(argv)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        save = os.path.join(args.out, mesh_name)
        print(f"== mesh {mesh_name} ({mesh.devices.size} devices) ==",
              flush=True)
        for arch in archs:
            for shape in shapes:
                try:
                    run_cell(arch, shape, mesh, mesh_name,
                             quantized=not args.bf16_serve, save_dir=save,
                             microbatches=args.microbatches or None,
                             opt=args.opt, strategy=args.strategy)
                except Exception as e:  # noqa: BLE001 — report & continue
                    failures.append((mesh_name, arch, shape, repr(e)))
                    print(f"  {arch:22s} {shape:12s} FAILED: {e!r}",
                          flush=True)
                    traceback.print_exc()
    print(f"\n{'=' * 60}")
    if failures:
        print(f"FAILURES ({len(failures)}):")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("ALL CELLS COMPILED OK")


if __name__ == "__main__":
    main()
