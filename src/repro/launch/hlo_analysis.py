"""HLO-text analyzer: per-device FLOPs, HBM traffic, and collective bytes
with while-loop trip-count multiplication.

Why: on this JAX (0.8.x), ``compiled.cost_analysis()`` counts while-loop
bodies ONCE and is per-device (verified empirically — DESIGN.md §7), so
scanned-layer models would be undercounted by ~num_layers×.  This module
parses ``compiled.as_text()`` directly:

  * computations are split and symbol tables built per computation;
  * ``while`` trip counts come from the s32 comparison constant in the
    loop's condition computation (scan lowers to ``iter < N``);
  * dot FLOPs = 2 · prod(result shape) · prod(contracting dims), using
    operand shapes from the symbol table, bucketed by operand dtype
    (int8 MXU dots have 2× the bf16 peak);
  * HBM traffic ≈ Σ over top-level ops of (output + operand bytes) —
    fusion internals excluded (they live in registers/VMEM);
  * collective wire bytes per device use ring-algorithm factors:
    all-reduce 2(S−1)/S·B, all-gather/reduce-scatter/all-to-all
    (S−1)/S·B, collective-permute B (S = replica-group size).

Everything is multiplied through nested while trip counts.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["HloMetrics", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "iota", "partition-id",
    "replica-id",
}

_shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
_op_re = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_comp_re = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_operand_re = re.compile(r"%([\w.\-]+)")
_groups_re = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_groups_braces_re = re.compile(r"replica_groups=\{\{([^}]*)\}")
_cdims_re = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")
_lhs_cdims_re = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_const_re = re.compile(r"constant\((\d+)\)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_re.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> tuple[str, list[int]]:
    m = _shape_re.search(type_str)
    if not m:
        return "f32", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else (dtype, [])


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str  # operands + attributes (un-split; attrs parsed by regex)


@dataclasses.dataclass
class HloMetrics:
    flops: float = 0.0                      # total dot flops (all dtypes)
    flops_by_dtype: dict = dataclasses.field(default_factory=dict)
    hbm_bytes: float = 0.0
    s2_bytes: float = 0.0                   # traffic of (s×s) attention
    #                                         score/prob tensors — a fused
    #                                         Pallas flash kernel keeps
    #                                         these in VMEM (subset of
    #                                         hbm_bytes)
    collective_bytes: float = 0.0           # raw operand bytes (task spec)
    wire_bytes: float = 0.0                 # ring-adjusted per-device bytes
    wire_bytes_by_group: dict = dataclasses.field(default_factory=dict)
    collectives: list = dataclasses.field(default_factory=list)
    while_trips: dict = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "HloMetrics":
        return HloMetrics(
            flops=self.flops * k,
            flops_by_dtype={d: v * k for d, v in self.flops_by_dtype.items()},
            hbm_bytes=self.hbm_bytes * k,
            s2_bytes=self.s2_bytes * k,
            collective_bytes=self.collective_bytes * k,
            wire_bytes=self.wire_bytes * k,
            wire_bytes_by_group={g: v * k for g, v
                                 in self.wire_bytes_by_group.items()},
            collectives=[(n, b * k, g) for (n, b, g) in self.collectives],
            while_trips=dict(self.while_trips),
        )

    def add(self, other: "HloMetrics"):
        self.flops += other.flops
        for d, v in other.flops_by_dtype.items():
            self.flops_by_dtype[d] = self.flops_by_dtype.get(d, 0.0) + v
        self.hbm_bytes += other.hbm_bytes
        self.s2_bytes += other.s2_bytes
        self.collective_bytes += other.collective_bytes
        self.wire_bytes += other.wire_bytes
        for g, v in other.wire_bytes_by_group.items():
            self.wire_bytes_by_group[g] = (
                self.wire_bytes_by_group.get(g, 0.0) + v)
        self.collectives.extend(other.collectives)
        self.while_trips.update(other.while_trips)


def _is_s2_tensor(type_str: str, min_dim: int = 1024) -> bool:
    """True for attention-score-shaped tensors: last two dims both large
    (the (sq, sk) logits/probs a fused flash kernel never spills)."""
    m = _shape_re.search(type_str)
    if not m or not m.group(2):
        return False
    dims = [int(d) for d in m.group(2).split(",")]
    return len(dims) >= 2 and dims[-1] >= min_dim and dims[-2] >= min_dim


def _parse_computations(text: str) -> tuple[dict[str, list[_Op]], str]:
    comps: dict[str, list[_Op]] = {}
    entry = ""
    current: list[_Op] | None = None
    for line in text.splitlines():
        if current is None:
            m = _comp_re.match(line)
            if m:
                is_entry, name = m.groups()
                current = []
                comps[name] = current
                if is_entry:
                    entry = name
            continue
        if line.strip().startswith("}"):
            current = None
            continue
        m = _op_re.match(line)
        if m:
            name, type_str, kind, rest = m.groups()
            current.append(_Op(name, type_str, kind, rest))
    return comps, entry


def _trip_count(cond_ops: list[_Op]) -> int:
    """Scan conditions lower to `lt(iter, N)`: take the max s32 constant."""
    best = 1
    for op in cond_ops:
        if op.kind == "constant" and "s32" in op.type_str:
            m = _const_re.search(op.kind + "(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
        m = _const_re.search(op.rest)
        if m and ("compare" in op.kind or "constant" in op.kind):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(op: _Op, symbols: dict[str, str]) -> tuple[str, float]:
    out_dtype, out_dims = _shape_dims(op.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # lhs shape via first operand
    operands = _operand_re.findall(op.rest)
    lhs_type = symbols.get(operands[0], "") if operands else ""
    lhs_dtype, lhs_dims = _shape_dims(lhs_type)
    cdims = _lhs_cdims_re.search(op.rest)
    contract = 1
    if cdims and cdims.group(1):
        for idx in cdims.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return lhs_dtype, 2.0 * out_elems * contract


def _collective_wire(op: _Op, symbols: dict[str, str]) -> tuple[float, float, int]:
    """(raw operand bytes, ring wire bytes per device, group size)."""
    operands = _operand_re.findall(op.rest.split(")")[0] + ")")
    in_bytes = sum(_type_bytes(symbols.get(o, "")) for o in operands
                   if o in symbols)
    out_bytes = _type_bytes(op.type_str)
    gm = _groups_re.search(op.rest)
    if gm:
        group = int(gm.group(2))
    else:
        gb = _groups_braces_re.search(op.rest)
        group = len(gb.group(1).split(",")) if gb else 1
    group = max(group, 1)
    kind = op.kind.replace("-start", "")
    f = (group - 1) / group
    if kind.startswith("all-reduce"):
        wire = 2 * f * in_bytes
    elif kind.startswith("all-gather"):
        wire = f * out_bytes
    elif kind.startswith("reduce-scatter"):
        wire = f * in_bytes
    elif kind.startswith("all-to-all"):
        wire = f * in_bytes
    else:  # collective-permute
        wire = in_bytes
    return float(in_bytes), float(wire), group


_PASSTHROUGH = {"bitcast", "reshape", "copy", "transpose", "convert"}


def _op_operands(op: _Op) -> list[str]:
    return _operand_re.findall(op.rest.split(")")[0])


def _fusion_hbm_bytes(comp_ops: list[_Op], out_bytes: float) -> float:
    """HBM traffic of one fused kernel: output + effective reads of each
    parameter.  A parameter consumed ONLY through dynamic-slice windows is
    charged the window bytes (scan reading one layer's weights from the
    stacked array), not the full operand; a dynamic-update-slice buffer is
    charged read+write of the update window (in-place aliasing)."""
    symbols = {op.name: op for op in comp_ops}
    consumers: dict[str, list[_Op]] = defaultdict(list)
    for op in comp_ops:
        for o in _op_operands(op):
            consumers[o].append(op)
    total = 0.0
    for op in comp_ops:
        if op.kind != "parameter":
            continue
        frontier = [op.name]
        seen = set()
        eff = 0.0
        full = False
        while frontier and not full:
            nm = frontier.pop()
            if nm in seen:
                continue
            seen.add(nm)
            for c in consumers.get(nm, []):
                if c.kind in _PASSTHROUGH:
                    frontier.append(c.name)
                elif c.kind == "dynamic-slice":
                    eff += _type_bytes(c.type_str)
                elif c.kind == "dynamic-update-slice":
                    ops_ = _op_operands(c)
                    if ops_ and ops_[0] == nm:  # nm is the big buffer
                        upd = symbols.get(ops_[1]) if len(ops_) > 1 else None
                        eff += 2 * _type_bytes(upd.type_str if upd
                                               else c.type_str)
                    else:  # nm is the update value → read it fully
                        full = True
                else:
                    full = True
        total += _type_bytes(op.type_str) if full else eff
    # if the fusion ROOT is a dynamic-update-slice, the output is aliased:
    # charge the update window, not the whole buffer
    dus_roots = [op for op in comp_ops if op.kind == "dynamic-update-slice"]
    if dus_roots and all(not consumers.get(op.name) for op in dus_roots):
        out_bytes = 0.0  # write already charged via the parameter path
    return total + out_bytes


def _called_comps(op: _Op) -> list[str]:
    out = []
    for attr in ("calls=", "to_apply=", "body=", "condition="):
        for m in re.finditer(re.escape(attr) + r"%?([\w.\-]+)", op.rest):
            out.append((attr, m.group(1)))
    return out


def analyze_hlo(text: str) -> HloMetrics:
    comps, entry = _parse_computations(text)
    cache: dict[str, HloMetrics] = {}

    def comp_metrics(name: str, *, count_bytes: bool) -> HloMetrics:
        key = name + ("|b" if count_bytes else "|nb")
        if key in cache:
            return cache[key]
        out = HloMetrics()
        cache[key] = out  # guards recursion
        ops = comps.get(name, [])
        symbols = {op.name: op.type_str for op in ops}
        for op in ops:
            kind = op.kind
            base = kind.replace("-start", "").replace("-done", "")
            if kind in ("dot", "convolution"):
                dtype, fl = _dot_flops(op, symbols)
                out.flops += fl
                out.flops_by_dtype[dtype] = (
                    out.flops_by_dtype.get(dtype, 0.0) + fl)
            if base in _COLLECTIVES and not kind.endswith("-done"):
                raw, wire, group = _collective_wire(op, symbols)
                out.collective_bytes += raw
                out.wire_bytes += wire
                out.wire_bytes_by_group[group] = (
                    out.wire_bytes_by_group.get(group, 0.0) + wire)
                out.collectives.append((kind, wire, group))
            if count_bytes and kind not in _SKIP_BYTES_OPS:
                out_b = _type_bytes(op.type_str)
                contrib = 0.0
                s2 = 0.0
                if kind == "fusion":
                    called = [t for a, t in _called_comps(op) if a == "calls="]
                    if called and called[0] in comps:
                        contrib = _fusion_hbm_bytes(comps[called[0]], out_b)
                    else:
                        contrib = out_b
                    if _is_s2_tensor(op.type_str):
                        s2 += out_b
                    for o in _op_operands(op):
                        if o in symbols and _is_s2_tensor(symbols[o]):
                            s2 += _type_bytes(symbols[o])
                    s2 = min(s2, contrib)
                elif kind == "dynamic-slice":
                    contrib = 2 * out_b  # window read + write
                elif kind == "dynamic-update-slice":
                    ops_ = _op_operands(op)
                    upd = symbols.get(ops_[1], "") if len(ops_) > 1 else ""
                    contrib = 2 * _type_bytes(upd)
                else:
                    in_b = sum(_type_bytes(symbols.get(o, ""))
                               for o in _op_operands(op) if o in symbols)
                    contrib = out_b + in_b
                    if _is_s2_tensor(op.type_str):
                        s2 += out_b
                    for o in _op_operands(op):
                        if o in symbols and _is_s2_tensor(symbols[o]):
                            s2 += _type_bytes(symbols[o])
                out.hbm_bytes += contrib
                out.s2_bytes += s2
            # recurse
            if kind == "while":
                body = cond = None
                for attr, target in _called_comps(op):
                    if attr == "body=":
                        body = target
                    elif attr == "condition=":
                        cond = target
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                out.while_trips[op.name] = trips
                if body:
                    out.add(comp_metrics(body, count_bytes=count_bytes)
                            .scaled(trips))
            elif kind == "fusion":
                for attr, target in _called_comps(op):
                    if attr == "calls=":
                        # flops/collectives inside fusions count; bytes don't
                        out.add(comp_metrics(target, count_bytes=False))
            elif kind in ("call", "conditional", "async-start"):
                for attr, target in _called_comps(op):
                    if attr in ("to_apply=", "calls="):
                        out.add(comp_metrics(target, count_bytes=count_bytes))
        cache[key] = out
        return out

    if not entry:
        raise ValueError("no ENTRY computation found in HLO text")
    return comp_metrics(entry, count_bytes=True)
