"""Production mesh construction (task-spec meshes).

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init).
"""

from __future__ import annotations

from repro.launch import compat

__all__ = ["make_production_mesh", "make_test_mesh", "dp_axes", "dp_size"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a leading 2-pod axis.

    Axis roles: ``pod`` — pure data parallel (gradient all-reduce crosses
    the DCN once per step); ``data`` — batch/FSDP; ``model`` — TP/EP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (same axis names as production)."""
    return compat.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Mesh axes carrying the batch (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
