"""Roofline-term derivation from compiled dry-run artifacts (TPU v5e).

Terms per (arch × shape × mesh), all in seconds-per-step-per-device:

    compute_s    = Σ_dtype FLOPs_dtype / peak_dtype          (int8 = 2× bf16)
    memory_s     = HBM_bytes / 819 GB/s
    collective_s = ici_wire_bytes / 50 GB/s  (+ DCN term for pod-crossing)

FLOPs/bytes come from repro.launch.hlo_analysis (per-device, while-trip
corrected).  MODEL_FLOPS = 6·N·D (train) or 2·N·tokens (decode/prefill),
with N = active params for MoE — the useful-compute ratio flags remat /
redundant work.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeCell
from repro.launch.hlo_analysis import HloMetrics

__all__ = ["HW", "RooflineReport", "roofline", "model_params", "model_flops",
           "serving_decode_cell", "serving_tick_flops",
           "serving_prefill_cell", "serving_prefill_flops",
           "serving_kv_token_elems", "serving_tick_hbm_bytes",
           "serving_prefill_hbm_bytes"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (task spec)."""

    peak_bf16: float = 197e12
    peak_int8: float = 394e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9          # per link; collective term per task formula
    dcn_bw: float = 25e9          # cross-pod (conservative)


def model_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) parameter counts from the config (analytic)."""
    d, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    emb = V * d
    head = d * V
    total = emb + head + d  # + final norm
    active = total
    for i in range(L):
        if cfg.family in ("dense", "audio", "vlm") or (
                cfg.family == "moe" and i < cfg.first_dense_layers):
            if cfg.kv_lora_rank:
                attn = (d * cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                        + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                        + cfg.kv_lora_rank * cfg.num_heads
                        * (cfg.qk_nope_dim + cfg.v_head_dim)
                        + cfg.num_heads * cfg.v_head_dim * d)
            else:
                hd = cfg.head_dim
                attn = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd \
                    + cfg.num_heads * hd * d
            ffn = 3 * d * cfg.d_ff
            total += attn + ffn
            active += attn + ffn
        elif cfg.family == "moe":
            if cfg.kv_lora_rank:
                attn = (d * cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                        + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                        + cfg.kv_lora_rank * cfg.num_heads
                        * (cfg.qk_nope_dim + cfg.v_head_dim)
                        + cfg.num_heads * cfg.v_head_dim * d)
            else:
                hd = cfg.head_dim
                attn = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd \
                    + cfg.num_heads * hd * d
            e_ffn = 3 * d * cfg.moe_d_ff
            total += attn + cfg.num_experts * e_ffn + d * cfg.num_experts
            active += attn + cfg.experts_per_tok * e_ffn + d * cfg.num_experts
            shared = cfg.num_shared_experts * 3 * d * cfg.moe_d_ff
            dense_res = 3 * d * cfg.d_ff if cfg.dense_residual else 0
            total += shared + dense_res
            active += shared + dense_res
        elif cfg.family in ("ssm", "hybrid"):
            di = cfg.d_inner
            gn = cfg.ssm_ngroups * cfg.ssm_state
            inp = d * (2 * di + 2 * gn + cfg.ssm_nheads)
            outp = di * d
            total += inp + outp
            active += inp + outp
    if cfg.family == "hybrid":
        hd = cfg.head_dim
        shared_blk = (d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd
                      + cfg.num_heads * hd * d + 3 * d * cfg.d_ff)
        total += shared_blk
        active += shared_blk
    return int(total), int(active)


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """Useful model FLOPs per step (global): 6·N·D train, 2·N·tokens serve."""
    _, active = model_params(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * active * tokens
    tokens = cell.global_batch  # one new token per sequence
    return 2.0 * active * tokens


def serving_decode_cell(max_slots: int, max_len: int = 256) -> ShapeCell:
    """The serving engine's batched decode tick as a roofline shape cell.

    ``ServingEngine.step`` issues ONE ``(max_slots, 1)`` decode program
    per tick — exactly a ``decode``-kind cell with ``global_batch ==
    max_slots``, i.e. the shape the ``decode_*`` roofline cells already
    model.  The per-slot baseline instead issues ``n_active`` batch-1
    programs for the SAME useful FLOPs, paying the dispatch + weight-
    stream overhead once per slot; benchmarks/serving_throughput.py uses
    this cell to cross-check measured tokens/tick against the model.
    """
    return ShapeCell(f"serve_decode_b{max_slots}", max_len, max_slots,
                     "decode")


def serving_tick_flops(cfg: ModelConfig, max_slots: int) -> float:
    """Useful model FLOPs of one batched engine tick (2·N_active·slots)."""
    return model_flops(cfg, serving_decode_cell(max_slots))


def serving_prefill_cell(n_admit: int, padded_len: int) -> ShapeCell:
    """One in-engine batched prefill dispatch as a roofline shape cell.

    ``PagedServingEngine`` admits a whole batch with ONE
    ``(n_admit, padded_prompt_len)`` ``prefill_paged`` program — a
    ``prefill``-kind cell with ``global_batch == n_admit``.  The seed
    admission path instead ran ``n_admit`` batch-1 prefills plus a full
    slot-extent ``write_slot`` copy each; the padded cell's FLOPs bound
    the batching overhead (padding rows) the dispatch saving buys.
    """
    return ShapeCell(f"serve_prefill_b{n_admit}x{padded_len}", padded_len,
                     n_admit, "prefill")


def serving_prefill_flops(cfg: ModelConfig, n_admit: int,
                          padded_len: int) -> float:
    """Useful model FLOPs of one batched admission dispatch
    (2·N_active·n_admit·padded_len)."""
    return model_flops(cfg, serving_prefill_cell(n_admit, padded_len))


def serving_kv_token_elems(cfg: ModelConfig) -> int:
    """KV-cache elements appended per token, summed over every
    attention invocation (MLA stores the latent + rope stripe; hybrids
    hit the shared block every ``attn_every`` SSM layers; pure SSM has
    O(1) state — nothing per token)."""
    if cfg.family in ("dense", "audio", "vlm"):
        return cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim
    if cfg.family == "moe":
        per = ((cfg.kv_lora_rank + cfg.qk_rope_dim) if cfg.kv_lora_rank
               else 2 * cfg.num_kv_heads * cfg.head_dim)
        return cfg.num_layers * per
    if cfg.family == "hybrid" and cfg.attn_every:
        invocations = -(-cfg.num_layers // cfg.attn_every)
        return invocations * 2 * cfg.num_kv_heads * cfg.head_dim
    return 0


def serving_tick_hbm_bytes(cfg: ModelConfig, n_slots: int,
                           mean_context: float, *,
                           weight_bits: int | None = None,
                           kv_bits: int | None = None,
                           backend: str = "xla") -> float:
    """Modeled HBM bytes of ONE batched decode tick — the quantity the
    obs layer attributes per kernel backend (docs/observability.md).

    Decode is dominated by two streams, and only those are modeled:
    the weight stream (active params × storage width — int4 packs two
    codes per byte) and the KV-cache traffic (read the per-slot context
    prefix, append one token).  The ``backend`` factor mirrors
    ``benchmarks.kernel_bench.paged_hbm_bytes``: the XLA ``paged_view``
    gather fallback materializes a contiguous bf16 view of the context
    KV (one write + one read) that the in-VMEM Pallas kernel never
    pays, so "xla" adds two bf16 passes over the read set.  Analytic —
    a per-backend attribution model, not a measurement.
    """
    _, active = model_params(cfg)
    w_bytes = active * (weight_bits / 8 if weight_bits else 2)
    elems = serving_kv_token_elems(cfg)
    kv_elem_bytes = 1 if kv_bits == 8 else 2
    read = n_slots * mean_context * elems * kv_elem_bytes
    write = n_slots * elems * kv_elem_bytes
    gather_extra = (2 * n_slots * mean_context * elems * 2
                    if backend == "xla" and elems else 0.0)
    return float(w_bytes + read + write + gather_extra)


def serving_prefill_hbm_bytes(cfg: ModelConfig, n_rows: int,
                              padded_len: int, *,
                              weight_bits: int | None = None,
                              kv_bits: int | None = None) -> float:
    """Modeled HBM bytes of ONE batched admission dispatch: the weight
    stream plus the KV written for every (row, position) — prefill
    attends from VMEM/registers over its own tile, so no context read
    term.  Same analytic caveat as :func:`serving_tick_hbm_bytes`."""
    _, active = model_params(cfg)
    w_bytes = active * (weight_bits / 8 if weight_bits else 2)
    kv_elem_bytes = 1 if kv_bits == 8 else 2
    write = n_rows * padded_len * serving_kv_token_elems(cfg) * kv_elem_bytes
    return float(w_bytes + write)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    memory_s_kernelized: float  # minus (s×s) attention traffic a fused
    #                             Pallas flash kernel keeps in VMEM
    collective_s: float
    dcn_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_device: float
    useful_ratio: float
    flops_by_dtype: dict
    hbm_gb_per_device: float
    wire_gb_per_device: float
    notes: str = ""

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s, self.dcn_s)

    @property
    def bound_time_kernelized(self) -> float:
        """Bound with the Pallas-flash memory term (s² traffic in VMEM)."""
        return max(self.compute_s, self.memory_s_kernelized,
                   self.collective_s, self.dcn_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the per-device compute roofline this step achieves
        if every term overlapped perfectly: useful_compute_time / bound."""
        if self.bound_time <= 0:
            return 0.0
        useful_s = (self.model_flops / self.chips) / HW().peak_bf16
        return useful_s / self.bound_time

    @property
    def roofline_fraction_kernelized(self) -> float:
        if self.bound_time_kernelized <= 0:
            return 0.0
        useful_s = (self.model_flops / self.chips) / HW().peak_bf16
        return useful_s / self.bound_time_kernelized

    def row(self) -> dict:
        return {k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in dataclasses.asdict(self).items()
                if k not in ("flops_by_dtype",)} | {
                    "bound_s": round(self.bound_time, 6),
                    "roofline_frac": round(self.roofline_fraction, 4),
                    "bound_s_kern": round(self.bound_time_kernelized, 6),
                    "roofline_frac_kern": round(
                        self.roofline_fraction_kernelized, 4)}


def roofline(metrics: HloMetrics, cfg: ModelConfig, cell: ShapeCell, *,
             mesh_name: str, chips: int, pod_size: int = 1,
             hw: HW = HW(), notes: str = "") -> RooflineReport:
    flops_int = sum(v for d, v in metrics.flops_by_dtype.items()
                    if d.startswith(("s8", "u8", "s4", "u4", "s16", "s32")))
    flops_fp = metrics.flops - flops_int
    compute_s = flops_fp / hw.peak_bf16 + flops_int / hw.peak_int8
    memory_s = metrics.hbm_bytes / hw.hbm_bw
    memory_s_kern = max(metrics.hbm_bytes - metrics.s2_bytes, 0.0) / hw.hbm_bw
    # pod-crossing collectives: groups spanning more devices than one pod's
    # mesh rows — heuristic: group size that equals the pod axis (2) or a
    # multiple that includes it (DESIGN.md §7)
    dcn_bytes = 0.0
    ici_bytes = 0.0
    per_pod_chips = chips // pod_size
    for group, b in metrics.wire_bytes_by_group.items():
        if pod_size > 1 and (group == pod_size or group > per_pod_chips
                             or group == chips):
            dcn_bytes += b
        else:
            ici_bytes += b
    collective_s = ici_bytes / hw.ici_bw
    dcn_s = dcn_bytes / hw.dcn_bw
    mf = model_flops(cfg, cell)
    hlo_flops = metrics.flops
    useful = (mf / chips) / hlo_flops if hlo_flops else 0.0
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s, "dcn": dcn_s}
    dominant = max(terms, key=terms.get)
    return RooflineReport(
        arch=cfg.name, shape=cell.name, mesh=mesh_name, chips=chips,
        compute_s=compute_s, memory_s=memory_s,
        memory_s_kernelized=memory_s_kern, collective_s=collective_s,
        dcn_s=dcn_s, dominant=dominant, model_flops=mf,
        hlo_flops_per_device=hlo_flops, useful_ratio=useful,
        flops_by_dtype=dict(metrics.flops_by_dtype),
        hbm_gb_per_device=metrics.hbm_bytes / 1e9,
        wire_gb_per_device=metrics.wire_bytes / 1e9, notes=notes)
