"""Serving driver: load (or train-and-fold) a model, quantize per the
paper's pipeline, and run the continuous-batching engine over a request
stream.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b \
        --reduced --requests 8 --max-new 16 --weight-bits 4

Observability (docs/observability.md): ``--trace-out t.jsonl`` streams
per-request span events (replayable offline with ``python -m repro.obs
t.jsonl``), ``--metrics-out m.json`` dumps the metrics-registry
snapshot, ``--quant-health N`` probes live activation health every N
ticks against the calibrated ranges, and ``--json`` swaps the human
report for one structured JSON document on stdout.

``--speculate K`` turns on draft-verify speculative decoding in the
paged engine (docs/speculative.md): K drafted tokens per slot verify in
ONE batched ragged dispatch per tick, greedy outputs bit-identical to
the plain path.

``--serve-http`` routes the same workload through the async streaming
front-end (repro.serving.frontend) over loopback — per-request
deadlines (``--deadline-s``), admission control (``--shed-queue-depth``
/ ``--shed-score``) and chunked prefill (``--prefill-chunk``) — and
prints the same reports from the same trace schema (docs/serving.md).

On a real cluster this runs under the production mesh with the sharding
rules from launch/sharding.py; the CPU path uses a (1,1) mesh with the
same code.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.core.transforms import TransformPlan
from repro.data import calibration_stream
from repro.launch import compat
from repro.launch.mesh import make_test_mesh
from repro.models.api import get_model
from repro.obs import Observability, QuantHealthSampler, format_summary
from repro.serving.engine import (EngineConfig, PagedServingEngine,
                                  PerSlotServingEngine, Request,
                                  ServingEngine)
from repro.serving.fold import collect_calibration, fold_quantize


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--checkpoint", default="",
                    help="checkpoint dir (else random init)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--weight-bits", type=int, default=4, choices=[4, 8])
    ap.add_argument("--act-bits", type=int, default=4, choices=[4, 8])
    ap.add_argument("--kv-bits", type=int, default=8, choices=[0, 8])
    ap.add_argument("--no-quant", action="store_true",
                    help="serve bf16 (baseline)")
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="smoothing migration strength (paper Eq. 4)")
    ap.add_argument("--auto-plan", action="store_true",
                    help="search a per-layer transform/α plan from the "
                         "calibration stream (repro.autoplan)")
    ap.add_argument("--plan-json", default="",
                    help="load a saved LayerwisePlan JSON instead of the "
                         "fixed §V plan (overridden by --auto-plan)")
    ap.add_argument("--use-kernels", default="auto",
                    choices=["auto", "never", "interpret"],
                    help="matmul backend (resolved by kernels.ops: auto = "
                         "fused Pallas qlinear on TPU, XLA elsewhere)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--engine", default="paged",
                    choices=["paged", "batched", "per-slot"],
                    help="paged: paged KV pool + in-engine batched prefill "
                         "(default); batched: dense slot-major cache, ONE "
                         "(max_slots, 1) decode dispatch per tick; "
                         "per-slot: the original one-dispatch-per-active-"
                         "slot baseline")
    ap.add_argument("--page-size", type=int, default=64,
                    help="paged engine: tokens per KV page")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged engine: interleave bounded prefill chunks "
                         "of this many tokens with decode ticks so a long "
                         "admit can't stall streaming tokens (0 = whole-"
                         "prompt prefill; dense-transformer family only)")
    ap.add_argument("--serve-http", action="store_true",
                    help="serve the workload through the async HTTP "
                         "front-end (repro.serving.frontend) over loopback "
                         "instead of the offline run() loop — same engine, "
                         "same trace schema, same report")
    ap.add_argument("--http-port", type=int, default=0,
                    help="--serve-http bind port (0 = ephemeral)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="--serve-http per-request deadline in seconds "
                         "(0 = none); expired requests cancel mid-stream")
    ap.add_argument("--shed-queue-depth", type=int, default=64,
                    help="--serve-http admission control: hard queue-depth "
                         "cap before requests shed with HTTP 503")
    ap.add_argument("--shed-score", type=float, default=32.0,
                    help="--serve-http admission control: shed when queue "
                         "depth × pool occupancy crosses this bound")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="paged engine: shared pool size in pages (0 = "
                         "zero-overcommit sizing, max_slots × pages/slot; "
                         "smaller pools overcommit and rely on admission "
                         "backpressure)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged engine: share identical prompt prefixes "
                         "page-granularly across requests (refcounted "
                         "pages, copy-on-write on divergence, LRU "
                         "eviction under pool pressure — docs/serving.md "
                         "§Prefix caching; dense-transformer family only)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="paged engine: draft K tokens per slot per tick "
                         "(self-draft) and verify all K+1 positions in ONE "
                         "batched ragged dispatch; greedy outputs stay "
                         "bit-identical to K=0 (docs/speculative.md; "
                         "dense-transformer family only)")
    ap.add_argument("--trace-out", default="",
                    help="stream per-request span events (submit/admit/"
                         "prefill/first-token/tick/preempt/retire) to this "
                         "JSONL file; summarize offline with "
                         "`python -m repro.obs <file>`")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics-registry snapshot (counters/"
                         "gauges/histograms) to this JSON file")
    ap.add_argument("--quant-health", type=int, default=0, metavar="N",
                    help="every N engine ticks, probe one live request's "
                         "activations against the calibrated ranges "
                         "(absmax / clip fraction / Eq.-2 difficulty); "
                         "0 = off (no extra dispatches)")
    ap.add_argument("--nan-guard", action="store_true",
                    help="per-tick finite check on decode logits: a "
                         "NaN/Inf row retires its request with status "
                         "failed (pages freed, guard trace event) instead "
                         "of streaming garbage (docs/resilience.md)")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic fault-injection schedule: a "
                         "FaultPlan JSON file (or inline JSON list) "
                         "replayed at the instrumented sites "
                         "(docs/resilience.md)")
    ap.add_argument("--json", action="store_true",
                    help="emit ONE structured JSON report on stdout "
                         "instead of the human tables")
    args = ap.parse_args(argv)
    say = (lambda *a, **k: None) if args.json else print

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    mesh = make_test_mesh()
    key = jax.random.PRNGKey(0)

    with compat.set_mesh(mesh):
        params = model.init(key, cfg)
        if args.checkpoint:
            ck = Checkpointer(args.checkpoint)
            restored = ck.restore_latest({"p": params})
            if restored:
                params = restored[0]["p"]
                say(f"restored checkpoint step {restored[1]}")

        policy = None
        if not args.no_quant:
            t0 = time.time()
            stats = collect_calibration(
                model, params, cfg,
                list(calibration_stream(cfg, n_batches=2, batch=2, seq=64)),
                keep_samples=128 if args.auto_plan else 0)
            policy = QuantPolicy(
                weight_bits=args.weight_bits, act_bits=args.act_bits,
                kv_cache_bits=args.kv_bits or None,
                use_kernels=args.use_kernels)
            if args.auto_plan:
                from repro.autoplan import SearchConfig, search_plan

                plan, _ = search_plan(
                    params, cfg, stats,
                    search=SearchConfig(weight_bits=args.weight_bits,
                                        act_bits=args.act_bits),
                    base=TransformPlan(alpha=args.alpha))
                plan_desc = "searched per-layer plan (repro.autoplan)"
            elif args.plan_json:
                from repro.autoplan import LayerwisePlan

                plan = LayerwisePlan.load(args.plan_json)
                # a mismatched plan would silently fall back to its base
                # for every stack — fail loudly instead (the planned stack
                # excludes MoE leading dense layers)
                planned_stack = cfg.num_layers - cfg.first_dense_layers
                if plan.num_layers != planned_stack:
                    ap.error(f"{args.plan_json} plans {plan.num_layers} "
                             f"layers but {cfg.name}'s planned stack has "
                             f"{planned_stack} — searched on a different "
                             "config?")
                if plan.arch and plan.arch != cfg.name:
                    say(f"WARNING: plan searched on {plan.arch!r}, "
                          f"serving {cfg.name!r}")
                plan_desc = f"LayerwisePlan from {args.plan_json}"
            else:
                plan = TransformPlan(alpha=args.alpha)
                plan_desc = "SmoothRotation on down_proj — paper §V"
            params = fold_quantize(params, cfg, policy=policy, plan=plan,
                                   stats=stats)
            say(f"calibrated + folded W{args.weight_bits}A{args.act_bits} "
                  f"in {time.time() - t0:.1f}s (plan: {plan_desc})")

        qh = None
        if args.quant_health:
            qh = QuantHealthSampler(
                model, params, cfg, policy=policy, every=args.quant_health,
                reference=stats if not args.no_quant else None,
                max_context=args.max_len)
        obs = Observability(trace_path=args.trace_out or None,
                            quant_health=qh)
        faults = None
        if args.fault_plan:
            from repro.resilience.faults import FaultPlan

            text = args.fault_plan
            if os.path.exists(text):
                with open(text) as fh:
                    text = fh.read()
            faults = FaultPlan.from_json(text)
            say(f"fault plan armed: {faults}")
        # ONE EngineConfig carries every engine knob (docs/api.md); the
        # non-paged engines ignore the page-pool fields
        econfig = EngineConfig(
            max_slots=args.max_slots, max_len=args.max_len, policy=policy,
            kv_bits=args.kv_bits or None, page_size=args.page_size,
            n_pages=args.pool_pages or None,
            prefill_chunk=args.prefill_chunk or None, obs=obs,
            faults=faults, nan_guard=args.nan_guard,
            prefix_cache=args.prefix_cache, spec_k=args.speculate)
        engine_cls = {"paged": PagedServingEngine, "batched": ServingEngine,
                      "per-slot": PerSlotServingEngine}[args.engine]
        eng = engine_cls(model, params, cfg, config=econfig)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=(4 + i % 13,))
                   for i in range(args.requests)]
        if args.prefix_cache:
            # the workload shape the cache exists for: every request
            # opens with the same "system prompt", unique tail per user;
            # whole pages, capped so prompt + tail + decode fit max_len
            max_tail = max(len(p) for p in prompts)
            headroom = args.max_len - max_tail - args.max_new
            sys_len = max(args.page_size,
                          min(4, headroom // args.page_size) * args.page_size)
            system = rng.integers(0, cfg.vocab_size, size=(sys_len,))
            prompts = [np.concatenate([system, p]) for p in prompts]
        if args.serve_http:
            import asyncio

            from repro.serving.frontend import ServingFrontend, http_generate

            async def _drive():
                fe = ServingFrontend(
                    eng, port=args.http_port,
                    max_queue_depth=args.shed_queue_depth,
                    shed_score=args.shed_score,
                    default_deadline_s=args.deadline_s or None)
                async with fe:
                    say(f"HTTP front-end on {fe.host}:{fe.port}")
                    return await asyncio.gather(*[
                        http_generate(fe.host, fe.port, {
                            "prompt": p.tolist(),
                            "max_new_tokens": args.max_new,
                            "temperature": args.temperature})
                        for p in prompts])

            t0 = time.time()
            results = asyncio.run(_drive())
            dt = time.time() - t0
            done = [Request(uid=r["body"]["uid"], prompt=prompts[i],
                            out_tokens=r["body"]["tokens"],
                            done=True, cancelled=r["body"]["cancelled"])
                    for i, r in enumerate(results) if r["status"] == 200]
            eng.run_stats = st = eng.stats()
        else:
            for i, p in enumerate(prompts):
                eng.submit(Request(uid=i, prompt=p,
                                   max_new_tokens=args.max_new,
                                   temperature=args.temperature))
            t0 = time.time()
            done = eng.run(max_ticks=10_000)
            dt = time.time() - t0
            st = eng.run_stats
        obs.close()
        if args.metrics_out:
            with open(args.metrics_out, "w") as fh:
                json.dump(obs.registry.snapshot(), fh, indent=1,
                          sort_keys=True)
        from repro.kernels import ops

        summary = obs.summary()
        if args.json:
            # one machine-readable document: run stats (minus the bulky
            # per-request map), the obs latency summary, and the
            # process-wide dispatch-resolution tally
            report = {
                "arch": cfg.name, "engine": args.engine,
                "requests_served": len(done),
                "wall_s": dt,
                "decode_tok_per_s": st["decode_tokens"] / max(dt, 1e-9),
                "run_stats": {k: v for k, v in st.items()
                              if k != "per_request"},
                "obs": summary,
                "dispatch_resolutions": ops.dispatch_resolutions(),
            }
            print(json.dumps(report, indent=1, sort_keys=True))
            return
        print(f"served {len(done)}/{args.requests} requests, "
              f"{st['decode_tokens']} tokens in {dt:.2f}s "
              f"({st['decode_tokens'] / max(dt, 1e-9):.1f} tok/s, "
              f"{args.engine} engine: {st['decode_dispatches']} decode "
              f"dispatches over {st['ticks']} ticks = "
              f"{st['dispatches_per_tick']:.2f}/tick, plus "
              f"{st['prefill_dispatches']} prefill dispatches, "
              f"kernel backend: {eng.kernel_backend})")
        if "n_pages" in st:
            print(f"  page pool: {st['peak_pages_in_use']}/{st['n_pages']} "
                  f"pages at peak ({100 * st['page_occupancy_peak']:.0f}% "
                  f"occupancy, page size {st['page_size']}), "
                  f"paged attention: {st['paged_attention_backend']}")
        if st.get("prefix", {}).get("enabled"):
            px = st["prefix"]
            print(f"  prefix cache: {px['hits']}/{px['hits'] + px['misses']} "
                  f"hits ({100 * px['hit_rate']:.0f}%), "
                  f"{px['shared_pages']} shared pages, "
                  f"{px['saved_prefill_tokens']} prefill tokens saved, "
                  f"{px['cow_copies']} COW copies, "
                  f"{px['evictions']} evictions")
        if st.get("spec", {}).get("enabled"):
            sp = st["spec"]
            print(f"  speculative (k={sp['k']}, "
                  f"{'self' if sp['self_draft'] else 'separate'}-draft): "
                  f"{sp['accepted']}/{sp['drafted']} drafts accepted "
                  f"({100 * sp['acceptance_rate']:.0f}%), "
                  f"{sp['emitted_tokens']} tokens over "
                  f"{sp['verify_dispatches']} verify dispatches = "
                  f"{sp['accepted_per_dispatch']:.2f} tokens/dispatch")
        for r in done[:3]:
            print(f"  req {r.uid}: {r.out_tokens[:12]}...")
        print()
        print(format_summary(summary))
        print(f"backend resolutions (kernels.ops): "
              f"{ops.dispatch_resolutions()}")
        if args.trace_out:
            print(f"trace: {args.trace_out} "
                  f"(summarize: python -m repro.obs {args.trace_out})")


if __name__ == "__main__":
    main()
