"""Sharding rules: param/batch/cache PartitionSpecs with divisibility
fallbacks (DESIGN.md §4).

Strategy: 2D-sharded weights — tensor-parallel over ``model`` on the
"wide" axis, FSDP over ``data`` on the other — so llama3-405B's bf16
params land at ~3.2 GB/chip on a 256-chip pod.  Optimizer state inherits
param sharding (ZeRO-3 by construction).  Activations: batch over
(pod, data).  MoE expert stacks: EP over ``model``, FSDP over ``data``.
Anything non-divisible degrades to replication on that axis (the helper
checks divisibility instead of crashing at lower time).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import dp_axes

__all__ = ["param_specs", "batch_spec", "cache_specs", "axis_if"]


def axis_if(mesh, axis: str | tuple[str, ...] | None, dim: int):
    """Return ``axis`` if it exists in the mesh and divides ``dim``."""
    if axis is None:
        return None
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    size = 1
    for a in axes:
        if a not in mesh.axis_names:
            return None
        size *= mesh.shape[a]
    if dim % size:
        return None
    return axes[0] if len(axes) == 1 else axes


import threading

_STRATEGY = threading.local()


def set_strategy(name: str):
    """Sharding strategy: '2d' (TP over model + FSDP over data, default)
    or 'fsdp' (NO tensor parallelism — batch over ALL axes, weights fully
    sharded over all axes jointly).  'fsdp' wins for models whose
    per-layer compute is too small to amortize TP activation collectives
    (§Perf: stablelm train collective term 13s → weight-AG only)."""
    _STRATEGY.name = name


def get_strategy() -> str:
    return getattr(_STRATEGY, "name", "2d")


def _linear_spec(mesh, shape, *, wide: str, lead: int = 0):
    """Spec for a (c_in, c_out) linear with `lead` stacked leading axes.
    wide='col' → TP on c_out/FSDP on c_in; wide='row' → the transpose."""
    c_in, c_out = shape[-2], shape[-1]
    if get_strategy() == "fsdp":
        all_axes = tuple(mesh.axis_names)
        rows = axis_if(mesh, all_axes, c_in)
        if rows is not None:
            return P(*([None] * lead), rows, None)
        return P(*([None] * lead), None, axis_if(mesh, all_axes, c_out))
    if wide == "col":
        rows, cols = axis_if(mesh, "data", c_in), axis_if(mesh, "model", c_out)
    else:
        rows, cols = axis_if(mesh, "model", c_in), axis_if(mesh, "data", c_out)
    return P(*([None] * lead), rows, cols)


def _leaf_spec(mesh, path: tuple[str, ...], leaf) -> P:
    """Rule table keyed on param-tree path names."""
    name = path[-1]
    shape = leaf.shape
    # scanned stacks have a leading L axis; expert stacks an E axis too.
    # Optimizer-state trees mirror params under a mu/nu prefix, so look
    # anywhere in the path for the stacked-layer containers.
    stacked = any(p in ("layers", "moe_layers", "dense_layers")
                  for p in path[:-1])
    lead = 1 if stacked else 0
    if name in ("g", "b", "A_log", "D", "dt_bias", "conv_b"):
        return P(*([None] * len(shape)))
    if name == "e":  # embedding (V, d): shard vocab only — a d-sharded
        # table makes the partitioner emit fragile gather slices when the
        # output is constrained to replicated-d (verifier failure seen on
        # stablelm train_4k); vocab-sharded gathers lower to mask+psum.
        return P(axis_if(mesh, ("data", "model"), shape[0]), None)
    if name == "conv_w":
        return P(*([None] * lead), None,
                 axis_if(mesh, "model", shape[-1]))
    if "router" in path:
        return P(*([None] * len(shape)))
    if name in ("wg", "wu") and len(shape) - lead == 3:  # experts (E, d, f)
        return P(*([None] * lead), axis_if(mesh, "model", shape[-3]),
                 axis_if(mesh, "data", shape[-2]), None)
    if name == "wd" and len(shape) - lead == 3:
        return P(*([None] * lead), axis_if(mesh, "model", shape[-3]),
                 None, axis_if(mesh, "data", shape[-1]))
    if name in ("wq", "wk", "wv", "wg", "wu", "wdkv"):
        return _linear_spec(mesh, shape, wide="col", lead=lead)
    if name in ("wo", "wd"):
        return _linear_spec(mesh, shape, wide="row", lead=lead)
    if name == "wukv":  # (lora, H(nd+vd)): TP cols
        return _linear_spec(mesh, shape, wide="col", lead=lead)
    if name == "in_proj":
        return _linear_spec(mesh, shape, wide="col", lead=lead)
    if name == "out_proj":
        return _linear_spec(mesh, shape, wide="row", lead=lead)
    if name == "w" and len(shape) >= 2:  # lm_head & generic linears
        return _linear_spec(mesh, shape, wide="col", lead=max(0, len(shape) - 2))
    # quantized leaves: w_q mirrors the source linear, scales follow cols
    return P(*([None] * len(shape)))


def param_specs(params: Any, cfg: ModelConfig, mesh) -> Any:
    """Spec pytree mirroring ``params`` (works for bf16 & quantized trees)."""

    def spec_for(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "name", k)))
                      for k in path)
        # QuantizedWeight fields: map w_q/scale under the owning linear name
        if names[-1] in ("w_q", "scale", "smooth"):
            owner = names[-3] if len(names) >= 3 else names[0]
            base = _leaf_spec(mesh, names[:-2] + (owner,),
                              _FakeShape(_owner_shape(leaf, names)))
            if names[-1] == "w_q":
                return base
            if names[-1] == "smooth":
                return P(*([None] * leaf.ndim))
            # scale: (..., 1, c_out) follows the base's last axis
            return P(*([None] * (leaf.ndim - 1)), base[-1] if len(base) else None)
        return _leaf_spec(mesh, names, leaf)

    return jax.tree_util.tree_map_with_path(spec_for, params)


class _FakeShape:
    def __init__(self, shape):
        self.shape = shape


def _owner_shape(leaf, names):
    return leaf.shape


def batch_spec(mesh, batch_size: int) -> P:
    axes = (tuple(mesh.axis_names) if get_strategy() == "fsdp"
            else dp_axes(mesh))
    dp = axis_if(mesh, axes, batch_size)
    # batch too small for the full dp product: try 'data' alone, else replicate
    if dp is None:
        dp = axis_if(mesh, dp_axes(mesh), batch_size)
    if dp is None:
        dp = axis_if(mesh, "data", batch_size)
    return P(dp, None)


def cache_specs(cfg: ModelConfig, mesh, cache) -> Any:
    """KV/SSM cache specs: batch over dp where divisible; heads over
    model; batch=1 long-context shards the sequence axis over data
    (sequence parallelism) for KV caches."""
    dp = dp_axes(mesh)

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "name", "")))
        if leaf.ndim == 0:
            return P()
        if name in ("k", "v", "k_scale", "v_scale"):
            L, b, S, h = leaf.shape[:4]
            bax = axis_if(mesh, dp, b) or axis_if(mesh, "data", b)
            hax = axis_if(mesh, "model", h)
            sax = None
            if hax is None:
                # few-KV-head archs (GQA kv < model): shard the SEQUENCE
                # over model — the attention S-reduction parallelizes and
                # probs·V psums a tiny (b,h,1,hd) tensor, vs head_dim
                # sharding which forces a full-cache gather per layer
                # (§Perf cell C: 151 GB → ~2 GB wire)
                sax = axis_if(mesh, "model", S)
            if bax is None and sax is None:  # batch=1 long-context
                sax = axis_if(mesh, "data", S)
            return P(None, bax, sax, hax, *([None] * (leaf.ndim - 4)))
        if name == "ssm":  # (L, b, h, p, n)
            L, b, h = leaf.shape[:3]
            bax = axis_if(mesh, dp, b) or axis_if(mesh, "data", b)
            return P(None, bax, axis_if(mesh, "model", h), None, None)
        if name == "conv":  # (L, b, k-1, c)
            L, b = leaf.shape[:2]
            bax = axis_if(mesh, dp, b) or axis_if(mesh, "data", b)
            return P(None, bax, None, axis_if(mesh, "model", leaf.shape[-1]))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, cache)
