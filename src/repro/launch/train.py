"""Training driver: sharded train_step factory + CLI loop.

Features (DESIGN.md §4): pjit 2D sharding (FSDP×TP), gradient
accumulation over microbatches (lax.scan — bounds activation memory for
the 405B train cell), remat on the layer scan (per config), optional
int8 gradient compression with error feedback, checkpoint/restart,
preemption-safe saves, and XLA latency-hiding flags for compute/comm
overlap on TPU.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --steps 100 --batch 8 --seq 128 --reduced
"""

from __future__ import annotations

import argparse
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import batch_spec, param_specs
from repro.models.api import get_model
from repro.optim import adamw, apply_error_feedback, warmup_cosine
from repro.launch import compat

# XLA flags a production TPU launcher sets for compute/comm overlap; they
# are inert on CPU and applied by the cluster launcher environment.
TPU_XLA_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
)


def make_train_step(model, cfg: ModelConfig, opt, *, microbatches: int = 1,
                    grad_compression: bool = False):
    """Returns train_step(params, opt_state, batch, step, key) →
    (params, opt_state, metrics).  ``batch`` leaves are (B, ...) global;
    with microbatches=A they are reshaped to (A, B/A, ...) and grads
    accumulated under lax.scan (memory ∝ B/A)."""

    def loss_fn(p, mb):
        return model.train_loss(p, cfg, mb)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mbs = jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                *x.shape[1:]), batch)

        def acc(carry, mb):
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            loss_acc, grads_acc = carry
            return (loss_acc + l,
                    jax.tree.map(jnp.add, grads_acc, g)), ()

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params))
        (loss, grads), _ = jax.lax.scan(acc, zero, mbs)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch, step, key):
        loss, grads = grads_of(params, batch)
        err = opt_state.err
        if grad_compression:
            grads, err = apply_error_feedback(grads, err, key)
        params, opt_state, metrics = opt.step(params, opt_state, grads, step)
        opt_state = opt_state._replace(err=err)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def shard_train_fns(model, cfg: ModelConfig, opt, mesh, global_batch: int,
                    seq: int, *, microbatches: int = 1,
                    grad_compression: bool = False):
    """jit-compiled (init_fn, train_step) with explicit shardings."""
    pspec_of = lambda tree: param_specs(tree, cfg, mesh)

    def init_all(key):
        params = model.init(key, cfg)
        return params, opt.init(params)

    params_shape, opt_shape = jax.eval_shape(init_all, jax.random.PRNGKey(0))
    pspecs = pspec_of(params_shape)
    ospecs = pspec_of(opt_shape)
    bspec = batch_spec(mesh, global_batch)
    if cfg.embeds_input and cfg.family in ("audio", "vlm"):
        bspecs = {"embeds": P(*bspec, None), "labels": bspec}
    else:
        bspecs = {"tokens": bspec, "labels": bspec}

    step_fn = make_train_step(model, cfg, opt, microbatches=microbatches,
                              grad_compression=grad_compression)
    train_step = jax.jit(
        step_fn,
        in_shardings=compat.jit_shardings(
            mesh, (pspecs, ospecs, bspecs, P(), P())),
        out_shardings=compat.jit_shardings(mesh, (pspecs, ospecs, P())),
        donate_argnums=(0, 1),
    )
    init_fn = jax.jit(init_all,
                      out_shardings=compat.jit_shardings(mesh, (pspecs, ospecs)))
    return init_fn, train_step, (pspecs, ospecs, bspecs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU)")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--data", default="synthetic")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    mesh = make_test_mesh()  # cluster launchers construct the real mesh
    opt = adamw(warmup_cosine(args.lr, 10, args.steps),
                error_feedback=args.grad_compression)

    from repro.data.pipeline import synthetic_batches

    with compat.set_mesh(mesh):
        init_fn, train_step, _ = shard_train_fns(
            model, cfg, opt, mesh, args.batch, args.seq,
            microbatches=args.microbatches,
            grad_compression=args.grad_compression)
        key = jax.random.PRNGKey(0)
        params, opt_state = init_fn(key)
        start_step = 0
        ckpt = None
        if args.checkpoint_dir:
            from repro.checkpoint import Checkpointer

            ckpt = Checkpointer(args.checkpoint_dir, keep=3)
            restored = ckpt.restore_latest({"params": params, "opt": opt_state})
            if restored is not None:
                state, start_step = restored
                params, opt_state = state["params"], state["opt"]
                print(f"resumed from step {start_step}")
        t0 = time.time()
        for step, batch in enumerate(
                synthetic_batches(cfg, args.batch, args.seq, start=start_step),
                start=start_step):
            if step >= args.steps:
                break
            params, opt_state, metrics = train_step(
                params, opt_state, batch, jnp.asarray(step),
                jax.random.fold_in(key, step))
            if step % 5 == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
            if ckpt and step and step % args.save_every == 0:
                ckpt.save({"params": params, "opt": opt_state}, step)
        if ckpt:
            ckpt.save({"params": params, "opt": opt_state}, args.steps)
    print("done")


if __name__ == "__main__":
    main()
