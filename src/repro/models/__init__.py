"""Model zoo: dense GQA transformer, MoE (+MLA), Mamba2 SSD, Zamba2
hybrid, audio/VLM backbones.  Uniform API via repro.models.api."""
