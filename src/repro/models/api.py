"""Uniform model API: family → module dispatch.

Every family module exposes:
    init(key, cfg)                              → params
    forward(params, cfg, tokens|embeds=, policy=) → logits[, aux]
    train_loss(params, cfg, batch)              → scalar
    make_cache(cfg, batch, max_len, bits=)      → cache pytree
    prefill(params, cfg, tokens, cache, policy=) → (logits, cache)
    decode_step(params, cfg, tokens, cache, policy=) → (logits, cache)
    forward_with_taps(params, cfg, ...)         → (logits, taps)
"""

from __future__ import annotations

from types import ModuleType

from repro.configs.base import ModelConfig
from repro.models import hybrid, mamba2, moe, transformer

_FAMILY: dict[str, ModuleType] = {
    "dense": transformer,
    "audio": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": mamba2,
    "hybrid": hybrid,
}


def get_model(cfg: ModelConfig) -> ModuleType:
    return _FAMILY[cfg.family]


def param_count(params) -> int:
    import jax

    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Active params per token (MoE: only top-k experts count)."""
    total = param_count(params)
    if not cfg.num_experts:
        return total
    import jax
    import jax.numpy as jnp  # noqa: F401

    expert_leaves = 0
    moe_layers = params.get("moe_layers", {})
    for name in ("wg", "wu", "wd"):
        for layer in jax.tree.leaves({k: v for k, v in _iter_moe(moe_layers, name)}):
            expert_leaves += layer.size
    inactive_frac = 1.0 - cfg.experts_per_tok / cfg.num_experts
    return int(total - expert_leaves * inactive_frac)


def _iter_moe(tree, name):
    if isinstance(tree, dict):
        for k, v in tree.items():
            if k == name and k in ("wg", "wu", "wd"):
                yield name + str(id(v)), v
            elif isinstance(v, dict):
                yield from _iter_moe(v, name)
