"""Shared model components: norms, RoPE, GQA attention, SwiGLU, linears.

Design notes
------------
* Pure functional: params are plain dict pytrees; configs are static.
* Every linear goes through :func:`dense`, which executes either the bf16
  weight (training) or a folded+quantized :class:`QuantizedWeight`
  (serving) — the paper's technique is a first-class execution mode, not
  a bolt-on.
* Layer stacks run under ``jax.lax.scan`` with params stacked on axis 0
  (constant-size HLO for 126-layer models; remat policy per config).
* KV caches support bf16 or int8 (per-token-per-head scales) storage.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import QuantPolicy, qlinear
from repro.launch import compat

Params = dict[str, Any]

__all__ = [
    "dense", "init_linear", "rms_norm", "init_rms", "rope_angles", "apply_rope",
    "attention_scores", "init_attn", "attn_apply", "init_mlp", "mlp_apply",
    "init_embedding", "embed", "cross_entropy", "KVCache", "init_kv_cache",
    "cache_update", "cache_read", "stack_layer_params", "scan_layers",
    "batch_slot_cache", "cache_at", "write_slot",
    "PagedKVCache", "init_paged_kv_cache", "pages_per_slot", "paged_update",
    "paged_view", "quant_roundtrip_kv", "gather_page_rows", "take_last_valid",
    "flash_decode", "paged_attn_backend",
]


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def shard_act(x: jax.Array, *, sp: bool = False) -> jax.Array:
    """Constrain an activation to batch-over-dp, replicated elsewhere.

    Anchors GSPMD propagation at block boundaries so the residual stream
    never silently picks up a model-axis sharding (which would insert
    per-layer activation all-gathers).  No-op without an active mesh or
    when the batch doesn't divide the dp axes.

    ``sp=True`` (sequence parallelism, Korthikanti et al.): additionally
    shard the sequence axis over 'model' between blocks — GSPMD then
    replaces each TP all-reduce with a reduce-scatter + all-gather pair
    at half the wire bytes, and layer-boundary residuals shrink 16×.
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names or mesh.size == 1:
        return x
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import get_strategy

    dp = (tuple(mesh.axis_names) if get_strategy() == "fsdp"
          else tuple(a for a in mesh.axis_names if a != "model"))
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    if not dp or x.shape[0] % size:
        return x
    seq_axis = None
    if (sp and x.ndim == 3 and "model" in mesh.axis_names
            and "model" not in dp
            and x.shape[1] % mesh.shape["model"] == 0):
        seq_axis = "model"
    spec = P(dp if len(dp) > 1 else dp[0], seq_axis,
             *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                scale: float | None = None, dtype=jnp.bfloat16) -> Params:
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(x: jax.Array, p: Params, policy: QuantPolicy | None = None) -> jax.Array:
    """Apply a linear from either a bf16 or a quantized param leaf.

    Quantized leaves dispatch through ``qlinear`` → ``kernels.ops``: on
    TPU the whole smooth→rotate→quantize→matmul chain is ONE fused
    Pallas kernel per linear (docs/kernels.md) — this is the call site
    the engine's ``(max_slots, 1)`` decode tick bottoms out in."""
    if "qw" in p:
        y = qlinear(x, p["qw"], policy or QuantPolicy())
    else:
        y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_rms(d: int, dtype=jnp.bfloat16) -> Params:
    return {"g": jnp.ones((d,), dtype)}


def rms_norm(x: jax.Array, p: Params | None, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    y = xf * inv
    if p is not None:  # folded (weightless) norms pass None — DESIGN.md §3
        y = y * p["g"].astype(jnp.float32)
    return y.astype(x.dtype)


def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """cos/sin tables for given integer positions, shape (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (b, s, h, d). cos/sin: (b, s, d/2) or (s, d/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


_CHUNK_Q_THRESHOLD = 8192   # switch to query-chunked attention beyond this
_CHUNK_Q = 512
_FLASH_KV_CHUNK = 1024
_FLASH_Q_CHUNK = 512


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    length=None, bf16_io: bool = True):
    """Online-softmax (FlashAttention-style) GQA in pure XLA.

    Double scan over (q-chunk × kv-chunk) with running (max, sum, acc)
    carries: the (sq × sk) probability matrix NEVER materializes in HBM —
    traffic drops from O(s²) to O(s·d) per pass.  This is the XLA twin of
    the Pallas flash kernel a TPU build fuses; block sizes follow the
    same VMEM reasoning (q 512 × kv 1024 tiles).  Exact (not approximate):
    matches naive attention to bf16 rounding.
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    dv = v.shape[-1]
    qb = _FLASH_Q_CHUNK if sq % _FLASH_Q_CHUNK == 0 else sq
    kb = _FLASH_KV_CHUNK if sk % _FLASH_KV_CHUNK == 0 else sk
    scale = d ** -0.5
    qg = q.reshape(b, sq, hkv, g, d)
    nq, nk = sq // qb, sk // kb
    k_pos0 = jnp.arange(kb)
    q_pos0 = jnp.arange(qb)

    def q_chunk(_, qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * qb, qb, 1)
        q_pos = q_pos0 + qi * qb + q_offset

        def kv_chunk(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * kb, kb, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * kb, kb, 1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            k_pos = k_pos0 + ki * kb
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if length is not None:
                mask &= k_pos[None, :] < jnp.asarray(length).reshape(-1)[0]
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            p_cast = p.astype(jnp.bfloat16) if bf16_io else p
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p_cast,
                            vc if bf16_io else vc.astype(jnp.float32))
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(
                jnp.float32)
            return (m_new, l_new, acc_new), ()

        init = (jnp.full((b, hkv, g, qb), -jnp.inf, jnp.float32),
                jnp.zeros((b, hkv, g, qb), jnp.float32),
                jnp.zeros((b, hkv, g, qb, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_chunk, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (b, hkv, g, qb, dv) → (b, qb, hq, dv)
        out = jnp.moveaxis(out, 3, 1).reshape(b, qb, hq, dv)
        return (), out

    _, chunks = jax.lax.scan(q_chunk, (), jnp.arange(nq))
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, sq, hq, dv)
    return out.astype(q.dtype)


def attention_scores(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, q_offset: jax.Array | int = 0,
                     window: int = 0, length: jax.Array | None = None,
                     bf16_io: bool = False) -> jax.Array:
    """Grouped-query attention core.

    q: (b, sq, hq, d); k/v: (b, sk, hkv, d); hq % hkv == 0.
    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``window``: sliding-window size (0 = full).  ``length``: valid kv
    prefix length for decode against a preallocated cache.  ``q_offset``
    and ``length`` may each be a scalar or a (b,) vector of per-row
    values (slot-major batched serving, incl. multi-token chunks).
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    if sq > _CHUNK_Q_THRESHOLD and sq % _CHUNK_Q == 0:
        # Long-prefill path: scan over query chunks so the logits tensor
        # is (chunk, sk) not (sq, sk) — O(sq·sk) FLOPs, O(chunk·sk) memory.
        def one_chunk(carry, idx):
            qc = jax.lax.dynamic_slice_in_dim(q, idx * _CHUNK_Q, _CHUNK_Q, 1)
            oc = attention_scores(
                qc, k, v, causal=causal,
                q_offset=(jnp.asarray(q_offset) + idx * _CHUNK_Q),
                window=window, length=length, bf16_io=bf16_io)
            return carry, oc
        _, chunks = jax.lax.scan(one_chunk, (),
                                 jnp.arange(sq // _CHUNK_Q))
        # chunks: (n, b, CHUNK, hq, d) → (b, sq, hq, d)
        return jnp.moveaxis(chunks, 0, 1).reshape(b, sq, hq, v.shape[-1])
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    # q_offset and length may each be a scalar or a (b,) per-row vector
    # (slot-major batched serving); masks carry a leading broadcast axis
    # of size B ∈ {1, b} so every combination shares one code path.
    q_off = jnp.asarray(q_offset).reshape(-1)          # (1,) or (b,)
    larr = None if length is None else jnp.asarray(length).reshape(-1)
    B = max(q_off.size, 1 if larr is None else larr.size)
    q_pos = q_off[:, None] + jnp.arange(sq)[None]      # (1|b, sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((B, sq, sk), bool)
    if causal:
        mask &= k_pos[None, None, :] <= q_pos[:, :, None]
    if window:
        mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
    if larr is not None:
        mask &= k_pos[None, None, :] < larr[:, None, None]
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    if bf16_io:  # cast before P·V: cotangents (and any TP collectives on
        # them) stay bf16 — halves backward wire bytes (§Perf)
        probs = probs.astype(jnp.bfloat16)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.bfloat16))
    else:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, v.shape[-1]).astype(q.dtype)  # v dim ≠ qk dim in MLA


def init_attn(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": init_linear(ks[0], cfg.d_model, hq * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], cfg.d_model, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], cfg.d_model, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], hq * hd, cfg.d_model, dtype=dtype),
        "ln": init_rms(cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# KV cache (bf16 or int8-quantized storage)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Per-stack KV cache; leading axis = layer (scanned).

    int8 mode stores codes + per (b, s, h) scales — 2× HBM saving, the
    serving-path default (QuantPolicy.kv_cache_bits = 8).
    """

    k: jax.Array                     # (L, b, S, hkv, d) bf16|int8
    v: jax.Array
    k_scale: jax.Array | None        # (L, b, S, hkv, 1) f32 when int8
    v_scale: jax.Array | None
    length: jax.Array                # () int32 — tokens filled

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, max_len: int,
                  *, bits: int | None = None, dtype=jnp.bfloat16,
                  head_dim: int | None = None, kv_heads: int | None = None) -> KVCache:
    hkv = cfg.num_kv_heads if kv_heads is None else kv_heads
    hd = cfg.head_dim if head_dim is None else head_dim
    if cfg.attn_window:
        max_len = min(max_len, cfg.attn_window)
    shape = (n_layers, batch, max_len, hkv, hd)
    if bits == 8:
        return KVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros((*shape[:4], 1), jnp.float32),
            v_scale=jnp.zeros((*shape[:4], 1), jnp.float32),
            length=jnp.zeros((), jnp.int32),
        )
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   k_scale=None, v_scale=None, length=jnp.zeros((), jnp.int32))


def _quant_kv(x: jax.Array):
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax) / 127.0
    return jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                    ).astype(jnp.int8), scale


def cache_update(layer_kv: dict, k_new: jax.Array, v_new: jax.Array,
                 length: jax.Array, *, window: int = 0):
    """Write new k/v at position ``length`` into one layer's cache slice.

    layer_kv: dict(k, v[, k_scale, v_scale]) with shapes (b, S, h, d).
    ``length`` may be a scalar (all rows at the same position — train /
    single-sequence serving) or a (b,) vector of per-row positions (the
    slot-major batched decode, where every slot sits at its own depth).
    Sliding-window caches write modulo the window (ring buffer).
    """
    S = layer_kv["k"].shape[1]
    pos = jnp.asarray((length % S) if window else length)
    if pos.ndim:  # per-slot write positions: vmap the row update
        def put(buf, val):
            def row(b1, v1, p1):
                return jax.lax.dynamic_update_slice(
                    b1, v1.astype(b1.dtype), (p1,) + (0,) * (b1.ndim - 1))
            return jax.vmap(row)(buf, val, pos)
    else:
        def put(buf, val):
            return jax.lax.dynamic_update_slice(
                buf, val.astype(buf.dtype), (0, pos, 0, 0))
    out = dict(layer_kv)
    if "k_scale" in layer_kv and layer_kv["k_scale"] is not None:
        kq, ks = _quant_kv(k_new)
        vq, vs = _quant_kv(v_new)
        out["k"], out["v"] = put(layer_kv["k"], kq), put(layer_kv["v"], vq)
        out["k_scale"] = put(layer_kv["k_scale"], ks)
        out["v_scale"] = put(layer_kv["v_scale"], vs)
    else:
        out["k"], out["v"] = put(layer_kv["k"], k_new), put(layer_kv["v"], v_new)
    return out


def cache_read(layer_kv: dict):
    """Dequantized (k, v) views of one layer's cache slice."""
    k, v = layer_kv["k"], layer_kv["v"]
    if layer_kv.get("k_scale") is not None:
        k = (k.astype(jnp.float32) * layer_kv["k_scale"]).astype(jnp.bfloat16)
        v = (v.astype(jnp.float32) * layer_kv["v_scale"]).astype(jnp.bfloat16)
    return k, v


# -- slot-major batched caches (serving engine) -----------------------------
#
# The serving engine stacks ``max_slots`` independent sequences into ONE
# cache pytree so a single (max_slots, 1) decode program serves every
# active slot per tick.  Convention shared by all family caches (KVCache,
# SSMCache, HybridCache): data leaves carry the slot axis at position 1
# (layer-major stacking puts layers at axis 0), and bookkeeping leaves
# (``length``) are scalars per sequence — vectorized to (max_slots,) by
# :func:`batch_slot_cache` so every slot tracks its own depth.


def batch_slot_cache(cache):
    """Vectorize a cache's scalar ``length`` leaves to per-slot (b,) vectors.

    ``cache`` comes from ``model.make_cache(cfg, max_slots, max_len)``;
    data leaves already carry the slot axis at position 1 (they are
    untouched), scalar leaves become (max_slots,) zeros-initialized
    vectors so decode can thread per-slot positions.
    """
    wide = [a for a in jax.tree.leaves(cache) if jnp.ndim(a) >= 2]
    slots = wide[0].shape[1]
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (slots,)) if jnp.ndim(a) == 0 else a,
        cache)


def cache_at(cache, slot: int):
    """Batch-1 view of one slot of a slot-major batched cache.

    Per-slot ``length`` vectors collapse back to the scalar the
    single-sequence prefill/decode path expects, so the view is
    interchangeable with a fresh ``make_cache(cfg, 1, max_len)``.
    """
    return jax.tree.map(
        lambda a: a[slot] if a.ndim <= 1 else a[:, slot:slot + 1], cache)


def write_slot(cache, slot_cache, slot: int):
    """Write a batch-1 cache (e.g. a freshly prefilled prompt) into slot
    ``slot`` of a slot-major batched cache.

    Copies the FULL slot extent — including zero (or zero-scale) tail
    positions — so a reused slot cannot leak stale keys/values or stale
    int8 dequant scales from the previous occupant.
    """
    def put(dst, src):
        if dst.ndim <= 1:  # per-slot length ← scalar slot length
            return dst.at[slot].set(jnp.asarray(src).reshape(()).astype(dst.dtype))
        return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))
    return jax.tree.map(put, cache, slot_cache)


# -- paged KV cache (serving engine, continuous batching) -------------------
#
# The dense slot-major layout above reserves a full (max_len) extent per
# slot.  The paged layout replaces it with fixed-size PAGES drawn from a
# SHARED pool: data leaves are (L, n_pages, page, hkv, d), a per-slot
# page table maps logical page j of a slot (tokens [j·page, (j+1)·page))
# to a physical pool page, and slots grow one page at a time — freed
# pages return to the pool on retirement.  int8 KV scale leaves page
# alongside their data leaves with the same indirection.  Pages are
# allocated CONTIGUOUSLY per slot, so the per-page validity mask reduces
# to the per-row valid-length prefix mask attention already applies.


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedKVCache:
    """Paged slot-major KV cache over a shared page pool.

    ``page_table[slot, j]`` is the physical page holding the slot's
    tokens ``[j*page, (j+1)*page)``; ``-1`` marks an unassigned logical
    page.  Writes routed through an unassigned page are DROPPED (the
    scatter goes out of bounds), reads clamp to page 0 and rely on the
    valid-length mask — the engine's host-side allocator owns the table.
    """

    k: jax.Array                     # (L, n_pages, page, hkv, d) bf16|int8
    v: jax.Array
    k_scale: jax.Array | None        # (L, n_pages, page, hkv, 1) f32 when int8
    v_scale: jax.Array | None
    page_table: jax.Array            # (slots, pages_per_slot) int32, -1 = free
    length: jax.Array                # (slots,) int32 — tokens filled per slot

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def page_size(self) -> int:
        return self.k.shape[2]

    @property
    def n_pages(self) -> int:
        return self.k.shape[1]


def pages_per_slot(max_len: int, page_size: int) -> int:
    """Page-table width: logical pages needed to back ``max_len`` tokens."""
    return -(-max_len // page_size)


def init_paged_kv_cache(cfg: ModelConfig, n_layers: int, slots: int,
                        max_len: int, *, page_size: int = 64,
                        n_pages: int | None = None, bits: int | None = None,
                        dtype=jnp.bfloat16, head_dim: int | None = None,
                        kv_heads: int | None = None) -> PagedKVCache:
    """Shared page pool + empty page table.  ``n_pages=None`` sizes the
    pool for zero overcommit (slots × pages_per_slot — every slot can
    reach ``max_len``); smaller pools overcommit and rely on the
    engine's admission backpressure."""
    if cfg.attn_window:
        raise ValueError("paged KV does not support sliding-window (ring) "
                         "caches; use the dense slot-major layout")
    hkv = cfg.num_kv_heads if kv_heads is None else kv_heads
    hd = cfg.head_dim if head_dim is None else head_dim
    width = pages_per_slot(max_len, page_size)
    if n_pages is None:
        n_pages = slots * width
    shape = (n_layers, n_pages, page_size, hkv, hd)
    table = jnp.full((slots, width), -1, jnp.int32)
    length = jnp.zeros((slots,), jnp.int32)
    if bits == 8:
        return PagedKVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros((*shape[:4], 1), jnp.float32),
            v_scale=jnp.zeros((*shape[:4], 1), jnp.float32),
            page_table=table, length=length)
    return PagedKVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                        k_scale=None, v_scale=None, page_table=table,
                        length=length)


def paged_update(layer_kv: dict, k_new: jax.Array, v_new: jax.Array,
                 length: jax.Array, page_table: jax.Array, *,
                 valid_new: jax.Array | None = None) -> dict:
    """Scatter-write new k/v into pool pages through the page table.

    layer_kv: dict(k, v[, k_scale, v_scale]) with POOL shapes
    (n_pages, page, h, d).  k_new/v_new: (b, s, h, d) written at per-row
    positions ``length + [0, s)`` — ``length`` is a scalar (all rows at
    the same depth) or a (b,) vector of per-row depths (slot-major
    batched decode / mixed-depth prefill); int8 pools quantize here and
    write the per-(position, head) scales through the same indirection.
    ``valid_new``: optional (b,) count of REAL new tokens per row
    (batched prefill right-pads mixed prompt lengths) — writes beyond it
    are dropped.  Any write that resolves to an unassigned (-1) or
    out-of-range logical page is routed out of bounds and dropped by the
    scatter, so padding rows and stalled slots cannot corrupt the pool.
    """
    n_pages, page = layer_kv["k"].shape[0], layer_kv["k"].shape[1]
    b, s = k_new.shape[0], k_new.shape[1]
    width = page_table.shape[1]
    pos = jnp.broadcast_to(
        jnp.asarray(length).reshape(-1, 1) + jnp.arange(s)[None], (b, s))
    logical = pos // page
    phys = jnp.take_along_axis(page_table, jnp.minimum(logical, width - 1),
                               axis=1)
    ok = (logical < width) & (phys >= 0)
    if valid_new is not None:
        ok &= jnp.arange(s)[None] < jnp.asarray(valid_new).reshape(-1, 1)
    phys = jnp.where(ok, phys, n_pages)          # out of bounds → dropped
    pflat, oflat = phys.reshape(-1), (pos % page).reshape(-1)

    def put(buf, val):
        flat = val.reshape(b * s, *val.shape[2:]).astype(buf.dtype)
        return buf.at[pflat, oflat].set(flat, mode="drop")

    out = dict(layer_kv)
    if layer_kv.get("k_scale") is not None:
        kq, ks = _quant_kv(k_new)
        vq, vs = _quant_kv(v_new)
        out["k"], out["v"] = put(layer_kv["k"], kq), put(layer_kv["v"], vq)
        out["k_scale"] = put(layer_kv["k_scale"], ks)
        out["v_scale"] = put(layer_kv["v_scale"], vs)
    else:
        out["k"], out["v"] = put(layer_kv["k"], k_new), put(layer_kv["v"], v_new)
    return out


def paged_view(layer_kv: dict, page_table: jax.Array):
    """Contiguous dequantized (k, v) views of a paged pool, per slot.

    This is the XLA gather that the Pallas paged-attention kernel
    (kernels/paged_attention.py) eliminates on the decode hot path: it
    materializes every cached byte into a fresh (b, width·page, h, d)
    HBM buffer per layer per call (int8 pools additionally inflate to
    bf16), which attention then re-reads.  It remains the parity
    fallback (``paged_attn_backend() == "xla"``) and the prefill-side
    view.

    Masking contract: positions past a slot's valid length read
    clamped (-1 → page 0) or stale pages and MUST be masked by the
    caller's length-prefix mask.  They always can be: the engine
    allocates pages contiguously per slot, so page validity ≡ the
    per-row valid-length prefix that ``attention_scores(length=...)``
    already applies.
    """
    idx = jnp.maximum(page_table, 0)                      # (b, width)
    k, v = layer_kv["k"][idx], layer_kv["v"][idx]         # (b, w, page, h, d)
    if layer_kv.get("k_scale") is not None:
        k = (k.astype(jnp.float32) * layer_kv["k_scale"][idx]
             ).astype(jnp.bfloat16)
        v = (v.astype(jnp.float32) * layer_kv["v_scale"][idx]
             ).astype(jnp.bfloat16)
    b, w, page = k.shape[0], k.shape[1], k.shape[2]
    return (k.reshape(b, w * page, *k.shape[3:]),
            v.reshape(b, w * page, *v.shape[3:]))


def gather_page_rows(page_table: jax.Array, slots) -> jax.Array:
    """Page-table rows for a batch of admitted slots.

    ``slots`` may contain the sentinel value ``page_table.shape[0]``
    (batched prefill pads the admission batch to a bucketed row count):
    sentinel rows resolve to all-unassigned (-1) so their writes drop.
    """
    n_slots = page_table.shape[0]
    sl = jnp.asarray(slots)
    rows = page_table[jnp.clip(sl, 0, n_slots - 1)]
    return jnp.where((sl[:, None] >= 0) & (sl[:, None] < n_slots), rows, -1)


def take_last_valid(x: jax.Array, lengths) -> jax.Array:
    """(n, s, d) → (n, 1, d) at each row's last valid position (the
    right-padded batched-prefill logits gather)."""
    idx = jnp.maximum(jnp.asarray(lengths) - 1, 0)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)


def spec_accept_greedy(proposed, greedy) -> list[int]:
    """Greedy speculative acceptance: longest matching prefix + one
    corrected token (docs/speculative.md).

    ``proposed``: the k drafted tokens for one slot; ``greedy``: the
    target's argmax at each of the k+1 verified positions (position j
    scored the context ending in draft j's predecessor, so ``greedy[j]``
    is what plain greedy decode would have emitted there).  Accept
    drafts while they match, then emit the target's own token at the
    first divergence — the emitted stream is exactly what plain greedy
    decode produces, one token at a time.  Always emits ≥ 1 token.
    """
    m = 0
    while m < len(proposed) and int(proposed[m]) == int(greedy[m]):
        m += 1
    return [int(t) for t in proposed[:m]] + [int(greedy[m])]


def quant_roundtrip_kv(x: jax.Array) -> jax.Array:
    """Quantize→dequantize through the int8 KV path (what a reader of the
    cache would see).  Batched prefill attends over LOCAL fresh k/v
    instead of reading them back from the pool; int8 caches must
    roundtrip so the local view matches the per-slot oracle bit for bit."""
    q, s = _quant_kv(x)
    return (q.astype(jnp.float32) * s).astype(jnp.bfloat16)


def flash_decode(q, layer_kv: dict, valid, *, dp_spec) -> jax.Array:
    """Distributed online-softmax decode over a SEQUENCE-sharded KV cache.

    Each model-shard scores its local KV slice (dequantizing int8 codes
    locally — the full cache never leaves its shard), computes a local
    (max, sum, partial output), and three tiny psums combine them:
    wire per layer drops from the (b,h,1,S) f32 logits all-gather
    (~137 MB for llama decode_32k) to (b,h,[1+1+hd]) f32 (~0.5 MB).
    q: (b, 1, hq, d); cache slices (b, S, hkv, ·).  §Perf cell C it2.

    ``valid`` — the number of visible cache positions per row, INCLUDING
    the token written this tick — may be a scalar (every row at the same
    depth: single-sequence serving) or a (b,) vector of per-row depths
    (the slot-major batched engine, where each slot decodes at its own
    length).  Either way it is broadcast to (b,) and sharded with the
    batch, and each shard masks its local positions against its own
    rows' depths — so the batched engine's ONE (max_slots, 1) tick
    reaches this flash path, not just scalar-length callers.
    ``dp_spec``: the batch-sharding spec from :func:`_flash_decode_ok`
    (None = batch replicated).
    """
    from jax.sharding import PartitionSpec as P

    mesh = compat.get_abstract_mesh()
    b, sq, hq, d = q.shape
    S = layer_kv["k"].shape[1]
    quantized = layer_kv.get("k_scale") is not None

    def local(qc, k, v, ks, vs, valid_):
        idx = jax.lax.axis_index("model")
        b_loc, sq_, hq_, d_ = qc.shape  # LOCAL shapes (batch may be sharded)
        s_loc = k.shape[1]
        if quantized:
            k = (k.astype(jnp.float32) * ks).astype(jnp.bfloat16)
            v = (v.astype(jnp.float32) * vs).astype(jnp.bfloat16)
        hkv = k.shape[2]
        g = hq_ // hkv
        qg = qc.reshape(b_loc, sq_, hkv, g, d_)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * (d ** -0.5)
        pos = jnp.arange(s_loc) + idx * s_loc  # global slot positions
        # valid_ is the (b_loc,) per-row depth slice: mask each row's
        # local positions against ITS depth (scalar callers were
        # broadcast before the shard_map)
        mask = pos[None, None, None, None, :] \
            < valid_[:, None, None, None, None]
        s = jnp.where(mask, s, -1e30)
        m_loc = s.max(-1)                                    # (b,h,g,1)
        m_glob = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m_glob[..., None])
        l = jax.lax.psum(p.sum(-1), "model")                 # (b,h,g,1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(jnp.float32),
                       v.astype(jnp.float32))
        o = jax.lax.psum(o, "model")
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1).reshape(
            b_loc, sq_, hq_, v.shape[-1]).astype(qc.dtype)

    kv_spec = P(dp_spec, "model", None, None)
    ks = layer_kv.get("k_scale")
    vs = layer_kv.get("v_scale")
    scale_spec = kv_spec if quantized else P()
    valid_vec = jnp.broadcast_to(
        jnp.asarray(valid, jnp.int32).reshape(-1), (b,))
    return compat.shard_map(
        local,
        in_specs=(P(dp_spec, None, None, None), kv_spec, kv_spec,
                  scale_spec, scale_spec, P(dp_spec)),
        out_specs=P(dp_spec, None, None, None)
    )(q, layer_kv["k"], layer_kv["v"],
      ks if quantized else jnp.zeros((), jnp.float32),
      vs if quantized else jnp.zeros((), jnp.float32),
      valid_vec)


def _flash_decode_ok(cfg: ModelConfig, q, layer_kv) -> tuple[bool, Any]:
    """Eligibility + the dp spec for flash_decode under the ambient mesh.

    Length-shape-agnostic: scalar and per-slot (b,) cache depths are
    both eligible (flash_decode broadcasts/shards the depth vector).
    Requires a sequence-sharded cache to exist at all — a mesh with a
    'model' axis that the kv-head count does NOT divide (head-sharded
    caches keep the plain gather path) and S divisible by the axis."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False, None
    b, sq = q.shape[0], q.shape[1]
    S, hkv = layer_kv["k"].shape[1], layer_kv["k"].shape[2]
    if sq != 1 or cfg.attn_window or S % mesh.shape["model"]:
        return False, None
    if hkv % mesh.shape["model"] == 0:
        return False, None  # head-sharded caches don't need it
    dp = tuple(a for a in mesh.axis_names if a != "model")
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    dp_spec = (dp if len(dp) > 1 else dp[0]) if (dp and b % size == 0) \
        else None
    return True, dp_spec


def paged_attn_backend(cfg: ModelConfig,
                       policy: QuantPolicy | None = None) -> str:
    """Resolved executor for decode attention over a PAGED KV pool.

    The single dispatch point for the paged decode hot path, sharing
    ``kernels.ops.resolve_backend`` with the quantized linears so one
    policy knob (``QuantPolicy.use_kernels``) governs both:

      * ``"pallas"`` / ``"interpret"`` — the in-VMEM Pallas
        paged-attention kernel (compiled on TPU / via the interpreter);
      * ``"xla"``  — the ``paged_view`` gather + ``attention_scores``
        parity fallback.  MLA latent pools resolve here by
        construction: the latent must be up-projected (``wukv``) into
        per-head K/V *before* attention, so the gather is load-bearing,
        not an attention implementation detail (docs/paged_attention.md
        has the full dispatch table).  ``attn_bf16_io`` configs also
        fall back (the kernel accumulates f32).
      * ``"none"`` — the family has no attention KV to page (pure SSM).

    Engines surface this in ``run_stats["paged_attention_backend"]``.
    """
    if not cfg.uses_attention:
        return "none"
    if cfg.kv_lora_rank or cfg.attn_bf16_io or cfg.attn_window:
        return "xla"
    from repro.kernels import ops

    return ops.resolve_backend(policy.use_kernels if policy is not None
                               else "auto")


def attn_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
               layer_kv: dict | None = None, length: jax.Array | int = 0,
               policy: QuantPolicy | None = None, taps: dict | None = None,
               page_table: jax.Array | None = None,
               valid_new: jax.Array | None = None,
               prefill_local: bool = False):
    """Full attention block (pre-norm). Returns (y, updated layer_kv).

    Per-slot length contract: ``length`` — the number of tokens already
    in the cache BEFORE this call — may be a scalar or a (b,) vector of
    per-row depths (slot-major batched decode).  RoPE positions, the
    cache write position, and the valid-length mask
    (``valid = min(length + s, S)``, which includes the tokens written
    this call) are all applied per row; rows at depth 0 with nothing
    written are inactive slots whose output is garbage by contract
    (never sampled).

    ``page_table`` switches ``layer_kv`` to the PAGED layout: leaves are
    pool-shaped (n_pages, page, h, d) and writes/reads go through
    :func:`paged_update` / :func:`paged_view`.  Decode (s == 1) then
    dispatches via :func:`paged_attn_backend`: on the Pallas modes the
    kernel indexes pages in-VMEM and the contiguous gather never
    materializes; on "xla" the ``paged_view`` fallback runs.
    ``prefill_local`` (paged batched prefill, rows all at length 0)
    attends over the freshly computed k/v instead of gathering them
    back from the pool — the causal mask alone covers validity, and
    ``valid_new`` masks the right-padding rows' writes.

    Dense-cache decode reaches :func:`flash_decode` when
    ``cfg.decode_flash`` and the mesh sequence-shards the cache —
    including per-slot (b,) depth vectors (the batched engine's tick).
    """
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    h = rms_norm(x, p.get("ln"), cfg.norm_eps)
    if taps is not None:  # q/k/v share this input (paper §III-A)
        taps["k_proj"] = h
    q = dense(h, p["wq"], policy).reshape(b, s, hq, hd)
    k = dense(h, p["wk"], policy).reshape(b, s, hkv, hd)
    v = dense(h, p["wv"], policy).reshape(b, s, hkv, hd)
    larr = jnp.asarray(length)
    pos = (larr[:, None] + jnp.arange(s)[None]) if larr.ndim \
        else (jnp.arange(s) + larr)
    cos, sin = rope_angles(pos, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if layer_kv is not None and page_table is not None:  # paged cache
        layer_kv = paged_update(layer_kv, k, v, length, page_table,
                                valid_new=valid_new)
        if prefill_local:
            kc, vc = k, v
            if layer_kv.get("k_scale") is not None:
                kc, vc = quant_roundtrip_kv(k), quant_roundtrip_kv(v)
            out = attention_scores(q, kc, vc, causal=True, q_offset=length,
                                   bf16_io=cfg.attn_bf16_io)
        else:
            width, page = page_table.shape[1], layer_kv["k"].shape[1]
            valid = jnp.minimum(larr + s, width * page)
            mode = paged_attn_backend(cfg, policy)
            if s == 1 and mode in ("pallas", "interpret"):
                # in-VMEM page indexing: the kernel DMAs each slot's
                # pages through the table and never materializes the
                # contiguous view (kernels/paged_attention.py)
                from repro.kernels import ops

                out = ops.paged_attention(q, layer_kv, page_table, valid,
                                          interpret=(mode == "interpret"))
            else:
                kc, vc = paged_view(layer_kv, page_table)
                out = attention_scores(q, kc, vc, causal=(s > 1),
                                       q_offset=length, length=valid,
                                       bf16_io=cfg.attn_bf16_io)
    elif layer_kv is not None:  # decode / cached prefill
        layer_kv = cache_update(layer_kv, k, v, length, window=cfg.attn_window)
        valid = jnp.minimum(larr + s, layer_kv["k"].shape[1])
        use_fd, dp_spec = (False, None)
        if cfg.decode_flash:  # per-slot (b,) depths are eligible too
            use_fd, dp_spec = _flash_decode_ok(cfg, q, layer_kv)
        if use_fd:
            out = flash_decode(q, layer_kv, valid, dp_spec=dp_spec)
        else:
            kc, vc = cache_read(layer_kv)
            # Ring-buffer caches: every stored slot is within the window
            # and causally valid; keys carry absolute RoPE so slot order
            # is irrelevant (attention is permutation-invariant over
            # keys).  Cached prefill (s > 1, non-ring) additionally needs
            # the causal mask since cache slots ARE absolute positions.
            out = attention_scores(q, kc, vc, causal=(s > 1),
                                   q_offset=length, window=0, length=valid,
                                   bf16_io=cfg.attn_bf16_io)
    elif cfg.attn_impl == "flash" and not cfg.attn_window:
        out = flash_attention(q, k, v, causal=True,
                              bf16_io=cfg.attn_bf16_io)
    else:
        out = attention_scores(q, k, v, causal=True, window=cfg.attn_window,
                               bf16_io=cfg.attn_bf16_io)
    o_in = out.reshape(b, s, hq * hd)
    if taps is not None:
        taps["o_proj"] = o_in
    y = dense(o_in, p["wo"], policy)
    return x + y, layer_kv


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wg": init_linear(ks[0], d_model, d_ff, dtype=dtype),
        "wu": init_linear(ks[1], d_model, d_ff, dtype=dtype),
        "wd": init_linear(ks[2], d_ff, d_model, dtype=dtype),
        "ln": init_rms(d_model, dtype),
    }


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig,
              policy: QuantPolicy | None = None, *, residual: bool = True,
              taps: dict | None = None):
    h = (rms_norm(x, p.get("ln"), cfg.norm_eps)
         if "ln" in p and p["ln"] is not None else x)
    if taps is not None:  # gate/up share this input (paper §III-A)
        taps["gate_proj"] = h
    g = dense(h, p["wg"], policy)
    u = dense(h, p["wu"], policy)
    a = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    if taps is not None:
        taps["down_proj"] = a
    y = dense(a, p["wd"], policy)
    return x + y if residual else y


# ---------------------------------------------------------------------------
# embedding / loss
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    return {"e": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["e"], tokens, axis=0)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None],
                             axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# layer stacking
# ---------------------------------------------------------------------------


def stack_layer_params(keys, init_fn):
    """vmap an init over layer keys → params stacked on axis 0."""
    return jax.vmap(init_fn)(keys)


def scan_layers(block_fn, params_stacked, x, *, remat: bool, extras=None,
                sp: bool = False, remat_policy: str = "full"):
    """Run ``block_fn(layer_params, x, extra) -> (x, y)`` over the stack.

    ``extras``: optional pytree with leading layer axis scanned alongside
    (e.g. per-layer KV cache slices).  Returns (x, stacked ys).
    ``sp``: sequence-parallel the residual stream between blocks.
    ``remat_policy='dots_no_batch'``: save linear (no-batch-dim) dot
    outputs, recompute attention scores/probs in backward — one fewer
    weight all-gather pass than full remat, and no s² residency (the
    contract a fused flash-attention backward provides on TPU).
    """
    group = 1
    if remat and remat_policy.startswith("group"):
        group = int(remat_policy[len("group"):] or 2)
        fn = block_fn
    elif remat and remat_policy == "dots_no_batch":
        fn = jax.checkpoint(
            block_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        fn = jax.checkpoint(block_fn)
    else:
        fn = block_fn

    if group > 1:
        # grouped remat: one residual stored per GROUP of g layers (126
        # layers × 134 MB does not fit HBM at 405B scale; 126/g does) —
        # backward recomputes the g-layer group once.
        L = jax.tree.leaves(params_stacked)[0].shape[0]
        if L % group:
            group = 1  # fall back silently for non-divisible stacks
    if group > 1:
        regroup = lambda t: jax.tree.map(
            lambda a: a.reshape(a.shape[0] // group, group, *a.shape[1:]), t)
        pg = regroup(params_stacked)
        eg = regroup(extras) if extras is not None else None

        # inner layers carry the dots-no-batch policy so the group's
        # backward live-set holds linear outputs only (never s² probs)
        inner_fn = jax.checkpoint(
            block_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        @jax.checkpoint
        def group_step(carry, group_in):
            lp_g, extra_g = group_in

            def inner(c, one):
                lp, ex = one
                c, y = inner_fn(lp, c, ex)
                return shard_act(c, sp=sp), y

            carry, ys = jax.lax.scan(inner, carry, (lp_g, extra_g))
            return carry, ys

        x, ys = jax.lax.scan(group_step, shard_act(x, sp=sp), (pg, eg))
        ys = jax.tree.map(
            lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]), ys)
        return x, ys

    def step(carry, layer_in):
        lp, extra = layer_in
        carry, y = fn(lp, carry, extra)
        return shard_act(carry, sp=sp), y

    x, ys = jax.lax.scan(step, shard_act(x, sp=sp), (params_stacked, extras))
    return x, ys
