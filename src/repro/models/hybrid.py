"""Zamba2-style hybrid: a Mamba2 backbone with a SHARED attention+FFN
block invoked after every ``attn_every`` SSM blocks (weight sharing is
the Zamba signature — one transformer block's parameters reused at every
invocation, each with its own KV cache).

Decode is O(1) in context for the SSM part plus one KV lookup per shared
-attention invocation → ``long_500k`` runs natively (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import mamba2 as mb


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HybridCache:
    ssm: jax.Array     # (L_mamba, b, h, p, n)
    conv: jax.Array    # (L_mamba, b, k-1, c)
    attn: cm.KVCache   # (n_invocations, b, S, hkv, hd)
    length: jax.Array


def _groups(cfg: ModelConfig) -> list[tuple[int, int, bool]]:
    """[(lo, hi, attn_after)] covering cfg.num_layers mamba blocks."""
    out, lo = [], 0
    while lo < cfg.num_layers:
        hi = min(lo + cfg.attn_every, cfg.num_layers)
        out.append((lo, hi, hi - lo == cfg.attn_every))
        lo = hi
    return out


def n_attn_invocations(cfg: ModelConfig) -> int:
    return sum(1 for *_x, a in _groups(cfg) if a)


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    k_emb, k_layers, k_shared, k_out = jax.random.split(key, 4)
    ka, km = jax.random.split(k_shared)
    return {
        "embed": cm.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": cm.stack_layer_params(
            jax.random.split(k_layers, cfg.num_layers),
            lambda k: mb.init_mamba_block(k, cfg, dtype)),
        "shared": {"attn": cm.init_attn(ka, cfg, dtype),
                   "mlp": cm.init_mlp(km, cfg.d_model, cfg.d_ff, dtype)},
        "final_ln": cm.init_rms(cfg.d_model, dtype),
        "lm_head": cm.init_linear(k_out, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def make_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               bits: int | None = None) -> HybridCache:
    return HybridCache(
        ssm=jnp.zeros((cfg.num_layers, batch, cfg.ssm_nheads, cfg.ssm_headdim,
                       cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                        mb.conv_channels(cfg)), jnp.bfloat16),
        attn=cm.init_kv_cache(cfg, n_attn_invocations(cfg), batch, max_len,
                              bits=bits),
        length=jnp.zeros((), jnp.int32),
    )


def make_paged_cache(cfg: ModelConfig, slots: int, max_len: int, *,
                     page_size: int = 64, n_pages: int | None = None,
                     bits: int | None = None) -> HybridCache:
    """Slot-major SSM/conv state (O(1) per slot — nothing to page) plus a
    PAGED pool for the shared-attention KV, one pool layer per
    invocation; all invocations share the per-slot page table."""
    return HybridCache(
        ssm=jnp.zeros((cfg.num_layers, slots, cfg.ssm_nheads, cfg.ssm_headdim,
                       cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((cfg.num_layers, slots, cfg.ssm_conv - 1,
                        mb.conv_channels(cfg)), jnp.bfloat16),
        attn=cm.init_paged_kv_cache(cfg, n_attn_invocations(cfg), slots,
                                    max_len, page_size=page_size,
                                    n_pages=n_pages, bits=bits),
        length=jnp.zeros((slots,), jnp.int32),
    )


def _slice_tree(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _stack_kv(xs):
    """Stack per-invocation KV dicts on a leading invocation axis."""
    if len(xs) > 1:
        return jax.tree.map(lambda *a: jnp.stack(a, 0), *xs)
    return jax.tree.map(lambda a: a[None], xs[0])


def _backbone(params, cfg: ModelConfig, h, *, cache: HybridCache | None = None,
              policy=None, collect_taps=False):
    length = 0 if cache is None else cache.length
    taps_all = [] if collect_taps else None
    ssm_out, conv_out, kv_out = [], [], []
    attn_idx = 0
    for lo, hi, attn_after in _groups(cfg):
        lp = _slice_tree(params["layers"], lo, hi)

        def block(lp_one, x, extra):
            taps = {} if collect_taps else None
            x, st = mb.mamba_apply(lp_one, x, cfg, state=extra, policy=policy,
                                   taps=taps)
            return x, (taps if collect_taps else st)

        if cache is None:
            h, ys = cm.scan_layers(lambda q, x, _: block(q, x, None), lp, h,
                                   remat=cfg.remat)
            if collect_taps:
                taps_all.append(ys)
        else:
            extras = {"ssm": cache.ssm[lo:hi], "conv": cache.conv[lo:hi]}
            h, st = cm.scan_layers(block, lp, h, remat=False, extras=extras)
            ssm_out.append(st["ssm"])
            conv_out.append(st["conv"])
        if attn_after:
            sp = params["shared"]
            if cache is None:
                h, _ = cm.attn_apply(sp["attn"], h, cfg, policy=policy)
            else:
                kv = {"k": cache.attn.k[attn_idx], "v": cache.attn.v[attn_idx]}
                if cache.attn.quantized:
                    kv.update(k_scale=cache.attn.k_scale[attn_idx],
                              v_scale=cache.attn.v_scale[attn_idx])
                paged = isinstance(cache.attn, cm.PagedKVCache)
                h, kv = cm.attn_apply(
                    sp["attn"], h, cfg, layer_kv=kv, length=length,
                    policy=policy,
                    page_table=cache.attn.page_table if paged else None)
                kv_out.append(kv)
            h = cm.mlp_apply(sp["mlp"], h, cfg, policy)
            attn_idx += 1
    x = cm.rms_norm(h, params.get("final_ln"), cfg.norm_eps)
    new_cache = None
    if cache is not None:
        kvs = _stack_kv(kv_out)
        # replace() serves both attn cache classes (page_table rides
        # along untouched on the paged one)
        attn_new = dataclasses.replace(
            cache.attn, k=kvs["k"], v=kvs["v"], k_scale=kvs.get("k_scale"),
            v_scale=kvs.get("v_scale"), length=cache.attn.length + h.shape[1])
        new_cache = HybridCache(
            ssm=jnp.concatenate(ssm_out, 0), conv=jnp.concatenate(conv_out, 0),
            attn=attn_new,
            length=cache.length + h.shape[1],
        )
    if collect_taps:
        merged = jax.tree.map(lambda *a: jnp.concatenate(a, 0), *taps_all) \
            if len(taps_all) > 1 else taps_all[0]
        return x, new_cache, merged
    return x, new_cache, None


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None, policy=None):
    h = cm.embed(params["embed"], tokens) if embeds is None else embeds
    x, _, _ = _backbone(params, cfg, h, policy=policy)
    return cm.dense(x, params["lm_head"], policy)


def forward_with_taps(params, cfg: ModelConfig, tokens=None, *, embeds=None,
                      policy=None):
    h = cm.embed(params["embed"], tokens) if embeds is None else embeds
    x, _, taps = _backbone(params, cfg, h, policy=policy, collect_taps=True)
    return cm.dense(x, params["lm_head"]), taps


def train_loss(params, cfg: ModelConfig, batch):
    logits = forward(params, cfg, batch.get("tokens"), embeds=batch.get("embeds"))
    return cm.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                            batch.get("mask"))


def decode_step(params, cfg: ModelConfig, tokens, cache: HybridCache,
                policy=None):
    """One token per sequence.  Slot-major batched serving: the SSM/conv
    states are position-free per batch row, and the shared-attention KV
    lookups thread ``cache.length`` — scalar or per-slot (b,) vector —
    through ``common.attn_apply`` (per-row RoPE/write/valid-mask)."""
    h = cm.embed(params["embed"], tokens)
    x, cache, _ = _backbone(params, cfg, h, cache=cache, policy=policy)
    return cm.dense(x, params["lm_head"], policy), cache


def prefill(params, cfg: ModelConfig, tokens, cache: HybridCache, policy=None):
    """Hybrid prefill: chunked SSM (threading out true final states) +
    full-prompt KV writes for each shared-attention invocation."""
    h = cm.embed(params["embed"], tokens)
    s = tokens.shape[1]
    ssm_out, conv_out, kv_out = [], [], []
    attn_idx = 0
    for lo, hi, attn_after in _groups(cfg):
        lp = _slice_tree(params["layers"], lo, hi)
        h, st = cm.scan_layers(
            lambda q, x, _: mb.mamba_prefill_block(q, x, cfg, policy),
            lp, h, remat=False)
        ssm_out.append(st["ssm"])
        conv_out.append(st["conv"])
        if attn_after:
            sp = params["shared"]
            kv = {"k": cache.attn.k[attn_idx], "v": cache.attn.v[attn_idx]}
            if cache.attn.quantized:
                kv.update(k_scale=cache.attn.k_scale[attn_idx],
                          v_scale=cache.attn.v_scale[attn_idx])
            h, kv = cm.attn_apply(sp["attn"], h, cfg, layer_kv=kv, length=0,
                                  policy=policy)
            kv_out.append(kv)
            h = cm.mlp_apply(sp["mlp"], h, cfg, policy)
            attn_idx += 1
    x = cm.rms_norm(h, params.get("final_ln"), cfg.norm_eps)
    kvs = _stack_kv(kv_out)
    new_cache = HybridCache(
        ssm=jnp.concatenate(ssm_out, 0), conv=jnp.concatenate(conv_out, 0),
        attn=cm.KVCache(k=kvs["k"], v=kvs["v"], k_scale=kvs.get("k_scale"),
                        v_scale=kvs.get("v_scale"),
                        length=cache.attn.length + s),
        length=cache.length + s,
    )
    logits = cm.dense(x[:, -1:], params["lm_head"], policy)
    return logits, new_cache


def prefill_paged(params, cfg: ModelConfig, tokens, lengths,
                  cache: HybridCache, slots, policy=None):
    """In-engine batched prefill: dt-masked chunked SSM with per-row conv
    tails scattered into slot rows, and shared-attention KV written
    straight into the slots' assigned pages (one paged pool layer per
    invocation, all sharing the per-slot page table)."""
    h = cm.embed(params["embed"], tokens)
    ptab = cm.gather_page_rows(cache.attn.page_table, slots)
    ssm_out, conv_out, kv_out = [], [], []
    attn_idx = 0
    for lo, hi, attn_after in _groups(cfg):
        lp = _slice_tree(params["layers"], lo, hi)
        h, st = cm.scan_layers(
            lambda q, x, _: mb.mamba_prefill_block(q, x, cfg, policy,
                                                   lengths=lengths),
            lp, h, remat=False)
        ssm_out.append(st["ssm"])
        conv_out.append(st["conv"])
        if attn_after:
            sp = params["shared"]
            kv = {"k": cache.attn.k[attn_idx], "v": cache.attn.v[attn_idx]}
            if cache.attn.quantized:
                kv.update(k_scale=cache.attn.k_scale[attn_idx],
                          v_scale=cache.attn.v_scale[attn_idx])
            h, kv = cm.attn_apply(sp["attn"], h, cfg, layer_kv=kv, length=0,
                                  policy=policy, page_table=ptab,
                                  valid_new=lengths, prefill_local=True)
            kv_out.append(kv)
            h = cm.mlp_apply(sp["mlp"], h, cfg, policy)
            attn_idx += 1
    x = cm.rms_norm(h, params.get("final_ln"), cfg.norm_eps)
    kvs = _stack_kv(kv_out)
    sl = jnp.asarray(slots)
    larr = jnp.asarray(lengths, jnp.int32)
    new_cache = HybridCache(
        ssm=cache.ssm.at[:, sl].set(jnp.concatenate(ssm_out, 0), mode="drop"),
        conv=cache.conv.at[:, sl].set(
            jnp.concatenate(conv_out, 0).astype(cache.conv.dtype),
            mode="drop"),
        attn=cm.PagedKVCache(
            k=kvs["k"], v=kvs["v"], k_scale=kvs.get("k_scale"),
            v_scale=kvs.get("v_scale"), page_table=cache.attn.page_table,
            length=cache.attn.length.at[sl].set(larr, mode="drop")),
        length=cache.length.at[sl].set(larr, mode="drop"),
    )
    logits = cm.dense(cm.take_last_valid(x, lengths), params["lm_head"],
                      policy)
    return logits, new_cache
