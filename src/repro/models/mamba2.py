"""Mamba2 (SSD — state-space duality) in chunked, MXU-friendly form.

The SSD algorithm (arXiv:2405.21060) computes the selective-SSM output
with matmuls over chunks: an intra-chunk quadratic term (masked by the
decay kernel L), per-chunk boundary states, an inter-chunk scan of
states, and a low-rank inter-chunk correction — all einsums of size
(chunk × chunk) or (chunk × d_state), which is exactly what the MXU
wants (DESIGN.md §3).  Decode keeps an O(1) recurrent state per layer:
h ← exp(dtA)·h + dt·B⊗x, y = C·h — this is what makes ``long_500k``
native for the SSM/hybrid archs.

Quantization sites (the paper's technique): in_proj / out_proj are
standard linears and go through the same fold+quantize pipeline; the
recurrence itself is not a weight matmul and stays bf16 (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import QuantPolicy
from repro.models import common as cm

Params = dict[str, Any]


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_mamba_block(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_nheads
    gn = cfg.ssm_ngroups * cfg.ssm_state
    d_in_proj = 2 * di + 2 * gn + h  # z, x, B, C, dt
    return {
        "in_proj": cm.init_linear(ks[0], d, d_in_proj, dtype=dtype),
        "out_proj": cm.init_linear(ks[1], di, d, dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, conv_channels(cfg)),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_channels(cfg),), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": (jax.random.uniform(ks[3], (h,), jnp.float32) * 2 - 4.0),
        "ln": cm.init_rms(d, dtype),
        "gate_ln": cm.init_rms(di, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, gn, h = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xc = zxbcdt[..., di:2 * di + 2 * gn]      # conv input: x ++ B ++ C
    dt = zxbcdt[..., 2 * di + 2 * gn:]
    return z, xc, dt


def _causal_conv(xc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along time. xc (b, l, c); w (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(xc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xc.shape[1], :] * w[i][None, None] for i in range(k))
    return jax.nn.silu((out + b[None, None]).astype(jnp.float32))


def _split_conv_out(cfg: ModelConfig, conv_out: jax.Array):
    di, g, n = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    x = conv_out[..., :di]
    B = conv_out[..., di:di + g * n]
    C = conv_out[..., di + g * n:]
    return x, B, C


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int, h_init=None):
    """SSD scan. x (b,l,h,p); dt (b,l,h) post-softplus; A (h,) negative;
    B,C (b,l,g,n).  Returns (y (b,l,h,p), final state (b,h,p,n))."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Q = chunk
    l_orig = l
    if l % Q:  # pad to a chunk multiple: dt=0 ⇒ no decay, no contribution
        pad = Q - l % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // Q
    xr = x.reshape(b, nc, Q, h, p)
    dtr = dt.reshape(b, nc, Q, h)
    Br = jnp.repeat(B.reshape(b, nc, Q, g, n), rep, axis=3)   # (b,c,Q,h,n)
    Cr = jnp.repeat(C.reshape(b, nc, Q, g, n), rep, axis=3)
    dA = dtr * A[None, None, None]                             # (b,c,Q,h) ≤ 0
    cum = jnp.cumsum(dA, axis=2)                               # within-chunk
    total = cum[:, :, -1]                                      # (b,c,h)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i ≥ j
    Lmat = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :])    # (b,c,Q,Q,h)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], Lmat, 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cr.astype(jnp.float32),
                        Br.astype(jnp.float32)) * Lmat
    xdt = xr.astype(jnp.float32) * dtr[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)
    # chunk boundary states: S_c = Σ_j exp(total - cum_j) dt_j B_j ⊗ x_j
    decay_out = jnp.exp(total[:, :, None] - cum)               # (b,c,Q,h)
    Sc = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", Br.astype(jnp.float32),
                    decay_out, xdt)
    # inter-chunk recurrence over c
    if h_init is None:
        h_init = jnp.zeros((b, h, p, n), jnp.float32)

    def step(S, inp):
        Sc_c, tot_c = inp
        S_new = S * jnp.exp(tot_c)[:, :, None, None] + Sc_c
        return S_new, S  # emit state BEFORE this chunk

    S_final, S_prev = jax.lax.scan(
        step, h_init, (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(total, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                        # (b,c,h,p,n)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Cr.astype(jnp.float32),
                         S_prev) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, l, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y[:, :l_orig], S_final


def mamba_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                state: dict | None = None, policy: QuantPolicy | None = None,
                taps: dict | None = None):
    """Full Mamba2 block. ``state`` (decode): dict(ssm (b,h,p,n),
    conv (b, k-1, conv_ch)).  Returns (y, new_state)."""
    bsz, l, _ = x.shape
    h_heads, pd = cfg.ssm_nheads, cfg.ssm_headdim
    res = x
    hid = cm.rms_norm(x, p.get("ln"), cfg.norm_eps)
    if taps is not None:
        taps["in_proj"] = hid
    zxbcdt = cm.dense(hid, p["in_proj"], policy)
    z, xc, dt = _split_proj(cfg, zxbcdt)
    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])

    if state is None:  # chunked prefill/train
        conv_out = _causal_conv(xc, p["conv_w"], p["conv_b"])
        xs, B, C = _split_conv_out(cfg, conv_out)
        y, _ = ssd_chunked(
            xs.reshape(bsz, l, h_heads, pd).astype(jnp.float32), dt, A,
            B.reshape(bsz, l, cfg.ssm_ngroups, cfg.ssm_state),
            C.reshape(bsz, l, cfg.ssm_ngroups, cfg.ssm_state),
            p["D"], chunk=min(cfg.ssm_chunk, l))
        new_state = None
    else:  # single-token decode: O(1) state update
        conv_buf = jnp.concatenate([state["conv"], xc.astype(state["conv"].dtype)],
                                   axis=1)          # (b, k, c)
        k = p["conv_w"].shape[0]
        conv_buf = conv_buf[:, -k:]
        conv_out = jax.nn.silu(
            (jnp.einsum("bkc,kc->bc", conv_buf.astype(jnp.float32),
                        p["conv_w"].astype(jnp.float32)) + p["conv_b"]
             ).astype(jnp.float32))[:, None]
        xs, B, C = _split_conv_out(cfg, conv_out)
        xh = xs.reshape(bsz, h_heads, pd).astype(jnp.float32)
        Bh = jnp.repeat(B.reshape(bsz, cfg.ssm_ngroups, cfg.ssm_state),
                        h_heads // cfg.ssm_ngroups, axis=1)
        Ch = jnp.repeat(C.reshape(bsz, cfg.ssm_ngroups, cfg.ssm_state),
                        h_heads // cfg.ssm_ngroups, axis=1)
        dt1 = dt[:, 0]                               # (b, h)
        S = state["ssm"] * jnp.exp(dt1 * A[None])[:, :, None, None] \
            + jnp.einsum("bhn,bh,bhp->bhpn", Bh, dt1, xh)
        y = jnp.einsum("bhn,bhpn->bhp", Ch, S) + xh * p["D"][None, :, None]
        y = y.reshape(bsz, 1, h_heads, pd)
        new_state = {"ssm": S, "conv": conv_buf[:, -(k - 1):]}

    y = y.reshape(bsz, l, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = cm.rms_norm(y, p.get("gate_ln"), cfg.norm_eps)
    if taps is not None:  # gated, normed SSM output — the down_proj analog
        taps["out_proj"] = y
    return res + cm.dense(y, p["out_proj"], policy), new_state


# ---------------------------------------------------------------------------
# full SSM model (mamba2-780m)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    ssm: jax.Array    # (L, b, h, p, n) f32
    conv: jax.Array   # (L, b, k-1, conv_ch) bf16
    length: jax.Array


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    return {
        "embed": cm.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": cm.stack_layer_params(
            jax.random.split(k_layers, cfg.num_layers),
            lambda k: init_mamba_block(k, cfg, dtype)),
        "final_ln": cm.init_rms(cfg.d_model, dtype),
        "lm_head": cm.init_linear(k_out, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def make_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               bits: int | None = None) -> SSMCache:
    del max_len, bits  # O(1) state regardless of context length
    return SSMCache(
        ssm=jnp.zeros((cfg.num_layers, batch, cfg.ssm_nheads, cfg.ssm_headdim,
                       cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                        conv_channels(cfg)), jnp.bfloat16),
        length=jnp.zeros((), jnp.int32),
    )


def _backbone(params, cfg, h, *, cache=None, policy=None, collect_taps=False):
    def block(lp, x, extra):
        taps = {} if collect_taps else None
        st = extra
        x, st_new = mamba_apply(lp, x, cfg, state=st, policy=policy, taps=taps)
        return x, (taps if collect_taps else st_new)

    if cache is None:
        x, ys = cm.scan_layers(lambda lp, x, _: block(lp, x, None),
                               params["layers"], h, remat=cfg.remat,
                               sp=cfg.seq_parallel,
                               remat_policy=cfg.remat_policy)
        new_cache = ys if collect_taps else None
    else:
        extras = {"ssm": cache.ssm, "conv": cache.conv}
        x, st = cm.scan_layers(block, params["layers"], h, remat=False,
                               extras=extras)
        new_cache = SSMCache(ssm=st["ssm"], conv=st["conv"],
                             length=cache.length + h.shape[1])
    x = cm.rms_norm(x, params.get("final_ln"), cfg.norm_eps)
    return x, new_cache


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None, policy=None):
    h = cm.embed(params["embed"], tokens) if embeds is None else embeds
    x, _ = _backbone(params, cfg, h, policy=policy)
    return cm.dense(x, params["lm_head"], policy)


def forward_with_taps(params, cfg: ModelConfig, tokens=None, *, embeds=None,
                      policy=None):
    h = cm.embed(params["embed"], tokens) if embeds is None else embeds
    x, taps = _backbone(params, cfg, h, policy=policy, collect_taps=True)
    return cm.dense(x, params["lm_head"]), taps


def train_loss(params, cfg: ModelConfig, batch):
    logits = forward(params, cfg, batch.get("tokens"), embeds=batch.get("embeds"))
    return cm.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                            batch.get("mask"))


def mamba_prefill_block(lp, x, cfg: ModelConfig, policy=None, lengths=None):
    """Chunked forward of one block that ALSO returns the decode state
    (final SSM state + conv tail) — used by SSM/hybrid prefill.

    ``lengths`` (batched in-engine prefill): (b,) real prompt lengths of
    right-padded rows.  Padding positions get dt forced to 0 — zero decay
    AND zero contribution, so each row's final SSM state equals the state
    at its own length — and the conv tail is gathered at per-row
    positions ``[length - (k-1), length)`` (zeros before the start, the
    same values a fresh decode state would hold).
    """
    bsz, l, _ = x.shape
    res = x
    hid = cm.rms_norm(x, lp.get("ln"), cfg.norm_eps)
    zxbcdt = cm.dense(hid, lp["in_proj"], policy)
    z, xc, dt = _split_proj(cfg, zxbcdt)
    A = -jnp.exp(lp["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"][None, None])
    if lengths is not None:
        pad = jnp.arange(l)[None, :, None] >= jnp.asarray(lengths)[:, None, None]
        dt = jnp.where(pad, 0.0, dt)
    conv_out = _causal_conv(xc, lp["conv_w"], lp["conv_b"])
    xs, B, C = _split_conv_out(cfg, conv_out)
    y, S = ssd_chunked(
        xs.reshape(bsz, l, cfg.ssm_nheads, cfg.ssm_headdim).astype(jnp.float32),
        dt, A, B.reshape(bsz, l, cfg.ssm_ngroups, cfg.ssm_state),
        C.reshape(bsz, l, cfg.ssm_ngroups, cfg.ssm_state),
        lp["D"], chunk=min(cfg.ssm_chunk, l))
    y = y.reshape(bsz, l, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = cm.rms_norm(y, lp.get("gate_ln"), cfg.norm_eps)
    x = res + cm.dense(y, lp["out_proj"], policy)
    k1 = cfg.ssm_conv - 1
    if lengths is None:
        conv_tail = xc[:, -k1:].astype(jnp.bfloat16)
    else:
        idx = jnp.asarray(lengths)[:, None] - k1 + jnp.arange(k1)[None]
        tail = jnp.take_along_axis(xc, jnp.maximum(idx, 0)[..., None], axis=1)
        conv_tail = jnp.where(idx[..., None] >= 0, tail, 0
                              ).astype(jnp.bfloat16)
    return x, {"ssm": S, "conv": conv_tail}


def prefill(params, cfg: ModelConfig, tokens, cache: SSMCache, policy=None):
    """SSM prefill: chunked scan, threading out the true final state."""
    h = cm.embed(params["embed"], tokens)
    x, st = cm.scan_layers(
        lambda lp, x, _: mamba_prefill_block(lp, x, cfg, policy),
        params["layers"], h, remat=False)
    x = cm.rms_norm(x, params.get("final_ln"), cfg.norm_eps)
    logits = cm.dense(x[:, -1:], params["lm_head"], policy)
    return logits, SSMCache(ssm=st["ssm"], conv=st["conv"],
                            length=cache.length + tokens.shape[1])


def make_paged_cache(cfg: ModelConfig, slots: int, max_len: int, *,
                     page_size: int = 64, n_pages: int | None = None,
                     bits: int | None = None) -> SSMCache:
    """The SSM state is O(1) per slot — there is nothing to page.  The
    'paged' engine cache is simply the slot-major batched state (length
    vectorized per slot); the engine's page allocator sees no page table
    and manages zero pages for this family."""
    del page_size, n_pages
    return cm.batch_slot_cache(make_cache(cfg, slots, max_len, bits=bits))


def prefill_paged(params, cfg: ModelConfig, tokens, lengths, cache: SSMCache,
                  slots, policy=None):
    """In-engine batched prefill: right-padded (n, s_pad) rows, each
    row's true final state (dt-masked SSD + gathered conv tail) scattered
    into its slot.  Sentinel slot ids (== slot count) drop."""
    h = cm.embed(params["embed"], tokens)
    x, st = cm.scan_layers(
        lambda lp, x, _: mamba_prefill_block(lp, x, cfg, policy,
                                             lengths=lengths),
        params["layers"], h, remat=False)
    x = cm.rms_norm(x, params.get("final_ln"), cfg.norm_eps)
    logits = cm.dense(cm.take_last_valid(x, lengths), params["lm_head"],
                      policy)
    sl = jnp.asarray(slots)
    new_cache = SSMCache(
        ssm=cache.ssm.at[:, sl].set(st["ssm"], mode="drop"),
        conv=cache.conv.at[:, sl].set(st["conv"].astype(cache.conv.dtype),
                                      mode="drop"),
        length=cache.length.at[sl].set(jnp.asarray(lengths, jnp.int32),
                                       mode="drop"))
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache: SSMCache, policy=None):
    """One token per sequence.  The recurrence is position-free, so the
    slot-major batched serving path needs no special handling here: each
    batch row carries its own conv tail + SSM state (axis 1 of the cache
    leaves), and ``cache.length`` may be a scalar or a per-slot vector —
    it is pure bookkeeping for this family."""
    h = cm.embed(params["embed"], tokens)
    x, cache = _backbone(params, cfg, h, cache=cache, policy=policy)
    return cm.dense(x, params["lm_head"], policy), cache
