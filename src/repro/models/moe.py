"""Mixture-of-Experts transformer (Arctic, DeepSeek-V2-lite) with
expert-parallel execution and optional MLA attention.

Expert parallelism (DESIGN.md §4): expert weights are sharded over the
``model`` mesh axis.  Activations entering the MoE FFN are replicated
over ``model`` (batch is sharded over data axes), so dispatch needs NO
all-to-all: a ``shard_map`` over ``model`` lets each shard compute only
its local experts on the tokens routed to them (capacity-bounded,
sort-based, fully differentiable), and one ``psum`` over ``model``
combines expert outputs — the same collective a tensor-parallel dense
FFN would issue.  Routing: top-k token choice with normalized gates and
a load-balancing auxiliary loss.

DeepSeek-V2 MLA: queries are full-rank; K/V derive from a compressed
kv_lora_rank latent that is ALSO what the cache stores (the paper's
technique gets an extra rotation site on this latent — DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.qlinear import QuantPolicy
from repro.launch import compat
from repro.models import common as cm

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# expert-parallel MoE FFN
# ---------------------------------------------------------------------------


def init_moe_ffn(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    sc_in, sc_f = d ** -0.5, f ** -0.5
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, E), jnp.float32) * sc_in
                         ).astype(jnp.float32)},  # router stays f32
        "wg": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * sc_in).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * sc_in).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, f, d), jnp.float32) * sc_f).astype(dtype),
        "ln": cm.init_rms(d, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = cm.init_mlp(ks[4], d, cfg.num_shared_experts * f, dtype)
        p["shared"].pop("ln")  # shares the block norm
    return p


def _expert_weight(mats: dict, had_dim: int = 0) -> jax.Array:
    """Materialize one expert weight stack from {'w'} or {'codes','scale'}
    (int4/int8 per-expert storage → bf16 for the grouped einsum)."""
    if "w" in mats:
        return mats["w"]
    codes = mats["codes"]
    if mats.get("packed"):
        from repro.core.quantizer import unpack_int4

        codes = jnp.swapaxes(unpack_int4(jnp.swapaxes(codes, -1, -2)), -1, -2)
    return (codes.astype(jnp.float32) * mats["scale"]).astype(jnp.bfloat16)


def _local_expert_compute(x_flat, topi, topv, wg, wu, wd, *, n_experts: int,
                          k: int, capacity_factor: float, axis: str | None,
                          wd_had: int = 0, token_valid=None):
    """Per-shard expert compute: select→pad→batched GEMM→combine.

    x_flat (T_local, d): this shard's tokens (sharded over data axes,
    replicated over ``axis``); wg/wu/wd local (E_loc, ...) either bf16
    arrays or quantized {'codes','scale'} dicts.  Capacity is derived
    from the LOCAL token count (buffers scale with per-device work, not
    global batch).  Fully differentiable (indices come from argsort,
    grads flow through gather/scatter); capacity overflow tokens are
    dropped (standard).
    """
    wg, wu, wd = (_expert_weight(m) if isinstance(m, dict) else m
                  for m in (wg, wu, wd))
    T, d = x_flat.shape
    e_loc = wg.shape[0]
    capacity = max(1, int(capacity_factor * T * k / n_experts))
    my_lo = (jax.lax.axis_index(axis) if axis else 0) * e_loc
    expert = topi.reshape(-1)            # (T*k,)
    gate = topv.reshape(-1)
    token = jnp.repeat(jnp.arange(T), k)
    local_e = expert - my_lo
    is_local = (local_e >= 0) & (local_e < e_loc)
    if token_valid is not None:
        # right-padding tokens (batched prefill) must not compete for
        # expert capacity — route them to the sentinel overflow group
        is_local &= jnp.repeat(token_valid.reshape(-1), k)
    sort_key = jnp.where(is_local, local_e, e_loc)  # sentinel group e_loc
    order = jnp.argsort(sort_key)        # group by local expert, locals first
    se = sort_key[order]
    rank = jnp.arange(se.shape[0]) - jnp.searchsorted(se, se, side="left")
    keep = (rank < capacity) & (se < e_loc)
    dest = jnp.where(keep, se * capacity + rank, e_loc * capacity)  # overflow slot
    tok_sorted = token[order]
    gate_sorted = jnp.where(keep, gate[order], 0.0)
    # scatter tokens into (E_loc*C [+1 overflow], d) buffer
    buf = jnp.zeros((e_loc * capacity + 1, d), x_flat.dtype)
    buf = buf.at[dest].set(x_flat[tok_sorted] * keep[:, None].astype(x_flat.dtype))
    xe = buf[: e_loc * capacity].reshape(e_loc, capacity, d)
    g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
    a = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    if wd_had:  # wd was folded with Rᵀ: rotate the expert activation
        from repro.core.hadamard import apply_hadamard

        a = apply_hadamard(a, wd_had)
    y = jnp.einsum("ecf,efd->ecd", a, wd.astype(xe.dtype))
    y_flat = y.reshape(e_loc * capacity, d)
    contrib = jnp.where(dest[:, None] < e_loc * capacity,
                        y_flat[jnp.minimum(dest, e_loc * capacity - 1)], 0.0)
    contrib = contrib * gate_sorted[:, None].astype(y_flat.dtype)
    out = jax.ops.segment_sum(contrib, tok_sorted, num_segments=T)
    if axis:
        out = jax.lax.psum(out, axis)
    return out


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig,
            policy: QuantPolicy | None = None, *, taps: dict | None = None,
            valid: jax.Array | None = None):
    """x: (b, s, d) → (b, s, d) MoE output + aux load-balance loss.

    ``valid``: optional (b, s) bool mask of REAL tokens (batched prefill
    right-pads mixed prompt lengths) — invalid tokens get zero gates and
    are excluded from expert-capacity competition.
    """
    b, s, d = x.shape
    h = cm.rms_norm(x, p.get("ln"), cfg.norm_eps)
    if taps is not None:  # routed+shared expert gate/up input
        taps["gate_proj"] = h
    hf = h.reshape(-1, d)
    logits = (hf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.experts_per_tok
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)  # renorm gates
    vmask = None if valid is None else valid.reshape(-1, 1)
    if vmask is not None:
        topv = topv * vmask.astype(topv.dtype)
    # load-balance aux (Switch-style): E * Σ_e f_e·P_e
    E = cfg.num_experts
    density = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=(0, 1))
    p_mean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * p_mean)

    mesh = compat.get_abstract_mesh()
    tp = "model" if (mesh is not None and "model" in mesh.axis_names
                     and E % mesh.shape["model"] == 0) else None

    def expert_mats(name):
        # NOTE: routed experts keep the ragged dequant-to-bf16 path below
        # (per-expert codes under shard_map; DESIGN.md §5).  Every DENSE
        # leaf in this file (attention, shared/parallel FFN, lm_head)
        # goes through cm.dense → qlinear and rides the one-pass fused
        # Pallas kernel on TPU (docs/kernels.md).
        leaf = p[name]
        if isinstance(leaf, dict) and "qw" in leaf:
            qw = leaf["qw"]
            return ({"codes": qw.w_q, "scale": qw.scale, "packed": qw.packed},
                    qw.had_dim, qw.had_mask)
        if isinstance(leaf, dict):
            return {"w": leaf.get("w", leaf)}, 0, None
        return {"w": leaf}, 0, None

    (mg, g_had, g_mask), (mu, _, _), (md, d_had, _) = (
        expert_mats(n) for n in ("wg", "wu", "wd"))
    hq = hf
    if g_had:  # gate/up folded with Rᵀ on d_model: rotate tokens once
        from repro.core.hadamard import apply_hadamard

        hr = apply_hadamard(hf, g_had)
        # g_mask gates per-layer rotation under a mixed LayerwisePlan
        # (scalar per layer once the scan slices the stack)
        hq = hr if g_mask is None else jnp.where(g_mask > 0, hr, hf)
    static = {k_: v for m in (mg, mu, md) for k_, v in m.items()
              if isinstance(v, bool)}
    mg, mu, md = ({k_: v for k_, v in m.items() if not isinstance(v, bool)}
                  for m in (mg, mu, md))
    packed = static.get("packed", False)

    vm = (jnp.ones((hf.shape[0], 1), jnp.bool_) if vmask is None
          else vmask)

    def fn(hq_, topi_, topv_, vm_, mg_, mu_, md_):
        if "codes" in mg_:
            mg_ = dict(mg_, packed=packed)
            mu_ = dict(mu_, packed=packed)
            md_ = dict(md_, packed=packed)
        return _local_expert_compute(
            hq_, topi_, topv_, mg_, mu_, md_, n_experts=E, k=k,
            capacity_factor=cfg.capacity_factor, axis=tp, wd_had=d_had,
            token_valid=vm_[:, 0])

    dp = tuple(a for a in mesh.axis_names if a != "model") if tp else ()
    dp_sz = 1
    for a in dp:
        dp_sz *= mesh.shape[a]
    if tp is None:
        out = fn(hq, topi, topv, vm, mg, mu, md)
    else:
        # batch=1 decode: tokens don't divide dp → replicate tokens and
        # keep only expert parallelism (every shard sees all tokens)
        xspec = P(dp, None) if hf.shape[0] % dp_sz == 0 else P(None, None)
        espec = jax.tree.map(lambda _: P("model", None, None), mg)
        out = compat.shard_map(
            fn,
            in_specs=(xspec, xspec, xspec, xspec, espec, espec,
                      jax.tree.map(lambda _: P("model", None, None), md)),
            out_specs=xspec
        )(hq, topi, topv, vm, mg, mu, md)
    y = out.reshape(b, s, d)
    if "shared" in p:
        y = y + cm.mlp_apply(p["shared"] | {"ln": None}, h, cfg, policy,
                             residual=False)
    if "dense" in p:  # Arctic parallel dense residual FFN
        y = y + cm.mlp_apply(p["dense"] | {"ln": None}, h, cfg, policy,
                             residual=False)
    return x + y, aux


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    H = cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": cm.init_linear(ks[0], cfg.d_model, H * qd, dtype=dtype),
        "wdkv": cm.init_linear(ks[1], cfg.d_model,
                               cfg.kv_lora_rank + cfg.qk_rope_dim, dtype=dtype),
        "wukv": cm.init_linear(ks[2], cfg.kv_lora_rank,
                               H * (cfg.qk_nope_dim + cfg.v_head_dim), dtype=dtype),
        "wo": cm.init_linear(ks[3], H * cfg.v_head_dim, cfg.d_model, dtype=dtype),
        "kv_ln": cm.init_rms(cfg.kv_lora_rank, dtype),
        "ln": cm.init_rms(cfg.d_model, dtype),
    }


def mla_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
              layer_kv: dict | None = None, length=0,
              policy: QuantPolicy | None = None, taps: dict | None = None,
              page_table: jax.Array | None = None,
              valid_new: jax.Array | None = None,
              prefill_local: bool = False):
    """MLA block. Cache stores the compressed latent (c_kv, k_rope) only.

    ``length`` may be a (b,) vector of per-row cache depths (slot-major
    batched decode), mirroring :func:`repro.models.common.attn_apply` —
    as do ``page_table`` / ``valid_new`` / ``prefill_local``, which
    switch the latent cache to the paged pool layout.

    Paged-attention dispatch: MLA resolves to the "xla" gather in
    :func:`repro.models.common.paged_attn_backend` by construction —
    the cached latent must be up-projected through ``wukv`` into
    per-head K/V *before* attention, so the contiguous latent view is
    load-bearing (it feeds a matmul), not an attention-internal
    materialization the in-VMEM kernel could elide.  An absorbed-MLA
    kernel (folding W_uk/W_uv into q/out — changes matmul order, hence
    greedy numerics) is the documented follow-up
    (docs/paged_attention.md).
    """
    b, s, _ = x.shape
    H = cfg.num_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h = cm.rms_norm(x, p.get("ln"), cfg.norm_eps)
    if taps is not None:  # q and down-kv projections share this input
        taps["k_proj"] = h
    q = cm.dense(h, p["wq"], policy).reshape(b, s, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    dkv = cm.dense(h, p["wdkv"], policy)
    c_kv, k_rope = dkv[..., : cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    larr = jnp.asarray(length)
    pos = (larr[:, None] + jnp.arange(s)[None]) if larr.ndim \
        else (jnp.arange(s) + larr)
    cos, sin = cm.rope_angles(pos, rd, cfg.rope_theta)
    q_rope = cm.apply_rope(q_rope, cos, sin)
    k_rope = cm.apply_rope(k_rope[:, :, None, :], cos, sin)  # (b,s,1,rd)

    if layer_kv is not None:
        # cache latent: k slot stores c_kv (b,S,1,lora), v slot stores k_rope
        k_store = c_kv[:, :, None, :]
        v_store = jnp.pad(k_rope, ((0, 0), (0, 0), (0, 0),
                                   (0, cfg.kv_lora_rank - rd)))
        if page_table is not None:                   # paged latent pool
            layer_kv = cm.paged_update(layer_kv, k_store, v_store, length,
                                       page_table, valid_new=valid_new)
            if prefill_local:
                if layer_kv.get("k_scale") is not None:
                    c_all = cm.quant_roundtrip_kv(k_store)[:, :, 0, :]
                    k_rope_all = cm.quant_roundtrip_kv(v_store)[:, :, 0, :rd]
                else:
                    c_all = c_kv
                    k_rope_all = k_rope[:, :, 0, :]
                valid = None
            else:
                # latent decode stays on the gather view — see the
                # paged-attention dispatch note in the docstring
                ck, kr = cm.paged_view(layer_kv, page_table)
                c_all = ck[:, :, 0, :]
                k_rope_all = kr[:, :, 0, :rd]
                valid = jnp.minimum(jnp.asarray(length) + s, ck.shape[1])
        else:
            layer_kv = cm.cache_update(layer_kv, k_store, v_store, length,
                                       window=cfg.attn_window)
            ck, kr = cm.cache_read(layer_kv)
            c_all = ck[:, :, 0, :]                   # (b, S, lora)
            k_rope_all = kr[:, :, 0, :rd]            # (b, S, rd)
            valid = jnp.minimum(jnp.asarray(length) + s, c_all.shape[1])
    else:
        c_all, k_rope_all = c_kv, k_rope[:, :, 0, :]
        valid = None
    c_all = cm.rms_norm(c_all, p.get("kv_ln"), cfg.norm_eps)
    if taps is not None:  # the compressed-latent rotation site (DESIGN §5)
        taps["kv_up"] = c_all
    ukv = cm.dense(c_all, p["wukv"], policy).reshape(b, -1, H, nd + vd)
    k_nope, v = ukv[..., :nd], ukv[..., nd:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :],
                                  (*k_nope.shape[:3], rd))], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    if layer_kv is not None:
        out = cm.attention_scores(qfull, k, v, causal=(s > 1),
                                  q_offset=length, length=valid)
    else:
        out = cm.attention_scores(qfull, k, v, causal=True,
                                  window=cfg.attn_window)
    o_in = out.reshape(b, s, H * vd)
    if taps is not None:
        taps["o_proj"] = o_in
    y = cm.dense(o_in, p["wo"], policy)
    return x + y, layer_kv


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def _is_dense_layer(cfg: ModelConfig, idx: int) -> bool:
    return idx < cfg.first_dense_layers


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    k_emb, k_dense, k_moe, k_out = jax.random.split(key, 4)
    n_dense = cfg.first_dense_layers
    n_moe = cfg.num_layers - n_dense
    attn_init = init_mla if cfg.kv_lora_rank else cm.init_attn

    def init_moe_layer(k):
        ka, km, kd = jax.random.split(k, 3)
        p = {"attn": attn_init(ka, cfg, dtype), "moe": init_moe_ffn(km, cfg, dtype)}
        if cfg.dense_residual:
            d_p = cm.init_mlp(kd, cfg.d_model, cfg.d_ff, dtype)
            d_p.pop("ln")
            p["moe"]["dense"] = d_p
        return p

    def init_dense_layer(k):
        ka, km = jax.random.split(k)
        return {"attn": attn_init(ka, cfg, dtype),
                "mlp": cm.init_mlp(km, cfg.d_model, cfg.d_ff, dtype)}

    params = {
        "embed": cm.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "moe_layers": cm.stack_layer_params(
            jax.random.split(k_moe, n_moe), init_moe_layer),
        "final_ln": cm.init_rms(cfg.d_model, dtype),
        "lm_head": cm.init_linear(k_out, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }
    if n_dense:
        params["dense_layers"] = cm.stack_layer_params(
            jax.random.split(k_dense, n_dense), init_dense_layer)
    return params


def _attn(cfg):
    return mla_apply if cfg.kv_lora_rank else cm.attn_apply


def _backbone(params, cfg: ModelConfig, h, *, cache=None, length=0,
              policy=None, collect_taps=False, page_table=None,
              valid_new=None, prefill_local=False, token_valid=None):
    attn = _attn(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    paged = isinstance(cache, cm.PagedKVCache)
    if paged and page_table is None:
        page_table = cache.page_table

    def moe_block(lp, x, extra):
        layer_kv = extra
        taps = {} if collect_taps else None
        x, layer_kv = attn(lp["attn"], x, cfg, layer_kv=layer_kv,
                           length=length, policy=policy,
                           page_table=page_table, valid_new=valid_new,
                           prefill_local=prefill_local)
        x, aux = moe_ffn(lp["moe"], x, cfg, policy, taps=taps,
                         valid=token_valid)
        y = taps if collect_taps else layer_kv
        return x, (y, aux)

    def dense_block(lp, x, extra):
        layer_kv = extra
        x, layer_kv = attn(lp["attn"], x, cfg, layer_kv=layer_kv,
                           length=length, policy=policy,
                           page_table=page_table, valid_new=valid_new,
                           prefill_local=prefill_local)
        x = cm.mlp_apply(lp["mlp"], x, cfg, policy)
        return x, (layer_kv, jnp.zeros((), jnp.float32))

    n_dense = cfg.first_dense_layers
    caches_out = []
    for name, block, n in (("dense_layers", dense_block, n_dense),
                           ("moe_layers", moe_block,
                            cfg.num_layers - n_dense)):
        if n == 0:
            continue
        if cache is None:
            extras = None
            fn = lambda lp, x, _ , _b=block: _b(lp, x, None)
        else:
            lo = 0 if name == "dense_layers" else n_dense
            kv = {"k": cache.k[lo:lo + n], "v": cache.v[lo:lo + n]}
            if cache.quantized:
                kv.update(k_scale=cache.k_scale[lo:lo + n],
                          v_scale=cache.v_scale[lo:lo + n])
            extras = kv
            fn = block
        h, (ys, aux) = cm.scan_layers(fn, params[name], h,
                                      remat=cfg.remat and cache is None,
                                      extras=extras,
                                      sp=cfg.seq_parallel and cache is None,
                                      remat_policy=cfg.remat_policy)
        aux_total = aux_total + jnp.sum(aux)
        if cache is not None:
            caches_out.append(ys)
    if cache is not None:
        merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *caches_out) \
            if len(caches_out) > 1 else caches_out[0]
        # replace() serves both cache classes (page_table rides along
        # untouched on the paged one)
        new_cache = dataclasses.replace(
            cache, k=merged["k"], v=merged["v"],
            k_scale=merged.get("k_scale"), v_scale=merged.get("v_scale"),
            length=cache.length + h.shape[1])
    else:
        new_cache = None
    h = cm.rms_norm(h, params.get("final_ln"), cfg.norm_eps)
    return h, new_cache, aux_total


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            policy: QuantPolicy | None = None):
    h = cm.embed(params["embed"], tokens) if embeds is None else embeds
    x, _, aux = _backbone(params, cfg, h, policy=policy)
    return cm.dense(x, params["lm_head"], policy), aux


def train_loss(params, cfg: ModelConfig, batch, aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, batch.get("tokens"),
                          embeds=batch.get("embeds"))
    ce = cm.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                          batch.get("mask"))
    return ce + aux_weight * aux


def make_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               bits: int | None = None) -> cm.KVCache:
    if cfg.kv_lora_rank:
        # latent cache: one "head" of width kv_lora_rank (stores c_kv; the
        # v slot stores k_rope padded to the same width)
        return cm.init_kv_cache(cfg, cfg.num_layers, batch, max_len, bits=bits,
                                head_dim=cfg.kv_lora_rank, kv_heads=1)
    return cm.init_kv_cache(cfg, cfg.num_layers, batch, max_len, bits=bits)


def make_paged_cache(cfg: ModelConfig, slots: int, max_len: int, *,
                     page_size: int = 64, n_pages: int | None = None,
                     bits: int | None = None) -> cm.PagedKVCache:
    if cfg.kv_lora_rank:
        return cm.init_paged_kv_cache(
            cfg, cfg.num_layers, slots, max_len, page_size=page_size,
            n_pages=n_pages, bits=bits, head_dim=cfg.kv_lora_rank, kv_heads=1)
    return cm.init_paged_kv_cache(cfg, cfg.num_layers, slots, max_len,
                                  page_size=page_size, n_pages=n_pages,
                                  bits=bits)


def prefill(params, cfg: ModelConfig, tokens, cache, policy=None):
    h = cm.embed(params["embed"], tokens)
    x, cache, _ = _backbone(params, cfg, h, cache=cache, length=0, policy=policy)
    return cm.dense(x[:, -1:], params["lm_head"], policy), cache


def prefill_paged(params, cfg: ModelConfig, tokens, lengths,
                  cache: cm.PagedKVCache, slots, policy=None):
    """In-engine batched prefill into assigned pages (right-padded rows;
    see :func:`repro.models.transformer.prefill_paged`).  Padding tokens
    are masked out of expert-capacity competition via ``moe_ffn``'s
    ``valid`` mask."""
    s = tokens.shape[1]
    h = cm.embed(params["embed"], tokens)
    ptab = cm.gather_page_rows(cache.page_table, slots)
    token_valid = jnp.arange(s)[None] < jnp.asarray(lengths)[:, None]
    x, new_cache, _ = _backbone(params, cfg, h, cache=cache, length=0,
                                policy=policy, page_table=ptab,
                                valid_new=lengths, prefill_local=True,
                                token_valid=token_valid)
    logits = cm.dense(cm.take_last_valid(x, lengths), params["lm_head"], policy)
    new_cache = dataclasses.replace(
        new_cache, length=cache.length.at[jnp.asarray(slots)].set(
            jnp.asarray(lengths, jnp.int32), mode="drop"))
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache, policy=None):
    """One token per sequence.  ``cache.length`` may be a scalar or a
    per-slot (b,) vector (slot-major batched serving) — GQA and MLA
    attention both thread it as per-row positions."""
    h = cm.embed(params["embed"], tokens)
    x, cache, _ = _backbone(params, cfg, h, cache=cache, length=cache.length,
                            policy=policy)
    return cm.dense(x, params["lm_head"], policy), cache


def forward_with_taps(params, cfg: ModelConfig, tokens=None, *, embeds=None,
                      policy=None):
    h = cm.embed(params["embed"], tokens) if embeds is None else embeds
    attn = _attn(cfg)
    # taps only from moe layers (the paper's sites); dense layers skipped
    def block(lp, x, _):
        taps = {}
        x, _kv = attn(lp["attn"], x, cfg, policy=policy, taps=taps)
        x, aux = moe_ffn(lp["moe"], x, cfg, policy, taps=taps)
        return x, taps
    if cfg.first_dense_layers:
        def dense_fn(lp, x, _):
            x, _kv = attn(lp["attn"], x, cfg)
            return cm.mlp_apply(lp["mlp"], x, cfg), ()
        h, _ = cm.scan_layers(dense_fn, params["dense_layers"], h, remat=False)
    h, taps = cm.scan_layers(block, params["moe_layers"], h, remat=False)
    h = cm.rms_norm(h, params.get("final_ln"), cfg.norm_eps)
    return cm.dense(h, params["lm_head"]), taps
