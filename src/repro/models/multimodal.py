"""Modality frontends for the audio (MusicGen) and VLM (InternVL2) archs.

Per the task spec these are STUBS: the transformer BACKBONE is the real
model (repro.models.transformer); ``input_specs()`` supplies precomputed
frame/patch embeddings.  The functions here generate such embeddings
deterministically from raw-ish inputs so the examples and smoke tests
have an end-to-end path, and document what a production frontend would
compute (EnCodec tokens → codebook embeddings; ViT patches → projected
visual tokens).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["encodec_frame_embeddings", "vit_patch_embeddings"]


def encodec_frame_embeddings(key, cfg: ModelConfig, batch: int, seq: int,
                             n_codebooks: int = 4) -> jax.Array:
    """Stand-in for EnCodec→embedding: sums n_codebooks codebook embeddings
    per frame (MusicGen's delay-pattern flattening is upstream of the
    backbone and out of scope per the task spec)."""
    ks = jax.random.split(key, n_codebooks + 1)
    tables = [jax.random.normal(k, (cfg.vocab_size, cfg.d_model)) * 0.02
              for k in ks[:n_codebooks]]
    tokens = jax.random.randint(ks[-1], (batch, seq, n_codebooks), 0,
                                cfg.vocab_size)
    emb = sum(jnp.take(t, tokens[..., i], axis=0) for i, t in enumerate(tables))
    return emb.astype(jnp.bfloat16)


def vit_patch_embeddings(key, cfg: ModelConfig, batch: int, seq: int,
                         patch: int = 14, channels: int = 3) -> jax.Array:
    """Stand-in for InternViT: projects random 'pixel patches' to d_model
    (a real frontend runs the ViT tower + pixel-shuffle + MLP projector)."""
    k_img, k_proj = jax.random.split(key)
    pixels = jax.random.normal(k_img, (batch, seq, patch * patch * channels))
    proj = jax.random.normal(k_proj, (patch * patch * channels, cfg.d_model)) * 0.02
    return (pixels @ proj).astype(jnp.bfloat16)
