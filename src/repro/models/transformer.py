"""Decoder-only dense transformer (LLaMA family; also the audio/VLM
backbones, whose modality frontends are stubs supplying embeddings).

Exposes the uniform model API consumed by launch/ and serving/:

    init(key, cfg)                          → params
    forward(params, cfg, tokens|embeds, …)  → logits
    train_loss(params, cfg, batch)          → scalar
    prefill / decode_step                   → serving path (+ KV cache)
    forward_with_taps                       → calibration taps per module
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.qlinear import QuantPolicy
from repro.models import common as cm

# prefill_paged accepts per-row ``start`` offsets (chunked prefill —
# docs/serving.md).  The SSM/hybrid/MLA families don't: their prefill
# state (chunked-scan SSD final states, per-invocation shared-attention
# KV, latent pools) has no continuation path, so the paged engine falls
# back to whole-prompt prefill for them.
supports_chunked_prefill = True


def init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)

    def init_layer(k):
        ka, km = jax.random.split(k)
        return {"attn": cm.init_attn(ka, cfg, dtype),
                "mlp": cm.init_mlp(km, cfg.d_model, cfg.d_ff, dtype)}

    return {
        "embed": cm.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": cm.stack_layer_params(layer_keys, init_layer),
        "final_ln": cm.init_rms(cfg.d_model, dtype),
        "lm_head": cm.init_linear(k_out, cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def _block(cfg: ModelConfig, policy: QuantPolicy | None, collect_taps: bool,
           page_table=None, valid_new=None, prefill_local: bool = False):
    def block(lp, x, layer_kv_and_len):
        layer_kv, length = (None, 0) if layer_kv_and_len is None else layer_kv_and_len
        taps: dict | None = {} if collect_taps else None
        x, layer_kv = cm.attn_apply(lp["attn"], x, cfg, layer_kv=layer_kv,
                                    length=length, policy=policy, taps=taps,
                                    page_table=page_table, valid_new=valid_new,
                                    prefill_local=prefill_local)
        x = cm.mlp_apply(lp["mlp"], x, cfg, policy, taps=taps)
        out = taps if collect_taps else layer_kv
        return x, out
    return block


def _backbone(params, cfg: ModelConfig, h, *, cache=None, length=0,
              policy=None, collect_taps=False, page_table=None,
              valid_new=None, prefill_local=False):
    if isinstance(cache, cm.PagedKVCache) and page_table is None:
        page_table = cache.page_table
    block = _block(cfg, policy, collect_taps, page_table, valid_new,
                   prefill_local)
    if cache is None:
        extras = None
        def fn(lp, x, _):
            return block(lp, x, None)
        x, ys = cm.scan_layers(fn, params["layers"], h, remat=cfg.remat,
                               extras=None, sp=cfg.seq_parallel,
                               remat_policy=cfg.remat_policy)
        new_cache = ys if collect_taps else None
    else:
        kv = {"k": cache.k, "v": cache.v}
        if cache.quantized:
            kv.update(k_scale=cache.k_scale, v_scale=cache.v_scale)
        def fn(lp, x, layer_kv):
            return block(lp, x, (layer_kv, length))
        x, kv_new = cm.scan_layers(fn, params["layers"], h, remat=False,
                                   extras=kv)
        # replace() serves both cache classes (page_table rides along
        # untouched on the paged one)
        new_cache = dataclasses.replace(
            cache, k=kv_new["k"], v=kv_new["v"],
            k_scale=kv_new.get("k_scale"), v_scale=kv_new.get("v_scale"),
            length=cache.length + h.shape[1])
    x = cm.rms_norm(x, params.get("final_ln"), cfg.norm_eps)
    return x, new_cache


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            policy: QuantPolicy | None = None):
    h = cm.embed(params["embed"], tokens) if embeds is None else embeds
    x, _ = _backbone(params, cfg, h, policy=policy)
    return cm.dense(x, params["lm_head"], policy)


def forward_with_taps(params, cfg: ModelConfig, tokens=None, *, embeds=None,
                      policy=None):
    h = cm.embed(params["embed"], tokens) if embeds is None else embeds
    x, taps = _backbone(params, cfg, h, policy=policy, collect_taps=True)
    return cm.dense(x, params["lm_head"]), taps


def train_loss(params, cfg: ModelConfig, batch):
    logits = forward(params, cfg, batch.get("tokens"),
                     embeds=batch.get("embeds"))
    labels, mask = batch["labels"], batch.get("mask")
    return cm.cross_entropy(logits[:, :-1], labels[:, 1:],
                            None if mask is None else mask[:, 1:])


def make_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               bits: int | None = None) -> cm.KVCache:
    return cm.init_kv_cache(cfg, cfg.num_layers, batch, max_len, bits=bits)


def make_paged_cache(cfg: ModelConfig, slots: int, max_len: int, *,
                     page_size: int = 64, n_pages: int | None = None,
                     bits: int | None = None) -> cm.PagedKVCache:
    return cm.init_paged_kv_cache(cfg, cfg.num_layers, slots, max_len,
                                  page_size=page_size, n_pages=n_pages,
                                  bits=bits)


def prefill(params, cfg: ModelConfig, tokens, cache: cm.KVCache,
            policy: QuantPolicy | None = None):
    h = cm.embed(params["embed"], tokens)
    x, cache = _backbone(params, cfg, h, cache=cache, length=0, policy=policy)
    logits = cm.dense(x[:, -1:], params["lm_head"], policy)
    return logits, cache


def prefill_paged(params, cfg: ModelConfig, tokens, lengths,
                  cache: cm.PagedKVCache, slots,
                  policy: QuantPolicy | None = None, start=None):
    """In-engine batched prefill straight into assigned pages.

    tokens: (n, s_pad) right-padded prompts sharing ONE dispatch via
    length-bucketed padding; lengths: (n,) real prompt lengths; cache:
    the engine's FULL paged cache (donated by the engine's jit); slots:
    (n,) slot ids the rows were admitted into (== slot count for padding
    rows, whose writes all drop).  Returns per-row logits at the last
    VALID position, (n, 1, vocab), and the updated cache.

    ``start`` (chunked prefill): (n,) per-row offsets of tokens already
    written to each slot's pages.  Rows then write this chunk at
    ``start + [0, s_pad)`` and attend over their pool prefix through the
    ``paged_view`` gather (RoPE positions and the causal mask carry the
    offset; keys past a row's written prefix are either causally masked
    or exact zeros after masking, so chunked logits match the one-shot
    dispatch).  ``lengths`` stays the CHUNK's valid token count.
    """
    h = cm.embed(params["embed"], tokens)
    ptab = cm.gather_page_rows(cache.page_table, slots)
    if start is None:
        x, new_cache = _backbone(params, cfg, h, cache=cache, length=0,
                                 policy=policy, page_table=ptab,
                                 valid_new=lengths, prefill_local=True)
        new_len = jnp.asarray(lengths, jnp.int32)
    else:
        starts = jnp.asarray(start, jnp.int32)
        x, new_cache = _backbone(params, cfg, h, cache=cache, length=starts,
                                 policy=policy, page_table=ptab,
                                 valid_new=lengths, prefill_local=False)
        new_len = starts + jnp.asarray(lengths, jnp.int32)
    logits = cm.dense(cm.take_last_valid(x, lengths), params["lm_head"], policy)
    new_cache = dataclasses.replace(
        new_cache, length=cache.length.at[jnp.asarray(slots)].set(
            new_len, mode="drop"))
    return logits, new_cache


def verify_paged(params, cfg: ModelConfig, tokens, lengths,
                 cache: cm.PagedKVCache, slots, start,
                 policy: QuantPolicy | None = None):
    """Speculative verify: score k+1 candidate positions per slot in ONE
    ragged dispatch (docs/speculative.md).

    Identical to the chunked-prefill continuation path of
    :func:`prefill_paged` — same per-row ``start`` offsets, same pool
    writes (int8 scale leaves included), same ``paged_view`` prefix
    gather — except logits come back for ALL ``s_pad`` positions,
    (n, s_pad, vocab), not just the last valid one: position j's row is
    exactly what a plain s=1 decode dispatch at depth ``start + j``
    would have produced, which is what makes greedy acceptance
    bit-identical to non-speculative greedy decode.  Rows beyond a row's
    ``lengths`` are garbage (the engine never reads them); rejected
    suffix writes are rolled back host-side by the engine (lengths +
    page refcounts), not here.
    """
    h = cm.embed(params["embed"], tokens)
    ptab = cm.gather_page_rows(cache.page_table, slots)
    starts = jnp.asarray(start, jnp.int32)
    x, new_cache = _backbone(params, cfg, h, cache=cache, length=starts,
                             policy=policy, page_table=ptab,
                             valid_new=lengths, prefill_local=False)
    new_len = starts + jnp.asarray(lengths, jnp.int32)
    logits = cm.dense(x, params["lm_head"], policy)
    new_cache = dataclasses.replace(
        new_cache, length=cache.length.at[jnp.asarray(slots)].set(
            new_len, mode="drop"))
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, tokens, cache: cm.KVCache,
                policy: QuantPolicy | None = None):
    """One token per sequence against the cache.

    ``cache.length`` may be a scalar (all rows at the same depth) or a
    (batch,) vector of per-row depths — the slot-major batched serving
    path, where each slot carries its own position (RoPE, cache write,
    valid-length mask are all per row; see ``common.batch_slot_cache``).
    """
    h = cm.embed(params["embed"], tokens)
    x, cache = _backbone(params, cfg, h, cache=cache, length=cache.length,
                         policy=policy)
    logits = cm.dense(x, params["lm_head"], policy)
    return logits, cache
