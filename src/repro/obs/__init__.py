"""Serving-stack observability: metrics registry, request tracing,
quant-health telemetry (docs/observability.md).

Zero-dependency by design (stdlib + numpy only on the sampling path):
the serving engines always carry a :class:`MetricsRegistry` for their
``run_stats`` counters, and attach the rest — span tracing, latency
histograms, quant-health sampling — only when the caller hands them an
:class:`Observability`:

    from repro import obs
    o = obs.Observability(trace_path="trace.jsonl")
    eng = PagedServingEngine(model, params, cfg, obs=o)
    eng.run()
    print(obs.format_summary(o.summary()))

``python -m repro.obs trace.jsonl`` rebuilds the same tables offline
from the JSONL event log.
"""

from __future__ import annotations

import time

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    ManualClock,
    MetricsRegistry,
    exact_percentile,
    percentile_summary,
)
from repro.obs.quant_health import QuantHealthSampler
from repro.obs.summary import format_summary, summarize
from repro.obs.trace import Tracer, load_trace

__all__ = ["Counter", "Gauge", "Histogram", "ManualClock", "MetricsRegistry",
           "Observability", "QuantHealthSampler", "Tracer", "load_trace",
           "summarize", "format_summary", "exact_percentile",
           "percentile_summary"]


class Observability:
    """The bundle an engine consumes: registry + tracer + clock
    (+ optional quant-health sampler).  One injectable clock drives
    every span/timestamp, so tests swap in :class:`ManualClock` and the
    whole pipeline — engine spans, histograms, trace summaries — is
    deterministic."""

    def __init__(self, *, registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 quant_health: QuantHealthSampler | None = None,
                 clock=None, trace_path: str | None = None):
        self.clock = clock if clock is not None else time.perf_counter
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = (tracer if tracer is not None
                       else Tracer(trace_path, clock=self.clock))
        self.quant_health = quant_health

    def summary(self) -> dict:
        """Aggregate the collected trace into the latency summary."""
        return summarize(self.tracer.events)

    def close(self) -> None:
        self.tracer.close()
