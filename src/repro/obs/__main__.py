"""Offline trace summarizer: the serve-time tables from a JSONL log.

    PYTHONPATH=src python -m repro.obs trace.jsonl [--json]

Reads a trace written by ``launch/serve.py --trace-out`` (or any
:class:`repro.obs.Tracer`) and prints the same latency/count/
quant-health tables the live run printed — byte-identical numbers, so
traces can be shipped and analyzed away from the serving host
(tests/test_obs.py pins the round trip).  ``--json`` emits the raw
summary dict for tooling instead of the markdown tables.
"""

from __future__ import annotations

import argparse
import json

from repro.obs.summary import format_summary, summarize
from repro.obs.trace import load_trace


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    ap.add_argument("trace", help="trace JSONL (launch/serve.py --trace-out)")
    ap.add_argument("--json", action="store_true",
                    help="print the summary dict as JSON instead of tables")
    args = ap.parse_args(argv)
    s = summarize(load_trace(args.trace))
    if args.json:
        print(json.dumps(s, indent=1, sort_keys=True))
    else:
        print(format_summary(s))


if __name__ == "__main__":
    main()
