"""Zero-dependency metrics primitives for the serving stack.

A :class:`MetricsRegistry` names three instrument kinds:

  * :class:`Counter`   — monotone float (dispatch counts, token totals,
    modeled HBM bytes per backend);
  * :class:`Gauge`     — last-write-wins float (pool occupancy);
  * :class:`Histogram` — fixed upper-bound buckets for cheap shape
    inspection PLUS an exact reservoir of every observation, so the
    p50/p90/p99 the latency reports quote are nearest-rank EXACT (no
    bucket interpolation).  Serving runs observe thousands of spans,
    not millions — the reservoir is bounded by ``reservoir_cap`` and
    decimates deterministically (every 2nd kept) if a run overflows it,
    which keeps percentiles exact for every workload the benchmarks and
    tests drive.

Every clock in the subsystem is injectable (:class:`ManualClock` in
tests) so span durations and percentiles are deterministic under test.
The registry itself never touches a clock — callers time spans and
``observe`` the durations.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "ManualClock", "exact_percentile", "percentile_summary"]

# upper bounds in seconds, tuned for serve-time spans (sub-ms ticks to
# multi-second prefills); +inf is implicit
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0,
                   3.0, 10.0)


class ManualClock:
    """Deterministic test clock: call → current time, advance() moves it."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


def exact_percentile(sorted_xs: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of an ASCENDING list."""
    if not sorted_xs:
        return float("nan")
    rank = max(int(math.ceil(q / 100.0 * len(sorted_xs))), 1)
    return sorted_xs[rank - 1]


def percentile_summary(xs: list[float]) -> dict:
    """count/mean/min/max + exact p50/p90/p99 of a sample list."""
    if not xs:
        return {"count": 0}
    s = sorted(xs)
    return {
        "count": len(s),
        "mean": sum(s) / len(s),
        "min": s[0],
        "max": s[-1],
        "p50": exact_percentile(s, 50),
        "p90": exact_percentile(s, 90),
        "p99": exact_percentile(s, 99),
    }


@dataclasses.dataclass
class Counter:
    name: str
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        self.value += amount


@dataclasses.dataclass
class Gauge:
    name: str
    value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Fixed-bucket histogram + exact observation reservoir."""

    def __init__(self, name: str, buckets: tuple[float, ...] = DEFAULT_BUCKETS,
                 reservoir_cap: int = 65536):
        self.name = name
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # last = +inf
        self.count = 0
        self.total = 0.0
        self.reservoir: list[float] = []
        self.reservoir_cap = reservoir_cap

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                self.bucket_counts[i] += 1
                break
        else:
            self.bucket_counts[-1] += 1
        self.reservoir.append(value)
        if len(self.reservoir) > self.reservoir_cap:
            # deterministic decimation: keep every other sample; the cap
            # is far above any bench/test workload, so in practice the
            # reservoir is the full observation set (exact percentiles)
            self.reservoir = self.reservoir[::2]

    def percentile(self, q: float) -> float:
        return exact_percentile(sorted(self.reservoir), q)

    def summary(self) -> dict:
        out = percentile_summary(self.reservoir)
        out.update(total=self.total,
                   buckets={str(ub): c for ub, c in
                            zip(self.buckets, self.bucket_counts)},
                   overflow=self.bucket_counts[-1])
        # count from the reservoir equals self.count unless decimated
        out["count"] = self.count
        return out


class MetricsRegistry:
    """Name → instrument, created on first use (prometheus-style)."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter(name)
        return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge(name)
        return self._gauges[name]

    def histogram(self, name: str,
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        if name not in self._histograms:
            self._histograms[name] = Histogram(name, buckets)
        return self._histograms[name]

    def counters_with_prefix(self, prefix: str) -> dict[str, float]:
        """{suffix: value} of every counter named ``prefix`` + suffix."""
        return {n[len(prefix):]: c.value for n, c in self._counters.items()
                if n.startswith(prefix)}

    def snapshot(self) -> dict:
        """JSON-serializable view of every instrument."""
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self._histograms.items())},
        }
