"""Serve-time quant-health sampling: what real traffic does to the
activations the offline calibration planned for.

The autoplan subsystem scores transforms OFFLINE on a calibration
stream (difficulty profiles in ``repro.autoplan.telemetry``); the
SmoothQuant-style folded scales bake those observed ranges into the
weights.  This sampler closes the loop at serving time: every N engine
ticks it re-runs the family's ``forward_with_taps`` over one active
request's full context (prompt + generated tokens — i.e. the exact
token stream the engine is serving) under the SERVING policy, and
reduces each quantized linear's input tap to three per-layer signals:

  * ``absmax``       — the live activation absolute maximum;
  * ``clip_fraction``— fraction of live values whose magnitude exceeds
    the CALIBRATED per-channel absmax (the range the folded smoothing
    scales / quantizer Δ were derived from).  A drifting workload shows
    up here before it shows up in output quality;
  * ``difficulty``   — the paper's Eq.-2-correlated metric (std of
    channel magnitudes, §II-B) of the observed ranges, directly
    comparable to the pre/post profiles in the autoplan telemetry
    artifacts (same ``modules`` keying as
    :mod:`repro.autoplan.telemetry`).

Sampling is OPT-IN (``--quant-health N`` in launch/serve.py): each
sample costs one extra tap-forward dispatch per bucketed context
length.  With sampling off the engines issue no extra dispatches
(tests/test_obs.py pins this).
"""

from __future__ import annotations

import numpy as np

__all__ = ["QuantHealthSampler"]


def _difficulty(x2: np.ndarray) -> float:
    """std of per-channel Frobenius norms of (tokens, C) samples — the
    numpy twin of ``repro.core.difficulty.quantization_difficulty``
    (host-side so sampling adds no device dispatches beyond the tap
    forward itself)."""
    mags = np.sqrt(np.sum(np.square(x2.astype(np.float64)), axis=0))
    return float(np.std(mags))


class QuantHealthSampler:
    """Every-N-ticks activation health probe over live request contexts."""

    def __init__(self, model, params, cfg, *, policy=None, every: int = 32,
                 reference=None, max_context: int = 256, bucket: int = 16):
        """``reference``: the calibration ``dict[str, CalibStats]`` the
        fold consumed — enables the clip-fraction-vs-calibrated-Δ lens;
        without it only absmax and difficulty are reported.
        ``max_context`` caps the probed PREFIX (a prefix forward is a
        faithful replay; a clipped suffix would not be); ``bucket``
        pads context lengths so the jitted tap forward compiles once
        per bucket, not once per length."""
        import jax

        self.model, self.params, self.cfg = model, params, cfg
        self.policy = policy
        self.every = max(int(every), 1)
        self.max_context = max_context
        self.bucket = max(int(bucket), 1)
        self.samples: list[dict] = []
        self.reference = {
            name: np.asarray(st.act_absmax, np.float32)
            for name, st in (reference or {}).items()
        } or None
        self._tap_fn = jax.jit(
            lambda toks: model.forward_with_taps(params, cfg, toks,
                                                 policy=policy)[1])

    def due(self, tick: int) -> bool:
        return tick % self.every == 0

    def sample(self, tick: int, uid: int, context: np.ndarray) -> dict:
        """Probe one request's context; returns (and stores) the record
        ``{"tick", "uid", "context_len", "modules": {m: {"absmax",
        "clip_fraction", "difficulty"} per layer}}``."""
        ctx = np.asarray(context, np.int64)[: self.max_context]
        t = len(ctx)
        pad = -(-t // self.bucket) * self.bucket
        toks = np.zeros((1, pad), np.int32)
        toks[0, :t] = ctx
        taps = self._tap_fn(toks)
        modules: dict[str, dict] = {}
        for name in sorted(taps):
            arr = np.asarray(taps[name], np.float32)
            if arr.ndim == 3:          # unscanned (B, T, C) → one layer
                arr = arr[None]
            arr = arr[:, :, :t, :]     # (L, B, t, C): drop pad tokens
            L = arr.shape[0]
            flat = arr.reshape(L, -1, arr.shape[-1])
            absmax = np.max(np.abs(flat), axis=(1, 2))
            diff = [_difficulty(flat[l]) for l in range(L)]
            clip = None
            ref = (self.reference or {}).get(name)
            if ref is not None:
                ref_l = np.broadcast_to(
                    ref.reshape(-1, ref.shape[-1]), (L, ref.shape[-1]))
                clip = [float(np.mean(np.abs(flat[l]) > ref_l[l]))
                        for l in range(L)]
            modules[name] = {
                "absmax": [float(v) for v in absmax],
                "clip_fraction": clip,
                "difficulty": diff,
            }
        rec = {"tick": int(tick), "uid": int(uid), "context_len": t,
               "modules": modules}
        self.samples.append(rec)
        return rec
