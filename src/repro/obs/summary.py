"""Trace-event aggregation: one summary dict + its human table.

``summarize`` consumes the event list a :class:`repro.obs.Tracer`
collected (or ``load_trace`` re-read from JSONL) and derives the
latency distributions the ROADMAP's serving items report through:

  * **TTFT** — ``first_token.ttft_s`` per request (submit → the token
    sampled from the prefill logits);
  * **per-token latency** — consecutive token-emission timestamp deltas
    per request (the prefill token's timestamp seeds the chain, each
    ``tick`` event timestamps every token it emitted, and ``token``
    events stamp the one sampled from a RESUME prefill), i.e. the
    inter-token gap a streaming client would observe — admission stalls
    and preemptions show up here, not just raw decode time;
  * **queue wait** — ``admit.queue_wait_s`` (submit → slot assignment);
  * **tick breakdown** — total tick span, host-side page-allocation
    span (paged engine), and the decode dispatch+sample remainder;
  * **prefill spans** and request/token/preemption counts;
  * **quant-health** aggregates when sampling was enabled (worst
    per-module clip fraction / absmax, mean Eq.-2 difficulty —
    docs/observability.md ties these to the paper's metric).

The same numbers print from ``python -m repro.obs trace.jsonl`` — the
JSONL round trip is exact (tests/test_obs.py).
"""

from __future__ import annotations

from repro.obs.metrics import percentile_summary

__all__ = ["summarize", "format_summary"]

_SPAN_ROWS = (
    ("ttft_s", "TTFT"),
    ("per_token_s", "per-token"),
    ("queue_wait_s", "queue wait"),
    ("prefill_s", "prefill span"),
    ("tick_s", "tick"),
    ("tick_alloc_s", "tick: page alloc"),
    ("tick_decode_s", "tick: decode+sample"),
    ("e2e_s", "end-to-end"),
)


def summarize(events: list[dict]) -> dict:
    """Aggregate a trace-event list into the latency/count summary."""
    token_ts: dict[int, list[float]] = {}   # uid → emission timestamps
    ttft, queue_wait, prefill_dur = [], [], []
    tick_dur, alloc_dur, decode_dur = [], [], []
    e2e = []
    counts = {"submitted": 0, "admitted": 0, "retired": 0, "preemptions": 0,
              "resumes": 0, "decode_tokens": 0, "prefill_tokens": 0,
              "ticks": 0, "cancelled": 0, "deadline_expired": 0, "shed": 0,
              "failed": 0, "faults_injected": 0, "guard_trips": 0,
              "breaker_trips": 0, "breaker_recoveries": 0,
              "watchdog_restarts": 0, "disconnects": 0}
    qh_events = []
    spec = {"ticks": 0, "drafted": 0, "accepted": 0, "rejected": 0,
            "emitted": 0}
    for ev in events:
        kind = ev["ev"]
        if kind == "submit":
            counts["submitted"] += 1
        elif kind == "admit":
            counts["admitted"] += 1
            queue_wait.append(ev["queue_wait_s"])
            counts["resumes"] += bool(ev.get("resumed"))
        elif kind == "prefill":
            prefill_dur.append(ev["dur_s"])
            counts["prefill_tokens"] += ev["n_tokens"]
        elif kind == "first_token":
            ttft.append(ev["ttft_s"])
            token_ts.setdefault(ev["uid"], []).append(ev["ts"])
        elif kind == "token":
            # a streamed token emitted outside the tick path (resume
            # prefill): a real token the client received — it counts and
            # joins the per-token chain
            counts["decode_tokens"] += 1
            token_ts.setdefault(ev["uid"], []).append(ev["ts"])
        elif kind == "tick":
            counts["ticks"] += 1
            tick_dur.append(ev["dur_s"])
            if "alloc_dur_s" in ev:
                alloc_dur.append(ev["alloc_dur_s"])
                decode_dur.append(ev["dur_s"] - ev["alloc_dur_s"])
            for uid in ev["uids"]:
                counts["decode_tokens"] += 1
                token_ts.setdefault(uid, []).append(ev["ts"])
        elif kind == "preempt":
            counts["preemptions"] += 1
        elif kind == "retire":
            counts["retired"] += 1
            counts["cancelled"] += bool(ev.get("cancelled"))
            counts["failed"] += bool(ev.get("failed"))
            e2e.append(ev["e2e_s"])
        elif kind == "deadline":
            counts["deadline_expired"] += 1
        elif kind == "shed":
            counts["shed"] += 1
        elif kind == "fault":
            counts["faults_injected"] += 1
        elif kind == "guard":
            counts["guard_trips"] += 1
        elif kind == "breaker":
            if ev.get("action") == "trip":
                counts["breaker_trips"] += 1
            elif ev.get("action") == "recover":
                counts["breaker_recoveries"] += 1
        elif kind == "watchdog":
            counts["watchdog_restarts"] += ev.get("action") == "restart"
        elif kind == "disconnect":
            counts["disconnects"] += 1
        elif kind == "quant_health":
            qh_events.append(ev)
        elif kind == "spec":
            # per-tick speculative accounting (docs/speculative.md); the
            # accepted tokens themselves arrived as tick uids + extra
            # ``token`` events, so decode_tokens already counts them
            spec["ticks"] += 1
            for k in ("drafted", "accepted", "rejected", "emitted"):
                spec[k] += ev.get(k, 0)
    per_token = [b - a for ts in token_ts.values()
                 for a, b in zip(ts, ts[1:])]
    out = {
        "counts": counts,
        "ttft_s": percentile_summary(ttft),
        "per_token_s": percentile_summary(per_token),
        "queue_wait_s": percentile_summary(queue_wait),
        "prefill_s": percentile_summary(prefill_dur),
        "tick_s": percentile_summary(tick_dur),
        "tick_alloc_s": percentile_summary(alloc_dur),
        "tick_decode_s": percentile_summary(decode_dur),
        "e2e_s": percentile_summary(e2e),
    }
    if spec["ticks"]:
        # present only when speculation ran (the exact-counts pin on
        # plain-run summaries is untouched)
        spec["acceptance_rate"] = spec["accepted"] / max(spec["drafted"], 1)
        out["spec"] = spec
    if qh_events:
        out["quant_health"] = _quant_health_summary(qh_events)
    return out


def _quant_health_summary(qh_events: list[dict]) -> dict:
    """Per-module worst-case view over every quant-health sample."""
    mods: dict[str, dict] = {}
    for ev in qh_events:
        for m, rec in ev["modules"].items():
            agg = mods.setdefault(m, {"samples": 0, "absmax_max": 0.0,
                                      "clip_fraction_max": None,
                                      "difficulty_sum": 0.0,
                                      "difficulty_n": 0})
            agg["samples"] += 1
            agg["absmax_max"] = max(agg["absmax_max"], max(rec["absmax"]))
            if rec.get("clip_fraction") is not None:
                cf = max(rec["clip_fraction"])
                agg["clip_fraction_max"] = (
                    cf if agg["clip_fraction_max"] is None
                    else max(agg["clip_fraction_max"], cf))
            agg["difficulty_sum"] += sum(rec["difficulty"])
            agg["difficulty_n"] += len(rec["difficulty"])
    return {
        m: {"samples": a["samples"], "absmax_max": a["absmax_max"],
            "clip_fraction_max": a["clip_fraction_max"],
            "difficulty_mean": (a["difficulty_sum"]
                                / max(a["difficulty_n"], 1))}
        for m, a in sorted(mods.items())
    }


def _fmt(v: float | None) -> str:
    if v is None:
        return "—"
    return f"{v:.6f}" if isinstance(v, float) else str(v)


def format_summary(s: dict) -> str:
    """The summary dict as markdown tables (serve.py end-of-run report
    and the ``repro.obs`` CLI print the same thing)."""
    c = s["counts"]
    lines = [
        f"requests: {c['submitted']} submitted, {c['admitted']} admitted "
        f"({c['resumes']} resumes), {c['retired']} retired, "
        f"{c['preemptions']} preemptions",
        f"tokens: {c['prefill_tokens']} prefill, {c['decode_tokens']} decode "
        f"over {c['ticks']} ticks",
    ]
    # front-end admission/deadline outcomes only when any occurred, so
    # offline-run tables are unchanged
    if c.get("shed") or c.get("deadline_expired") or c.get("cancelled"):
        lines.append(f"front-end: {c.get('shed', 0)} shed, "
                     f"{c.get('deadline_expired', 0)} deadline-expired, "
                     f"{c.get('cancelled', 0)} cancelled")
    # resilience outcomes only when any occurred (docs/resilience.md):
    # clean-run tables are unchanged
    res_keys = ("faults_injected", "failed", "guard_trips", "breaker_trips",
                "watchdog_restarts", "disconnects")
    if any(c.get(k) for k in res_keys):
        lines.append(
            f"resilience: {c.get('faults_injected', 0)} faults injected, "
            f"{c.get('guard_trips', 0)} guard trips "
            f"({c.get('failed', 0)} failed), "
            f"{c.get('breaker_trips', 0)} breaker trips "
            f"({c.get('breaker_recoveries', 0)} recoveries), "
            f"{c.get('watchdog_restarts', 0)} watchdog restarts, "
            f"{c.get('disconnects', 0)} disconnects")
    # speculative-decoding line only when speculation ran
    # (docs/speculative.md): plain-run tables are unchanged
    sp = s.get("spec")
    if sp:
        lines.append(
            f"spec: {sp['drafted']} drafted, {sp['accepted']} accepted "
            f"(rate {sp['acceptance_rate']:.3f}), {sp['rejected']} rejected, "
            f"{sp['emitted']} emitted over {sp['ticks']} verify ticks")
    lines += [
        "",
        "| span | count | mean s | p50 s | p90 s | p99 s | max s |",
        "|---|---|---|---|---|---|---|",
    ]
    for key, label in _SPAN_ROWS:
        p = s.get(key) or {}
        if not p.get("count"):
            continue
        lines.append(f"| {label} | {p['count']} | {_fmt(p['mean'])} | "
                     f"{_fmt(p['p50'])} | {_fmt(p['p90'])} | "
                     f"{_fmt(p['p99'])} | {_fmt(p['max'])} |")
    qh = s.get("quant_health")
    if qh:
        lines += ["", "| module | samples | absmax max | clip frac max | "
                      "difficulty mean |", "|---|---|---|---|---|"]
        for m, a in qh.items():
            cf = ("—" if a["clip_fraction_max"] is None
                  else f"{a['clip_fraction_max']:.4f}")
            lines.append(f"| {m} | {a['samples']} | {a['absmax_max']:.4g} | "
                         f"{cf} | {a['difficulty_mean']:.4g} |")
    return "\n".join(lines)
