"""Per-request span tracing for the serving engines (JSONL event log).

Every event is one flat JSON object with two required keys — ``ev`` (the
event kind) and ``ts`` (seconds, from the injected clock) — plus
kind-specific fields.  The engines emit (docs/observability.md has the
full schema table):

    submit       uid, prompt_len
    admit        uid, slot, queue_wait_s, resumed
    prefill      n_requests, n_tokens, dur_s [, rows, padded_len, chunked]
    first_token  uid, ttft_s
    token        uid [, resumed] — a streamed token emitted OUTSIDE the
                 tick path (the token sampled from a RESUME prefill);
                 joins the per-token timestamp chain like a tick entry
    tick         tick, n_active, uids, dur_s [, alloc_dur_s, n_stalled]
    preempt      uid, n_generated
    retire       uid, prompt_len, decode_tokens, e2e_s [, cancelled]
    deadline     uid, deadline_s, n_streamed — a front-end per-request
                 deadline expired; the request was cancelled mid-stream
    shed         queue_depth, occupancy, score — admission control
                 rejected a request before it reached the engine
    quant_health tick, uid, context_len, modules
    fault        site [, uid, op, tick] — a FaultPlan spec fired at an
                 instrumented site (repro.resilience.faults)
    guard        uid, slot, tick, reason [, module, layer, difficulty] —
                 the numerical guard retired one slot as ``failed``,
                 citing the worst Eq.-2-difficulty layer when the
                 quant-health tap is on
    breaker      op, action ("trip"/"recover") [, error] — the kernel
                 circuit breaker moved an op to/from the XLA fallback
    watchdog     action ("engine_error"/"restart"/"give_up") [, reason,
                 error, n_resumed, restarts] — front-end engine-thread
                 supervision (docs/resilience.md)
    disconnect   uid, n_streamed — a client connection dropped
                 mid-stream; the request was cancelled in the engine
    prefix_hit   uid, slot, matched_tokens, shared_pages, suffix_tokens
                 — a paged-engine admission matched cached prefix pages
                 and re-prefilled only the suffix (docs/serving.md)
    spec         tick, drafted, accepted, rejected, emitted, n_rows —
                 one speculative tick's draft/verify accounting; the
                 accepted tokens themselves land as the tick's uid list
                 plus extra ``token`` events (docs/speculative.md)

The tracer buffers events in memory (``events``) and, when constructed
with a path, streams each event as one JSON line — ``repro.obs
summarize`` rebuilds the exact in-process summary from that file
(tests/test_obs.py pins the round trip).  ``emit`` is thread-safe: the
async front-end emits deadline/shed events from its event-loop thread
while the engine thread emits everything else.
"""

from __future__ import annotations

import json
import threading
import time

__all__ = ["Tracer", "load_trace"]

EVENT_KINDS = ("submit", "admit", "prefill", "first_token", "token", "tick",
               "preempt", "retire", "deadline", "shed", "quant_health",
               "fault", "guard", "breaker", "watchdog", "disconnect",
               "prefix_hit", "spec")


class Tracer:
    """Append-only event sink with an injectable clock."""

    def __init__(self, path: str | None = None, clock=time.perf_counter):
        self.events: list[dict] = []
        self.clock = clock
        self._fh = open(path, "w") if path else None
        self._lock = threading.Lock()

    def emit(self, ev: str, *, ts: float | None = None, **fields) -> dict:
        if ev not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind: {ev!r}")
        rec = {"ev": ev, "ts": self.clock() if ts is None else float(ts),
               **fields}
        with self._lock:
            self.events.append(rec)
            if self._fh is not None:
                self._fh.write(json.dumps(rec) + "\n")
        return rec

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Tracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def load_trace(path: str) -> list[dict]:
    """Read a trace JSONL back into the event-dict list ``summarize``
    consumes (blank lines tolerated)."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events
