"""Optimizer substrate: AdamW, LR schedules, int8 gradient compression."""
from repro.optim.adamw import adamw, AdamW, AdamWState, global_norm, clip_by_global_norm
from repro.optim.schedule import warmup_cosine, warmup_linear, constant
from repro.optim.grad_compress import (
    compress_decompress,
    compressed_psum,
    apply_error_feedback,
)
