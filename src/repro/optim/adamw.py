"""AdamW with decoupled weight decay, global-norm clipping, bf16-friendly
state layout, and optional int8-compressed cross-pod gradient reduction.

Functional API (no optax dependency):

    opt = adamw(lr_schedule, ...)
    state = opt.init(params)
    params, state, metrics = opt.step(params, state, grads, step)

Optimizer moments are stored in the PARAM sharding (ZeRO-3 by
construction under pjit — state specs mirror param specs).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "AdamWState", "global_norm", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    mu: Any          # first moment  (param dtype-promoted f32)
    nu: Any          # second moment (f32)
    err: Any | None  # error-feedback buffer for compressed reduction


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32
    error_feedback: bool = False  # pairs with int8 grad compression

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, self.moment_dtype), p)
        err = zeros(params) if self.error_feedback else None
        return AdamWState(mu=zeros(params), nu=zeros(params), err=err)

    def step(self, params, state: AdamWState, grads, step: jax.Array):
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        b1, b2 = self.b1, self.b2
        t = step.astype(jnp.float32) + 1.0
        lr = self.lr(step)

        mu = jax.tree.map(lambda m, g: b1 * m.astype(jnp.float32) + (1 - b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v.astype(jnp.float32) + (1 - b2) * g * g,
                          state.nu, grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** t), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** t), nu)

        def upd(p, m, v):
            u = m / (jnp.sqrt(v) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        params = jax.tree.map(upd, params, mu_hat, nu_hat)
        cast = lambda tr: jax.tree.map(lambda x: x.astype(self.moment_dtype), tr)
        return params, AdamWState(cast(mu), cast(nu), state.err), {
            "grad_norm": gnorm, "lr": lr}


def adamw(lr, **kw) -> AdamW:
    if not callable(lr):
        lr_value = float(lr)
        lr = lambda step: jnp.asarray(lr_value, jnp.float32)
    return AdamW(lr=lr, **kw)
