"""int8 gradient compression for cross-pod (DCN) all-reduce.

The expensive hop at multi-pod scale is the per-step gradient reduction
across pods over DCN (DESIGN.md §4).  This module provides:

  * ``compress_decompress``  — int8 symmetric quantization with
    STOCHASTIC rounding (unbiased) + error feedback (the residual is
    carried in optimizer state so systematic error cannot accumulate);
  * ``compressed_psum``      — a shard_map'd psum over a chosen mesh
    axis that sends int8 codes + one f32 scale per tensor instead of
    f32/bf16 gradients — a 4×/2× DCN traffic cut;
  * the pieces compose into train_step via ``apply_error_feedback``.

Unbiasedness: E[sr(g/Δ)·Δ] = g; variance Δ²/4 per element, controlled by
per-tensor Δ = max|g|/127.  Error feedback stores (g − decompress) and
adds it into the next step's gradient — SGD-style convergence guarantees
carry over (Karimireddy et al., 2019).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress_decompress", "compressed_psum", "apply_error_feedback"]


def _sr_quant(g: jax.Array, key: jax.Array):
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.where(absmax == 0, 1.0, absmax) / 127.0
    noise = jax.random.uniform(key, g.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.floor(g / scale + 0.5 + noise), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def compress_decompress(grads, key: jax.Array):
    """Round-trip int8(sr) compression of a grad pytree (per-tensor Δ)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        q, s = _sr_quant(g.astype(jnp.float32), k)
        out.append(q.astype(jnp.float32) * s)
    return jax.tree.unflatten(treedef, out)


def compressed_psum(grads, key: jax.Array, *, axis: str = "pod"):
    """psum a grad pytree over ``axis`` sending int8 codes on the wire.

    Each shard quantizes its local partial gradient with stochastic
    rounding, psums the int32-widened codes (the only cross-``axis``
    traffic: 1 byte/elem + scales), then rescales by the max scale.
    Call INSIDE shard_map where ``axis`` is a manual mesh axis and the
    grads are per-shard partials.
    """
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        g = g.astype(jnp.float32)
        # shared scale: max over the axis so codes are on a common grid
        absmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
        scale = jnp.where(absmax == 0, 1.0, absmax) / 127.0
        noise = jax.random.uniform(k, g.shape, jnp.float32) - 0.5
        q = jnp.clip(jnp.floor(g / scale + 0.5 + noise), -127, 127
                     ).astype(jnp.int32)
        total = jax.lax.psum(q, axis)           # wire: int codes
        out.append(total.astype(jnp.float32) * scale)
    return jax.tree.unflatten(treedef, out)


def apply_error_feedback(grads, err, key: jax.Array):
    """g' = compress(g + err); err' = (g + err) − g'. Returns (g', err')."""
    if err is None:
        return compress_decompress(grads, key), None
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err)
    rounded = compress_decompress(corrected, key)
    new_err = jax.tree.map(lambda c, r: c - r, corrected, rounded)
    return rounded, new_err
