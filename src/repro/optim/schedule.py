"""Learning-rate schedules (warmup + cosine / linear decay)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "warmup_linear", "constant"]


def constant(peak: float):
    return lambda step: jnp.asarray(peak, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak * (step + 1) / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos).astype(jnp.float32)
    return fn


def warmup_linear(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak * (step + 1) / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        lin = peak * (1 - (1 - floor) * prog)
        return jnp.where(step < warmup_steps, warm, lin).astype(jnp.float32)
    return fn
