"""Resilience layer: deterministic fault injection + recovery plumbing.

``repro.resilience.faults`` is the injection plane (docs/resilience.md);
the consumers live where the faults land — numerical guards and fault
sites in ``repro.serving.engine``, the kernel circuit breaker in
``repro.kernels.ops``, and the watchdog/recovery path in
``repro.serving.frontend``.
"""

from repro.resilience.faults import (
    SITES,
    FaultInjected,
    FaultPlan,
    FaultSpec,
)

__all__ = ["SITES", "FaultInjected", "FaultPlan", "FaultSpec"]
