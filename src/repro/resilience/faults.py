"""Deterministic, seeded fault-injection plane for the serving stack.

A :class:`FaultPlan` is a SCHEDULE: each :class:`FaultSpec` names a
fault site, an arrival window (``at``/``count``) and optional uid/op
filters.  Code under test calls ``plan.fire(site, **ctx)`` at the
instrumented sites; the call returns the matching spec (the fault fires)
or ``None``.  Arrivals are counted PER SPEC over the calls that match
its filters, so two specs at the same site trigger independently and a
plan replays identically run after run — chaos tests rely on that to
compare a faulted run against its fault-free twin.

Sites (docs/resilience.md has the full table):

    dispatch_raise    a jitted kernel dispatch raises (engine: before
                      the call, so no donated buffer is half-consumed)
    nan_logits        one slot's decode logits row turns NaN (engine:
                      post-dispatch poisoning — other rows untouched)
    page_alloc_fail   a page allocation reports an empty pool (engine:
                      admission backpressure / mid-decode stall paths)
    slow_tick         the engine tick blocks for ``delay_s`` (watchdog
                      stall detection)
    client_disconnect the front-end's writer raises mid-stream (the
                      disconnect-cancels-request path)

Zero-overhead-when-off contract: holders keep ``faults=None`` and guard
every site with ``self._faults is not None`` — the same shape as the obs
hooks (tests/test_resilience.py pins token identity and dispatch counts
against a no-plan run).
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

__all__ = ["SITES", "FaultSpec", "FaultPlan", "FaultInjected"]

SITES = ("dispatch_raise", "nan_logits", "page_alloc_fail", "slow_tick",
         "client_disconnect")


class FaultInjected(RuntimeError):
    """Raised by a ``dispatch_raise``/``client_disconnect`` site when its
    spec fires — distinguishable from organic failures in logs, handled
    identically by the recovery machinery (that is the point)."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected fault at {site}" +
                         (f": {detail}" if detail else ""))
        self.site = site


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    ``at``/``count``: fire on matching arrivals ``at .. at+count-1``
    (0-based, counted per spec over calls passing the filters).
    ``uid``/``op``: only arrivals carrying that uid / op name match;
    ``None`` matches everything.  ``delay_s``: sleep length for
    ``slow_tick``."""

    site: str
    at: int = 0
    count: int = 1
    uid: int | None = None
    op: str | None = None
    delay_s: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site: {self.site!r} "
                             f"(sites: {SITES})")

    def matches(self, uid, op) -> bool:
        return ((self.uid is None or self.uid == uid)
                and (self.op is None or self.op == op))


class FaultPlan:
    """A replayable schedule of faults over the named sites.

    ``fire(site, uid=..., op=...)`` advances every spec of that site
    whose filters match the call and returns the first spec inside its
    arrival window (else ``None``).  ``fired`` records every trigger
    (site + context + arrival index) so tests can assert the schedule
    actually executed.
    """

    def __init__(self, specs: list[FaultSpec] | tuple[FaultSpec, ...] = ()):
        self.specs = list(specs)
        self._arrivals: list[int] = [0] * len(self.specs)
        self.fired: list[dict] = []

    def __repr__(self) -> str:
        return f"FaultPlan({self.specs!r})"

    def fire(self, site: str, *, uid: int | None = None,
             op: str | None = None, **ctx) -> FaultSpec | None:
        hit = None
        for i, spec in enumerate(self.specs):
            if spec.site != site or not spec.matches(uid, op):
                continue
            n = self._arrivals[i]
            self._arrivals[i] = n + 1
            if hit is None and spec.at <= n < spec.at + spec.count:
                hit = spec
                self.fired.append({"site": site, "uid": uid, "op": op,
                                   "arrival": n, **ctx})
        return hit

    # -- construction helpers ----------------------------------------------

    @classmethod
    def random(cls, seed: int, *, n_faults: int = 3, sites=SITES,
               uids=(), max_at: int = 24, max_count: int = 2,
               delay_s: float = 0.0) -> "FaultPlan":
        """Seeded random schedule (the chaos suite's generator): every
        draw comes from one ``default_rng(seed)`` stream, so the same
        seed always yields the same plan."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(int(n_faults)):
            site = sites[int(rng.integers(len(sites)))]
            uid = (int(rng.choice(np.asarray(uids)))
                   if len(uids) and rng.random() < 0.5 else None)
            specs.append(FaultSpec(
                site=site, at=int(rng.integers(max_at)),
                count=int(rng.integers(1, max_count + 1)), uid=uid,
                delay_s=delay_s if site == "slow_tick" else 0.0))
        return cls(specs)

    # -- serde (serve.py --fault-plan) --------------------------------------

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(s) for s in self.specs])

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls([FaultSpec(**d) for d in json.loads(text)])
