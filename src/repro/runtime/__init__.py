"""Fault-tolerance runtime: preemption, heartbeats, stragglers, elastic."""
from repro.runtime.fault_tolerance import (
    PreemptionHandler,
    Heartbeat,
    StragglerPolicy,
    elastic_mesh,
)
