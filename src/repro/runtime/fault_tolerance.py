"""Fault-tolerance runtime: preemption handling, heartbeats, straggler
policy, and elastic-restart glue.

On a real cluster each host runs this manager next to the training loop:

  * ``PreemptionHandler`` — SIGTERM/SIGINT → set a flag the step loop
    checks; the loop performs an emergency checkpoint and exits cleanly
    (TPU preemption notices arrive ~30 s ahead).
  * ``Heartbeat`` — background thread touching a per-host file (or KV
    entry); the coordinator declares a host dead after ``timeout`` and
    triggers an elastic restart with the surviving host set.
  * ``StragglerPolicy`` — per-step wall-time EWMA; a step exceeding
    ``factor``× the EWMA flags the host as a straggler.  The documented
    mitigation at the data level: the coordinator re-dispatches that
    host's batch shard and excludes the straggler from the next mesh
    (elastic re-shard via checkpoint restore under the new mesh —
    repro.checkpoint restores are mesh-agnostic by design).
  * ``elastic_mesh`` — rebuild the largest (data, model) mesh that fits
    the surviving device count, preferring to shrink the data axis
    (model-parallel groups must stay intact).
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time

import jax

__all__ = ["PreemptionHandler", "Heartbeat", "StragglerPolicy", "elastic_mesh"]


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = threading.Event()
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._on_signal)
            except ValueError:  # non-main thread (tests)
                pass

    def _on_signal(self, signum, frame):
        self._flag.set()

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()

    def trigger(self):  # for tests / manual drains
        self._flag.set()


class Heartbeat:
    """Touches ``path`` every ``interval`` s; ``alive(path, timeout)``
    is the coordinator-side check."""

    def __init__(self, path: str, interval: float = 5.0):
        self.path = path
        self.interval = interval
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def _run(self):
        while not self._stop.is_set():
            with open(self.path, "w") as f:
                f.write(str(time.time()))
            self._stop.wait(self.interval)

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)

    @staticmethod
    def alive(path: str, timeout: float = 30.0) -> bool:
        try:
            with open(path) as f:
                return time.time() - float(f.read()) < timeout
        except (OSError, ValueError):
            return False


@dataclasses.dataclass
class StragglerPolicy:
    """EWMA step-time tracker; flags steps slower than factor× the mean."""

    factor: float = 3.0
    alpha: float = 0.1
    _ewma: float = 0.0
    _n: int = 0
    flagged: int = 0

    def observe(self, step_seconds: float) -> bool:
        is_straggler = (self._n > 3 and
                        step_seconds > self.factor * self._ewma)
        if is_straggler:
            self.flagged += 1
        self._ewma = (step_seconds if self._n == 0
                      else (1 - self.alpha) * self._ewma + self.alpha * step_seconds)
        self._n += 1
        return is_straggler


def elastic_mesh(n_devices: int, *, model_parallel: int = 16,
                 axis_names=("data", "model")):
    """Largest (data, model) mesh from n_devices, keeping the model axis
    intact (TP groups cannot shrink without resharding weights within a
    group — data-parallel replicas are the elastic dimension)."""
    if n_devices < model_parallel:
        model_parallel = 1 << (n_devices.bit_length() - 1)
    data = n_devices // model_parallel
    devices = jax.devices()[: data * model_parallel]
    import numpy as np

    arr = np.asarray(devices).reshape(data, model_parallel)
    return jax.sharding.Mesh(arr, axis_names)
