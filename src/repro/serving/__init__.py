"""Serving stack: fold+quantize pipeline, KV caches, batched/paged
engines (repro.serving.engine), async HTTP front-end
(repro.serving.frontend)."""
