"""Serving stack: fold+quantize pipeline, KV caches, batched engine."""
