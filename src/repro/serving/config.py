"""Unified engine configuration (docs/api.md).

The three serving engines grew ~13 constructor kwargs duplicated across
``serve.py``, the front-end, and every benchmark; the prefix-cache knobs
would have made it worse.  :class:`EngineConfig` consolidates them into
ONE frozen value object:

  * engines take ``Engine(model, params, cfg, config=EngineConfig(...))``
    — the legacy per-kwarg form still works through a deprecation shim
    that warns ONCE per process (``resolve_engine_config``);
  * the config JSON round-trips like ``FaultPlan`` (``to_json`` /
    ``from_json``) so launch scripts and benchmark manifests can pin an
    engine setup as data.  ``obs`` is the one runtime-only field
    (tracers hold open files and injected clocks): it is dropped from
    the JSON form and comes back ``None``.
"""

from __future__ import annotations

import dataclasses
import json
import warnings

from repro.configs.base import ModelConfig
from repro.core.qlinear import QuantPolicy
from repro.obs import Observability
from repro.resilience.faults import FaultPlan

__all__ = ["EngineConfig", "resolve_engine_config"]

# eviction policies the paged engine's prefix cache understands; a tuple
# so the validation error can enumerate them (docs/serving.md)
PREFIX_EVICT_POLICIES = ("lru",)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every serving-engine knob in one frozen, JSON-able value.

    Fields the non-paged engines don't use (``page_size`` ...) are
    simply ignored by them, so ONE config can build any of the three
    engines (the schema-equality tests rely on exactly that).
    """

    max_slots: int = 4
    max_len: int = 256
    policy: QuantPolicy | None = None
    eos_id: int = -1
    kv_bits: int | None = None
    page_size: int = 64
    n_pages: int | None = None
    prefill_bucket: int = 16
    prefill_chunk: int | None = None
    obs: Observability | None = None
    faults: FaultPlan | None = None
    nan_guard: bool = False
    # prefix caching over the paged pool (docs/serving.md §Prefix
    # caching): OFF by default — the cache-off engine is byte-identical
    # to the pre-cache allocator
    prefix_cache: bool = False
    prefix_evict: str = "lru"
    # speculative decoding (docs/speculative.md): spec_k > 0 turns it on
    # for the paged engine; spec_draft_config names the draft model
    # (None = self-draft, the target drafts for itself).  Ignored by the
    # non-paged engines, like the page knobs above.
    spec_k: int = 0
    spec_draft_config: ModelConfig | None = None

    def __post_init__(self):
        for name in ("max_slots", "max_len", "page_size", "prefill_bucket"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        for name in ("n_pages", "prefill_chunk"):
            val = getattr(self, name)
            if val is not None and val < 1:
                raise ValueError(f"{name} must be None or >= 1, got {val}")
        if self.kv_bits not in (None, 8):
            raise ValueError(f"kv_bits must be None or 8, got {self.kv_bits}")
        if self.prefix_evict not in PREFIX_EVICT_POLICIES:
            raise ValueError(
                f"prefix_evict must be one of {PREFIX_EVICT_POLICIES}, "
                f"got {self.prefix_evict!r}")
        if self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {self.spec_k}")
        if self.spec_draft_config is not None and self.spec_k < 1:
            raise ValueError(
                "spec_draft_config requires spec_k >= 1 (a draft model "
                "with nothing to draft is a misconfiguration)")

    # -- JSON round trip (the FaultPlan pattern) ----------------------------

    def to_json(self) -> str:
        """Serialize every field except the runtime-only ``obs``."""
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "obs"}
        if self.policy is not None:
            d["policy"] = dataclasses.asdict(self.policy)
        if self.faults is not None:
            d["faults"] = json.loads(self.faults.to_json())
        if self.spec_draft_config is not None:
            d["spec_draft_config"] = dataclasses.asdict(self.spec_draft_config)
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "EngineConfig":
        d = json.loads(text)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown EngineConfig fields: {sorted(unknown)}")
        if d.get("policy") is not None:
            d["policy"] = QuantPolicy(**d["policy"])
        if d.get("faults") is not None:
            d["faults"] = FaultPlan.from_json(json.dumps(d["faults"]))
        if d.get("spec_draft_config") is not None:
            d["spec_draft_config"] = ModelConfig(**d["spec_draft_config"])
        return cls(**d)


# the legacy-kwarg deprecation warns ONCE per process, not once per
# engine: property tests construct hundreds of engines
_legacy_warned = False


def resolve_engine_config(config: EngineConfig | None,
                          legacy: dict) -> EngineConfig:
    """Resolve an engine constructor's ``config=`` / legacy-kwarg pair.

    ``config=EngineConfig(...)`` is the supported path.  Legacy kwargs
    (``max_slots=4, ...``) build an equivalent config through a
    deprecation shim that warns once per process; mixing both forms or
    passing a kwarg ``EngineConfig`` doesn't know is a ``TypeError``
    (the old constructors rejected typos the same way)."""
    global _legacy_warned
    if legacy:
        known = {f.name for f in dataclasses.fields(EngineConfig)}
        unknown = set(legacy) - known
        if unknown:
            raise TypeError(
                f"unknown engine kwargs: {sorted(unknown)} "
                f"(EngineConfig fields: {sorted(known)})")
        if config is not None:
            raise TypeError(
                "pass either config=EngineConfig(...) or legacy kwargs, "
                f"not both (got legacy {sorted(legacy)})")
        if not _legacy_warned:
            warnings.warn(
                "per-kwarg engine construction is deprecated; pass "
                "config=EngineConfig(...) (see docs/api.md)",
                DeprecationWarning, stacklevel=3)
            _legacy_warned = True
        return EngineConfig(**legacy)
    return config if config is not None else EngineConfig()
