"""Batched serving engine: continuous batching over a fixed slot grid.

The engine owns ONE slot-major cache pytree (``max_slots`` sequences ×
``max_len`` positions — ``common.batch_slot_cache`` over the family's
``make_cache``) and runs two jitted programs:

  * ``prefill``     — admit one request into a free slot (prompt → a
    batch-1 cache, copied into the slot with ``common.write_slot``)
  * ``decode_step`` — ONE ``(max_slots, 1)`` program per tick for EVERY
    active slot (the batched shape the roofline decode cells model),
    with per-slot positions threaded through the cache ``length``
    vector and vectorized sampling over the slot axis.

Requests are queued, admitted as slots free up, sampled greedily or by
temperature, and retired on EOS/max_tokens — vLLM-style continuous
batching reduced to its JAX-native core.  Weights may be the bf16 train
params or the fold+quantized serving params (the paper's pipeline).

``PerSlotServingEngine`` preserves the original one-dispatch-per-slot
loop as the equivalence/throughput baseline: batched greedy output is
token-identical to it (tests/test_serving_batched.py), while issuing
``1`` decode dispatch per tick instead of ``n_active``.

jit caches are shared process-wide per (model, cfg, policy), so
constructing many engines (property tests, benchmarks) does not retrace.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.qlinear import QuantPolicy
from repro.models import common as cm

__all__ = ["Request", "ServingEngine", "PerSlotServingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0             # 0 → greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@functools.lru_cache(maxsize=None)
def _jitted(model, cfg: ModelConfig, policy: QuantPolicy | None):
    """Process-wide (model, cfg, policy) → jitted (prefill, decode_step)."""
    prefill = jax.jit(lambda p, t, c: model.prefill(p, cfg, t, c, policy=policy))
    decode = jax.jit(lambda p, t, c: model.decode_step(p, cfg, t, c,
                                                       policy=policy))
    return prefill, decode


def _sample_key(step: int, uid: int) -> jax.Array:
    """Per-(tick, request) PRNG key.  Folding in the uid is load-bearing:
    a step-only fold hands every slot in a tick the SAME key, i.e.
    identical draws across concurrent requests at temperature > 0."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(7), step), uid)


def _sample_one(logits: jax.Array, temperature: float, step: int,
                uid: int) -> jax.Array:
    """Sample one token from (1, V) logits (the admit/prefill path)."""
    if temperature <= 0:
        return jnp.argmax(logits, -1)
    return jax.random.categorical(_sample_key(step, uid),
                                  logits / temperature, axis=-1)


# slot writes run jitted with the batched cache donated: one fused program
# per (shape, slot) that updates the slot in place instead of eagerly
# re-materializing every cache leaf on each admission
_write_slot = jax.jit(cm.write_slot, static_argnums=2, donate_argnums=0)


class _EngineBase:
    """Shared scheduling state + request bookkeeping."""

    def __init__(self, model, params, cfg: ModelConfig, *, max_slots: int = 4,
                 max_len: int = 256, policy: QuantPolicy | None = None,
                 eos_id: int = -1, kv_bits: int | None = None):
        self.model, self.params, self.cfg = model, params, cfg
        self.policy = policy
        self.max_slots, self.max_len = max_slots, max_len
        self.eos_id = eos_id
        self.kv_bits = kv_bits
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_slots
        self.retired: list[Request] = []
        self._prefill, self._decode = _jitted(model, cfg, policy)
        self._step = 0
        self.decode_dispatches = 0       # jitted decode calls issued
        self.ticks = 0                   # step() calls that decoded
        self._init_caches()

    def _init_caches(self):
        """Build this engine's cache storage (layout differs per engine)."""
        raise NotImplementedError

    @property
    def kernel_backend(self) -> str:
        """Resolved matmul backend for this engine's policy ("bf16" when
        no quantization policy is attached).  repro.kernels.ops is the
        single dispatch authority (docs/kernels.md): on TPU hosts the
        quantized decode tick runs the one-pass fused Pallas qlinear."""
        if self.policy is None:
            return "bf16"
        from repro.kernels import ops

        return ops.resolve_backend(self.policy.use_kernels)

    def submit(self, req: Request):
        self.queue.append(req)

    def _finished(self, req: Request, tok: int) -> bool:
        return tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens

    def _install_slot_cache(self, slot: int, cache):
        """Store an admitted request's prefilled batch-1 cache for
        ``slot`` (layout differs per engine)."""
        raise NotImplementedError

    def _admit(self):
        for i in range(self.max_slots):
            while self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                slot_cache = self.model.make_cache(self.cfg, 1, self.max_len,
                                                   bits=self.kv_bits)
                toks = jnp.asarray(req.prompt[None, :], jnp.int32)
                logits, slot_cache = self._prefill(self.params, toks,
                                                   slot_cache)
                nxt = int(_sample_one(logits[:, -1], req.temperature,
                                      self._step, req.uid)[0])
                req.out_tokens.append(nxt)
                # the prefill-sampled token can already finish the request
                # (EOS or max_new_tokens=1): retire without occupying the
                # slot, and keep admitting into it
                if self._finished(req, nxt):
                    req.done = True
                    self.retired.append(req)
                else:
                    self.slots[i] = req
                    self._install_slot_cache(i, slot_cache)

    def pop_retired(self) -> list[Request]:
        """Drain and return retired requests (callers driving step()
        directly should call this periodically — the engine does not
        retain retired requests once handed out)."""
        out, self.retired = self.retired, []
        return out

    def run(self, max_ticks: int = 1000) -> list[Request]:
        """Tick until queue and slots drain (or the tick budget runs out);
        returns every retired request not yet handed out — including ones
        already occupying a slot beforehand or submitted mid-run."""
        while (self.queue or any(self.slots)) and max_ticks > 0:
            self.step()
            max_ticks -= 1
        return self.pop_retired()


class ServingEngine(_EngineBase):
    """Slot-batched continuous batching: one decode dispatch per tick."""

    def _init_caches(self):
        # ONE slot-major cache: data leaves (layer, slot, ...), lengths
        # vectorized to (max_slots,) so each slot decodes at its own depth
        self.cache = cm.batch_slot_cache(
            self.model.make_cache(self.cfg, self.max_slots, self.max_len,
                                  bits=self.kv_bits))

    def _install_slot_cache(self, slot: int, cache):
        # full-extent copy: no stale KV/scales from the slot's previous
        # occupant survive admission
        self.cache = _write_slot(self.cache, cache, slot)

    def _sample_batch(self, logits: jax.Array, temps: np.ndarray,
                      uids: np.ndarray) -> jax.Array:
        """Vectorized over slots: greedy rows take argmax; temperature
        rows draw categorically with a per-(tick, uid) key."""
        greedy = jnp.argmax(logits, -1)
        if not (temps > 0).any():
            return greedy
        keys = jax.vmap(lambda u: _sample_key(self._step, u))(
            jnp.asarray(uids, jnp.int32))
        scaled = logits / jnp.maximum(jnp.asarray(temps), 1e-6)[:, None]
        drawn = jax.vmap(jax.random.categorical)(keys, scaled)
        return jnp.where(jnp.asarray(temps) > 0, drawn, greedy)

    # -- one engine tick ----------------------------------------------------

    def step(self) -> int:
        """Admit + decode one token for every active slot with a SINGLE
        (max_slots, 1) jitted dispatch. Returns the number of active
        sequences."""
        self._admit()
        self._step += 1
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        last = np.zeros((self.max_slots, 1), np.int32)
        temps = np.zeros((self.max_slots,), np.float32)
        uids = np.zeros((self.max_slots,), np.int32)
        for i in active:
            req = self.slots[i]
            last[i, 0] = req.out_tokens[-1]
            temps[i] = req.temperature
            uids[i] = req.uid
        # inactive slots ride along masked: their rows decode garbage that
        # is never sampled into a request, and admission overwrites their
        # slot cache wholesale
        logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                          self.cache)
        self.decode_dispatches += 1
        self.ticks += 1
        toks = np.asarray(self._sample_batch(logits[:, -1], temps, uids))
        for i in active:
            req = self.slots[i]
            nxt = int(toks[i])
            req.out_tokens.append(nxt)
            if self._finished(req, nxt):
                req.done = True
                self.retired.append(req)
                self.slots[i] = None
        return len(active)


class PerSlotServingEngine(_EngineBase):
    """The original per-slot loop: one (1, 1) decode dispatch per active
    slot per tick.  Kept as the equivalence oracle and the throughput
    baseline (benchmarks/serving_throughput.py); the batched engine must
    match its greedy tokens exactly."""

    def _init_caches(self):
        self.caches = [self.model.make_cache(self.cfg, 1, self.max_len,
                                             bits=self.kv_bits)
                       for _ in range(self.max_slots)]

    def _install_slot_cache(self, slot: int, cache):
        self.caches[slot] = cache

    def step(self) -> int:
        self._admit()
        self._step += 1
        active = 0
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            active += 1
            tok = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            logits, self.caches[i] = self._decode(self.params, tok,
                                                  self.caches[i])
            self.decode_dispatches += 1
            nxt = int(_sample_one(logits[:, -1], req.temperature, self._step,
                                  req.uid)[0])
            req.out_tokens.append(nxt)
            if self._finished(req, nxt):
                req.done = True
                self.retired.append(req)
                self.slots[i] = None
        if active:
            self.ticks += 1
        return active
