"""Batched serving engine: continuous batching over a fixed slot grid.

The engine owns a slot-structured KV cache (``max_slots`` sequences ×
``max_len`` positions) and runs two jitted programs:

  * ``prefill``    — admit one request into a free slot (prompt → cache)
  * ``decode_step`` — one token for EVERY active slot (the batched path
    whose roofline the decode_* shape cells measure)

Requests are queued, admitted as slots free up, sampled greedily or by
temperature, and retired on EOS/max_tokens — vLLM-style continuous
batching reduced to its JAX-native core.  Weights may be the bf16 train
params or the fold+quantized serving params (the paper's pipeline).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.qlinear import QuantPolicy

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0             # 0 → greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, cfg: ModelConfig, *, max_slots: int = 4,
                 max_len: int = 256, policy: QuantPolicy | None = None,
                 eos_id: int = -1, kv_bits: int | None = None):
        self.model, self.params, self.cfg = model, params, cfg
        self.policy = policy
        self.max_slots, self.max_len = max_slots, max_len
        self.eos_id = eos_id
        self.kv_bits = kv_bits
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_slots
        self.retired: list[Request] = []
        # one independent cache per slot (slot-batched decode batches them)
        self.caches = [model.make_cache(cfg, 1, max_len, bits=kv_bits)
                       for _ in range(max_slots)]
        self._prefill = jax.jit(
            lambda p, t, c: model.prefill(p, cfg, t, c, policy=policy))
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, cfg, t, c, policy=policy))
        self._step = 0

    # -- scheduling ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.max_slots):
            while self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                cache = self.model.make_cache(self.cfg, 1, self.max_len,
                                              bits=self.kv_bits)
                toks = jnp.asarray(req.prompt[None, :], jnp.int32)
                logits, cache = self._prefill(self.params, toks, cache)
                self.caches[i] = cache
                nxt = int(self._sample(logits[:, -1], req.temperature)[0])
                req.out_tokens.append(nxt)
                # the prefill-sampled token can already finish the request
                # (EOS or max_new_tokens=1): retire without occupying the
                # slot, and keep admitting into it
                if (nxt == self.eos_id or
                        len(req.out_tokens) >= req.max_new_tokens):
                    req.done = True
                    self.retired.append(req)
                else:
                    self.slots[i] = req

    def _sample(self, logits, temperature: float):
        if temperature <= 0:
            return jnp.argmax(logits, -1)
        key = jax.random.fold_in(jax.random.PRNGKey(7), self._step)
        return jax.random.categorical(key, logits / temperature, axis=-1)

    # -- one engine tick ----------------------------------------------------

    def step(self) -> int:
        """Admit + decode one token for every active slot. Returns the
        number of active sequences."""
        self._admit()
        self._step += 1
        active = 0
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            active += 1
            tok = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            logits, self.caches[i] = self._decode(self.params, tok,
                                                  self.caches[i])
            nxt = int(self._sample(logits[:, -1], req.temperature)[0])
            req.out_tokens.append(nxt)
            if (nxt == self.eos_id or
                    len(req.out_tokens) >= req.max_new_tokens):
                req.done = True
                self.retired.append(req)
                self.slots[i] = None
        return active

    def pop_retired(self) -> list[Request]:
        """Drain and return retired requests (callers driving step()
        directly should call this periodically — the engine does not
        retain retired requests once handed out)."""
        out, self.retired = self.retired, []
        return out

    def run(self, max_ticks: int = 1000) -> list[Request]:
        """Tick until queue and slots drain (or the tick budget runs out);
        returns every retired request not yet handed out — including ones
        already occupying a slot beforehand or submitted mid-run."""
        while (self.queue or any(self.slots)) and max_ticks > 0:
            self.step()
            max_ticks -= 1
        return self.pop_retired()
