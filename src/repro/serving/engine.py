"""Batched serving engine: continuous batching over a fixed slot grid.

The engine owns ONE slot-major cache pytree (``max_slots`` sequences ×
``max_len`` positions — ``common.batch_slot_cache`` over the family's
``make_cache``) and runs two jitted programs:

  * ``prefill``     — admit one request into a free slot (prompt → a
    batch-1 cache, copied into the slot with ``common.write_slot``)
  * ``decode_step`` — ONE ``(max_slots, 1)`` program per tick for EVERY
    active slot (the batched shape the roofline decode cells model),
    with per-slot positions threaded through the cache ``length``
    vector and vectorized sampling over the slot axis.

Requests are queued, admitted as slots free up, sampled greedily or by
temperature, and retired on EOS/max_tokens — vLLM-style continuous
batching reduced to its JAX-native core.  Weights may be the bf16 train
params or the fold+quantized serving params (the paper's pipeline).

``PagedServingEngine`` rebuilds the memory and admission layers on top
of the batched tick: the dense per-slot ``(max_slots, max_len)`` extents
become fixed-size PAGES from a shared pool (``common.PagedKVCache``) so
slots grow on demand and freed pages return to the pool, and admission
runs ONE jitted ``(n_admit, padded_prompt_len)`` ``prefill_paged``
dispatch that writes straight into the assigned pages — replacing the
per-request batch-1 prefill + ``write_slot`` copy.  Mixed prompt lengths
share the dispatch through length-bucketed padding.

``PerSlotServingEngine`` preserves the original one-dispatch-per-slot
loop as the equivalence/throughput baseline: batched AND paged greedy
output are token-identical to it (tests/test_serving_batched.py,
tests/test_serving_paged.py), while issuing ``1`` decode dispatch per
tick instead of ``n_active``.

jit caches are shared process-wide per (model, cfg, policy), so
constructing many engines (property tests, benchmarks) does not retrace.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.qlinear import QuantPolicy
from repro.launch.roofline import (
    serving_prefill_flops,
    serving_prefill_hbm_bytes,
    serving_tick_hbm_bytes,
)
from repro.models import common as cm
from repro.obs import MetricsRegistry
from repro.resilience.faults import FaultInjected
from repro.serving.config import EngineConfig, resolve_engine_config

__all__ = ["Request", "EngineConfig", "ServingEngine", "PagedServingEngine",
           "PerSlotServingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0             # 0 → greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    cancelled: bool = False              # set by engine.cancel()
    failed: bool = False                 # set by the numerical guard


@functools.lru_cache(maxsize=None)
def _jitted(model, cfg: ModelConfig, policy: QuantPolicy | None):
    """Process-wide (model, cfg, policy) → jitted (prefill, decode_step)."""
    prefill = jax.jit(lambda p, t, c: model.prefill(p, cfg, t, c, policy=policy))
    decode = jax.jit(lambda p, t, c: model.decode_step(p, cfg, t, c,
                                                       policy=policy))
    return prefill, decode


@functools.lru_cache(maxsize=None)
def _jitted_paged_prefill(model, cfg: ModelConfig, policy: QuantPolicy | None):
    """Process-wide jitted in-engine batched prefill; the engine cache is
    donated (the pool is the engine's largest buffer — no tick-time copy)."""
    return jax.jit(
        lambda p, t, ln, c, s: model.prefill_paged(p, cfg, t, ln, c, s,
                                                   policy=policy),
        donate_argnums=3)


@functools.lru_cache(maxsize=None)
def _jitted_chunked_prefill(model, cfg: ModelConfig,
                            policy: QuantPolicy | None):
    """Chunked-prefill CONTINUATION dispatch: each row writes its next
    prompt chunk into its pages at per-row ``start`` offsets and attends
    over its already-written pool prefix (docs/serving.md).  Only model
    families with ``supports_chunked_prefill`` expose the ``start``
    parameter; the cache is donated exactly like the whole-prompt path."""
    return jax.jit(
        lambda p, t, ln, st, c, s: model.prefill_paged(
            p, cfg, t, ln, c, s, policy=policy, start=st),
        donate_argnums=4)


@functools.lru_cache(maxsize=None)
def _jitted_verify(model, cfg: ModelConfig, policy: QuantPolicy | None):
    """Speculative VERIFY dispatch (docs/speculative.md): one batched
    ragged call scores k+1 candidate positions per slot at per-row
    ``start`` offsets — the chunked-prefill continuation shape, but
    logits come back for ALL positions so greedy acceptance can compare
    the target's argmax against every draft.  The cache is donated like
    the prefill paths (closures re-materialize host state on retry)."""
    return jax.jit(
        lambda p, t, ln, st, c, s: model.verify_paged(
            p, cfg, t, ln, c, s, st, policy=policy),
        donate_argnums=4)


# copy-on-write page clone: one donated jit per pool-leaf shape copies a
# single physical page's data inside the pool buffer (page axis 1)
_page_copy = jax.jit(lambda buf, src, dst: buf.at[:, dst].set(buf[:, src]),
                     donate_argnums=0)


def _sample_key(step: int, uid: int) -> jax.Array:
    """Per-(tick, request) PRNG key.  Folding in the uid is load-bearing:
    a step-only fold hands every slot in a tick the SAME key, i.e.
    identical draws across concurrent requests at temperature > 0."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(7), step), uid)


def _sample_one(logits: jax.Array, temperature: float, step: int,
                uid: int) -> jax.Array:
    """Sample one token from (1, V) logits (the admit/prefill path)."""
    if temperature <= 0:
        return jnp.argmax(logits, -1)
    return jax.random.categorical(_sample_key(step, uid),
                                  logits / temperature, axis=-1)


# slot writes run jitted with the batched cache donated: one fused program
# per (shape, slot) that updates the slot in place instead of eagerly
# re-materializing every cache leaf on each admission
_write_slot = jax.jit(cm.write_slot, static_argnums=2, donate_argnums=0)


class _EngineBase:
    """Shared scheduling state + request bookkeeping.

    Counters live in a :class:`repro.obs.MetricsRegistry` — the engine
    always carries one (``run_stats``/``stats()`` read from it), and an
    ``obs=Observability(...)`` argument swaps in a shared registry plus
    the OPT-IN layers: span tracing (submit/admit/prefill/first-token/
    tick/preempt/retire events + TTFT/queue-wait/tick histograms),
    per-backend dispatch + modeled-HBM-byte attribution, and
    quant-health sampling.  With ``obs=None`` the engine takes no
    timestamps, emits no events, and issues exactly the same jitted
    dispatches (tests/test_obs.py pins zero overhead and token
    identity)."""

    def __init__(self, model, params, cfg: ModelConfig, *,
                 config: EngineConfig | None = None, **legacy):
        # ONE EngineConfig carries every knob (docs/api.md); the legacy
        # per-kwarg form builds an equivalent config through a shim that
        # warns once per process (serving/config.py)
        config = resolve_engine_config(config, legacy)
        self.config = config
        policy, obs = config.policy, config.obs
        self.model, self.params, self.cfg = model, params, cfg
        self.policy = policy
        self.max_slots, self.max_len = config.max_slots, config.max_len
        self.eos_id = config.eos_id
        self.kv_bits = config.kv_bits
        self.obs = obs
        faults, nan_guard = config.faults, config.nan_guard
        # resilience layer (docs/resilience.md): both OPT-IN with the
        # obs-hook zero-overhead contract — faults=None / nan_guard=False
        # cost one attribute check per site and change nothing else
        self._faults = faults
        self._nan_guard = nan_guard
        self._metrics = obs.registry if obs is not None else MetricsRegistry()
        self._tracer = obs.tracer if obs is not None else None
        self._qhealth = obs.quant_health if obs is not None else None
        self._clock = obs.clock if obs is not None else time.perf_counter
        self._c_decode = self._metrics.counter("engine.decode_dispatches")
        self._c_prefill = self._metrics.counter("engine.prefill_dispatches")
        self._c_ticks = self._metrics.counter("engine.ticks")
        self._c_prefill_tokens = self._metrics.counter("engine.prefill_tokens")
        self._submit_ts: dict[int, float] = {}    # uid → ORIGINAL submit ts
        self._wait_from: dict[int, float] = {}    # uid → submit OR requeue ts
        self._seen_uids: set[int] = set()         # first-token bookkeeping
        # streaming hooks (the async front-end installs these; both run
        # on the engine thread and must not block)
        self.on_token = None                      # fn(req, tok) per token
        self.on_retire = None                     # fn(req) at retirement
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * self.max_slots
        self.retired: list[Request] = []
        self._prefill, self._decode = _jitted(model, cfg, policy)
        self._step = 0
        self._per_request: dict[int, dict] = {}   # uid → token counts
        self.run_stats: dict = {}        # filled by run()
        self._backend = self.kernel_backend     # resolved once: attribution
        # circuit-breaker fallback jits (same math, use_kernels="never"
        # lowering) — only built when the native path is a kernel path,
        # so a trip has somewhere safe to land (docs/resilience.md)
        self._fb_policy = None
        if policy is not None and self._backend in ("pallas", "interpret"):
            self._fb_policy = dataclasses.replace(policy,
                                                  use_kernels="never")
        if self._fb_policy is not None:
            self._prefill_fb, self._decode_fb = _jitted(model, cfg,
                                                        self._fb_policy)
        else:
            self._prefill_fb = self._decode_fb = None
        self._init_caches()

    # registry-backed views of the legacy counter attributes (run_stats
    # keys and these names are unchanged for backward compatibility)
    @property
    def decode_dispatches(self) -> int:
        """Jitted decode calls issued."""
        return int(self._c_decode.value)

    @property
    def prefill_dispatches(self) -> int:
        """Jitted prefill calls issued."""
        return int(self._c_prefill.value)

    @property
    def ticks(self) -> int:
        """step() calls that decoded."""
        return int(self._c_ticks.value)

    def _init_caches(self):
        """Build this engine's cache storage (layout differs per engine)."""
        raise NotImplementedError

    @property
    def kernel_backend(self) -> str:
        """Resolved matmul backend for this engine's policy ("bf16" when
        no quantization policy is attached).  repro.kernels.ops is the
        single dispatch authority (docs/kernels.md): on TPU hosts the
        quantized decode tick runs the one-pass fused Pallas qlinear."""
        if self.policy is None:
            return "bf16"
        from repro.kernels import ops

        return ops.resolve_backend(self.policy.use_kernels)

    def submit(self, req: Request):
        self.queue.append(req)
        if self.obs is not None:
            self._submit_ts[req.uid] = self._clock()
            self._wait_from[req.uid] = self._submit_ts[req.uid]
            self._tracer.emit("submit", ts=self._submit_ts[req.uid],
                              uid=req.uid, prompt_len=len(req.prompt))

    def resubmit(self, req: Request):
        """Re-admit a request that already streamed tokens on a PREVIOUS
        engine instance — the front-end watchdog's recovery path.
        Admission re-prefills ``_resume_ctx`` (prompt + tokens so far)
        exactly like a preemption resume, so the greedy continuation is
        token-identical and already-streamed tokens are neither repeated
        nor lost; obs bookkeeping marks the re-admission ``resumed``."""
        if req.out_tokens:
            self._seen_uids.add(req.uid)
        self.submit(req)

    @staticmethod
    def _resume_ctx(req: Request) -> np.ndarray:
        """Full re-prefill context for a (possibly resumed) request: the
        ORIGINAL prompt plus every token generated so far.  Computed at
        admission time — ``_preempt_youngest`` used to fold
        ``out_tokens`` into ``req.prompt`` in place, which corrupted the
        caller-visible Request (retired requests came back with a prompt
        they never submitted, and retire-event ``prompt_len`` inflated),
        and a SECOND preemption of the same request re-folded the
        already-folded tokens, duplicating context."""
        if not req.out_tokens:
            return np.asarray(req.prompt, np.int64)
        return np.concatenate([np.asarray(req.prompt, np.int64),
                               np.asarray(req.out_tokens, np.int64)])

    def _finished(self, req: Request, tok: int) -> bool:
        return tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens

    def _install_slot_cache(self, slot: int, cache):
        """Store an admitted request's prefilled batch-1 cache for
        ``slot`` (layout differs per engine)."""
        raise NotImplementedError

    def _count_prefill(self, req: Request, n_tokens: int):
        self._c_prefill_tokens.inc(n_tokens)
        rec = self._per_request.setdefault(req.uid,
                                           {"prefill": 0, "decode": 0})
        rec["prefill"] += n_tokens

    def _append_token(self, req: Request, tok: int):
        """Every sampled token flows through here so the streaming hook
        sees it the instant it exists (the async front-end forwards it
        to the client's open response)."""
        req.out_tokens.append(tok)
        if self.on_token is not None:
            self.on_token(req, tok)

    def _retire(self, req: Request):
        req.done = True
        self.retired.append(req)
        rec = self._per_request.setdefault(req.uid,
                                           {"prefill": 0, "decode": 0})
        rec["decode"] = len(req.out_tokens)
        self._metrics.counter("engine.requests_retired").inc()
        if self.obs is not None:
            now = self._clock()
            e2e = now - self._submit_ts.get(req.uid, now)
            extra = {"cancelled": True} if req.cancelled else {}
            if req.failed:
                extra["failed"] = True
            self._tracer.emit("retire", ts=now, uid=req.uid,
                              prompt_len=len(req.prompt),
                              decode_tokens=len(req.out_tokens), e2e_s=e2e,
                              **extra)
            # retired uids never re-admit: drop their timestamp entries
            # so a long-lived front-end engine doesn't grow unboundedly
            self._submit_ts.pop(req.uid, None)
            self._wait_from.pop(req.uid, None)
            self._seen_uids.discard(req.uid)
        if self.on_retire is not None:
            self.on_retire(req)

    def cancel(self, uid: int) -> bool:
        """Cancel a queued or in-flight request (the front-end's
        deadline path).  The request retires immediately with
        ``cancelled=True`` — its already-streamed tokens stand — and an
        occupied slot is evicted (the paged engine returns its pages to
        the pool).  Returns False when the uid is not present (already
        retired: the caller lost the race, which is fine)."""
        for i, r in enumerate(self.queue):
            if r.uid == uid:
                del self.queue[i]
                r.cancelled = True
                self._retire(r)
                return True
        for i, r in enumerate(self.slots):
            if r is not None and r.uid == uid:
                r.cancelled = True
                self._retire(r)
                self._evict_slot(i)
                return True
        return False

    def _evict_slot(self, slot: int):
        """Clear a cancelled request's slot.  The dense engines just
        vacate it (admission overwrites the slot cache wholesale)."""
        self.slots[slot] = None

    # -- resilience layer (docs/resilience.md) ------------------------------

    def _fire(self, site: str, **ctx):
        """Advance the fault plan at one site (callers pre-check
        ``self._faults is not None``); a triggered spec also lands a
        ``fault`` trace event so chaos-run traces are self-describing."""
        spec = self._faults.fire(site, **ctx)
        if spec is not None and self.obs is not None:
            self._tracer.emit("fault", ts=self._clock(), site=site, **ctx)
        return spec

    def _fire_slow_tick(self):
        spec = self._fire("slow_tick", tick=self._step)
        if spec is not None and spec.delay_s > 0:
            time.sleep(spec.delay_s)

    def _dispatch_guarded(self, op: str, native, fallback):
        """Issue ONE jitted dispatch through the fault plane and the
        process-wide kernel circuit breaker (``repro.kernels.ops``).

        ``native``/``fallback`` are zero-arg closures capturing their
        argument pytrees late — a retry of a donating dispatch (paged
        prefill) must re-materialize the donated cache.  An injected
        ``dispatch_raise`` fires BEFORE the call for the same reason: no
        donated buffer is ever half-consumed by a scheduled fault.
        Returns ``(outputs, executed_backend)``.

        With no fallback (bf16 engines, ``use_kernels="never"``, auto on
        a non-TPU host) a failure propagates: containment moves up to
        the front-end watchdog.  Otherwise a native failure trips the
        breaker for ``op`` and the tick completes on the XLA fallback
        jit; while the circuit is open every dispatch rides the fallback
        (counted under ``dispatch.fallback.*``) until a half-open probe
        succeeds and closes it again.
        """
        if fallback is None:
            if self._faults is not None and self._fire("dispatch_raise",
                                                       op=op):
                raise FaultInjected("dispatch_raise", op)
            return native(), self._backend
        from repro.kernels import ops

        mode = ops.resolve_backend(self.policy.use_kernels, op=op)
        if mode == "xla":
            # circuit open: ride the fallback until the breaker re-probes
            self._metrics.counter(f"dispatch.fallback.{op}").inc()
            if self._faults is not None and self._fire("dispatch_raise",
                                                       op=op):
                raise FaultInjected("dispatch_raise", f"{op} (fallback)")
            return fallback(), "xla"
        try:
            if self._faults is not None and self._fire("dispatch_raise",
                                                       op=op):
                raise FaultInjected("dispatch_raise", op)
            out = native()
        except Exception as exc:  # noqa: BLE001 — any dispatch failure trips
            ops.breaker.record_failure(op)
            self._metrics.counter("engine.breaker_trips").inc()
            self._metrics.counter(f"dispatch.fallback.{op}").inc()
            if self.obs is not None:
                self._tracer.emit("breaker", ts=self._clock(), op=op,
                                  action="trip", error=repr(exc))
            return fallback(), "xla"
        if ops.breaker.record_success(op):
            self._metrics.counter("engine.breaker_recoveries").inc()
            if self.obs is not None:
                self._tracer.emit("breaker", ts=self._clock(), op=op,
                                  action="recover")
        return out, mode

    def _poison_logits(self, logits, active: list[int]):
        """``nan_logits`` fault site: poison scheduled slots' logits
        rows ON DEVICE, post-dispatch — other rows' values are the exact
        arrays the fault-free run produced, which is what makes the
        chaos suite's bit-identical-survivors invariant provable."""
        for i in active:
            if self._fire("nan_logits", uid=self.slots[i].uid,
                          tick=self._step):
                logits = logits.at[i].set(jnp.nan)
        return logits

    def _guard_rows(self, logits, active: list[int]) -> list[int]:
        """Opt-in per-tick finite check over the active rows' last-token
        logits; returns the slots to fail this tick (empty when the
        guard is off — the common path costs one attribute check)."""
        if not self._nan_guard or not active:
            return []
        finite = np.isfinite(np.asarray(logits[:, -1],
                                        np.float32)).all(axis=-1)
        return [i for i in active if not finite[i]]

    def _fail_slot(self, slot: int, reason: str = "nonfinite_logits"):
        """Numerical-guard containment: retire ONE slot with status
        ``failed`` — pages freed through ``_evict_slot`` — and escalate
        a quant-health-style ``guard`` trace event citing the layer
        whose Eq.-2 difficulty is worst over this request's context (the
        runtime counterpart of the passive ``quant_health`` sampler).
        Every other slot is untouched: the guard reads only the failing
        row."""
        req = self.slots[slot]
        req.failed = True
        self._metrics.counter("engine.requests_failed").inc()
        if self.obs is not None:
            self._tracer.emit("guard", ts=self._clock(), uid=req.uid,
                              slot=slot, tick=self.ticks, reason=reason,
                              **self._guard_escalation(req))
        self._retire(req)
        self._evict_slot(slot)

    def _guard_escalation(self, req: Request) -> dict:
        """Name the worst-difficulty (module, layer) for the failing
        request's context via the quant-health tap forward — only when
        the sampler is attached (obs opt-in), else the guard event
        carries just uid/slot/reason."""
        if self._qhealth is None:
            return {}
        ctx = np.concatenate([np.asarray(req.prompt, np.int64),
                              np.asarray(req.out_tokens, np.int64)])
        rec = self._qhealth.sample(self.ticks, req.uid, ctx)
        worst = (None, -1, float("-inf"))
        for mod, sig in rec["modules"].items():
            for layer, diff in enumerate(sig["difficulty"]):
                if diff > worst[2]:
                    worst = (mod, layer, diff)
        if worst[0] is None:
            return {}
        return {"module": worst[0], "layer": worst[1],
                "difficulty": float(worst[2])}

    @property
    def prompt_capacity(self) -> int:
        """Longest prompt this engine can ever admit — the front-end
        rejects over-capacity submissions with an HTTP 400 instead of
        letting ``submit`` raise on the engine thread."""
        return self.max_len

    # -- obs hooks (all no-ops costing one attribute check when disabled) --

    def _obs_admitted(self, req: Request, slot: int) -> float:
        """Emit admit (+ queue-wait) for one request; returns 'now'.

        Queue wait is measured from ``_wait_from`` — the ORIGINAL submit
        for a fresh request, the REQUEUE time for a preemption-resumed
        one (``_preempt_youngest`` stamps it).  Measuring resumes from
        the original submit would double-count the first service period
        and inflate ``engine.queue_wait_s``; end-to-end latency keeps
        the original submit via ``_submit_ts``."""
        now = self._clock()
        wait = now - self._wait_from.get(req.uid, now)
        self._metrics.histogram("engine.queue_wait_s").observe(wait)
        self._tracer.emit("admit", ts=now, uid=req.uid, slot=slot,
                          queue_wait_s=wait,
                          resumed=req.uid in self._seen_uids)
        return now

    def _obs_prefill_token(self, req: Request):
        """Timestamp the token sampled from prefill logits.  For a
        freshly admitted request that is the FIRST token (TTFT); a
        preemption-resumed request already streamed its first token, but
        the resume-prefill token is still a real streamed token — it
        gets a ``token`` event so the per-token chain and trace-derived
        ``decode_tokens`` stay complete (summarize counts it)."""
        now = self._clock()
        if req.uid in self._seen_uids:
            self._tracer.emit("token", ts=now, uid=req.uid, resumed=True)
            return
        self._seen_uids.add(req.uid)
        ttft = now - self._submit_ts.get(req.uid, now)
        self._metrics.histogram("engine.ttft_s").observe(ttft)
        self._tracer.emit("first_token", ts=now, uid=req.uid, ttft_s=ttft)

    def _attr_decode_dispatch(self, n_rows: int, backend: str | None = None):
        """Per-backend decode-dispatch count + modeled HBM bytes
        (launch/roofline.py) — the byte attribution only when obs is on
        (it walks the active slots for the mean context length).
        ``backend`` is the EXECUTED mode when the circuit breaker may
        have rerouted the dispatch (default: the engine's native)."""
        self._metrics.counter(
            f"dispatch.decode.{backend or self._backend}").inc()
        if self.obs is None:
            return
        ctx = [len(r.prompt) + len(r.out_tokens)
               for r in self.slots if r is not None]
        mean_ctx = sum(ctx) / max(len(ctx), 1)
        pa = getattr(self, "paged_attention_backend", "pallas")
        nbytes = serving_tick_hbm_bytes(
            self.cfg, n_rows, mean_ctx,
            weight_bits=self.policy.weight_bits if self.policy else None,
            kv_bits=self.kv_bits,
            backend="xla" if pa == "xla" else "pallas")
        self._metrics.counter(
            f"hbm_modeled_bytes.decode.{backend or self._backend}").inc(
            nbytes)

    def _attr_prefill_dispatch(self, n_rows: int, padded_len: int,
                               backend: str | None = None):
        self._metrics.counter(
            f"dispatch.prefill.{backend or self._backend}").inc()
        if self.obs is None:
            return
        nbytes = serving_prefill_hbm_bytes(
            self.cfg, n_rows, padded_len,
            weight_bits=self.policy.weight_bits if self.policy else None,
            kv_bits=self.kv_bits)
        self._metrics.counter(
            f"hbm_modeled_bytes.prefill.{backend or self._backend}").inc(
            nbytes)

    def _maybe_quant_health(self):
        """Opt-in every-N-ticks activation health probe over the active
        request with the deepest context (repro.obs.quant_health)."""
        qh = self._qhealth
        if qh is None or not qh.due(self.ticks):
            return
        reqs = [r for r in self.slots if r is not None]
        if not reqs:
            return
        req = max(reqs, key=lambda r: len(r.prompt) + len(r.out_tokens))
        ctx = np.concatenate([np.asarray(req.prompt, np.int64),
                              np.asarray(req.out_tokens, np.int64)])
        rec = qh.sample(self.ticks, req.uid, ctx)
        self._tracer.emit("quant_health", **rec)

    def _pool_stats(self) -> dict:
        """Page-pool occupancy; non-paged engines have no pool."""
        return {}

    def stats(self) -> dict:
        """Aggregate + per-request token counts (so callers stop
        re-deriving them from the retired Request lists by hand).
        Counter-backed fields read from the obs metrics registry — ONE
        implementation for all three engines, keys unchanged."""
        # a truncated run (max_ticks exhausted) leaves requests in slots
        # or requeued: fold their in-flight decode counts in so the
        # aggregate never under-reports work actually done
        from repro.kernels import ops

        for req in list(self.slots) + list(self.queue):
            if req is not None and req.uid in self._per_request:
                self._per_request[req.uid]["decode"] = len(req.out_tokens)
        return {
            "requests_failed": int(
                self._metrics.counter("engine.requests_failed").value),
            "breaker": ops.breaker.state(),
            "requests": len(self._per_request),
            "prefill_tokens": int(self._c_prefill_tokens.value),
            "decode_tokens": sum(r["decode"]
                                 for r in self._per_request.values()),
            "per_request": {uid: dict(rec)
                            for uid, rec in self._per_request.items()},
            "ticks": self.ticks,
            "decode_dispatches": self.decode_dispatches,
            "prefill_dispatches": self.prefill_dispatches,
            "dispatches_per_tick": self.decode_dispatches / max(self.ticks, 1),
            "kernel_backend": self._backend,
            "dispatch_backends": self._metrics.counters_with_prefix(
                "dispatch."),
            "hbm_modeled_bytes": self._metrics.counters_with_prefix(
                "hbm_modeled_bytes."),
            **self._pool_stats(),
        }

    def _admit(self):
        for i in range(self.max_slots):
            while self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                if self.obs is not None:
                    t0 = self._obs_admitted(req, i)
                # prefill the RESUME context (prompt + generated) — for a
                # fresh request that is just the prompt; a watchdog
                # re-admission replays its streamed tokens too
                ctx = self._resume_ctx(req)
                fresh = self.model.make_cache(self.cfg, 1, self.max_len,
                                              bits=self.kv_bits)
                toks = jnp.asarray(ctx[None, :], jnp.int32)
                (logits, slot_cache), used = self._dispatch_guarded(
                    "prefill",
                    lambda t=toks, c=fresh: self._prefill(self.params, t, c),
                    None if self._prefill_fb is None else
                    (lambda t=toks, c=fresh: self._prefill_fb(self.params,
                                                              t, c)))
                self._c_prefill.inc()
                self._attr_prefill_dispatch(1, len(ctx), used)
                self._count_prefill(req, len(ctx))
                nxt = int(_sample_one(logits[:, -1], req.temperature,
                                      self._step, req.uid)[0])
                if self.obs is not None:
                    # nxt materialized ⇒ the prefill dispatch completed
                    now = self._clock()
                    self._metrics.histogram("engine.prefill_s").observe(
                        now - t0)
                    self._tracer.emit("prefill", ts=now, n_requests=1,
                                      n_tokens=len(ctx), rows=1,
                                      padded_len=len(ctx),
                                      dur_s=now - t0)
                    self._obs_prefill_token(req)
                self._append_token(req, nxt)
                # the prefill-sampled token can already finish the request
                # (EOS or max_new_tokens=1): retire without occupying the
                # slot, and keep admitting into it
                if self._finished(req, nxt):
                    self._retire(req)
                else:
                    self.slots[i] = req
                    self._install_slot_cache(i, slot_cache)

    def pop_retired(self) -> list[Request]:
        """Drain and return retired requests (callers driving step()
        directly should call this periodically — the engine does not
        retain retired requests once handed out)."""
        out, self.retired = self.retired, []
        return out

    def run(self, max_ticks: int = 1000) -> list[Request]:
        """Tick until queue and slots drain (or the tick budget runs out);
        returns every retired request not yet handed out — including ones
        already occupying a slot beforehand or submitted mid-run.  The
        aggregate/per-request token counts and (paged engines) page-pool
        occupancy land in ``self.run_stats``."""
        while (self.queue or any(self.slots)) and max_ticks > 0:
            self.step()
            max_ticks -= 1
        self.run_stats = self.stats()
        return self.pop_retired()


class ServingEngine(_EngineBase):
    """Slot-batched continuous batching: one decode dispatch per tick."""

    def _init_caches(self):
        # ONE slot-major cache: data leaves (layer, slot, ...), lengths
        # vectorized to (max_slots,) so each slot decodes at its own depth
        self.cache = cm.batch_slot_cache(
            self.model.make_cache(self.cfg, self.max_slots, self.max_len,
                                  bits=self.kv_bits))

    def _install_slot_cache(self, slot: int, cache):
        # full-extent copy: no stale KV/scales from the slot's previous
        # occupant survive admission
        self.cache = _write_slot(self.cache, cache, slot)

    def _sample_batch(self, logits: jax.Array, temps: np.ndarray,
                      uids: np.ndarray) -> jax.Array:
        """Vectorized over slots: greedy rows take argmax; temperature
        rows draw categorically with a per-(tick, uid) key."""
        greedy = jnp.argmax(logits, -1)
        if not (temps > 0).any():
            return greedy
        keys = jax.vmap(lambda u: _sample_key(self._step, u))(
            jnp.asarray(uids, jnp.int32))
        scaled = logits / jnp.maximum(jnp.asarray(temps), 1e-6)[:, None]
        drawn = jax.vmap(jax.random.categorical)(keys, scaled)
        return jnp.where(jnp.asarray(temps) > 0, drawn, greedy)

    # -- one engine tick ----------------------------------------------------

    def step(self) -> int:
        """Admit + decode one token for every active slot with a SINGLE
        (max_slots, 1) jitted dispatch. Returns the number of active
        sequences."""
        if self._faults is not None:
            self._fire_slow_tick()
        self._admit()
        self._step += 1
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        last = np.zeros((self.max_slots, 1), np.int32)
        temps = np.zeros((self.max_slots,), np.float32)
        uids = np.zeros((self.max_slots,), np.int32)
        for i in active:
            req = self.slots[i]
            last[i, 0] = req.out_tokens[-1]
            temps[i] = req.temperature
            uids[i] = req.uid
        # inactive slots ride along masked: their rows decode garbage that
        # is never sampled into a request, and admission overwrites their
        # slot cache wholesale
        t0 = self._clock() if self.obs is not None else 0.0
        last_j = jnp.asarray(last)
        (logits, self.cache), used = self._dispatch_guarded(
            "decode",
            lambda: self._decode(self.params, last_j, self.cache),
            None if self._decode_fb is None else
            (lambda: self._decode_fb(self.params, last_j, self.cache)))
        self._c_decode.inc()
        self._c_ticks.inc()
        self._attr_decode_dispatch(self.max_slots, used)
        if self._faults is not None:
            logits = self._poison_logits(logits, active)
        failed = self._guard_rows(logits, active)
        toks = np.asarray(self._sample_batch(logits[:, -1], temps, uids))
        if self.obs is not None:
            # toks materialized ⇒ the decode dispatch completed
            now = self._clock()
            self._metrics.histogram("engine.tick_s").observe(now - t0)
            # failed rows stream no token, so they are excluded from the
            # tick's uid list (summarize counts one decode token per uid)
            self._tracer.emit("tick", ts=now, tick=self.ticks,
                              n_active=len(active),
                              uids=[self.slots[i].uid for i in active
                                    if i not in failed],
                              dur_s=now - t0)
        for i in active:
            req = self.slots[i]
            if i in failed:
                # guard containment: no token appended from a non-finite
                # row — the request retires failed, others are untouched
                self._fail_slot(i)
                continue
            nxt = int(toks[i])
            self._append_token(req, nxt)
            if self._finished(req, nxt):
                self._retire(req)
                self.slots[i] = None
        self._maybe_quant_health()
        return len(active)


def _paged_part(cache) -> cm.PagedKVCache | None:
    """The PagedKVCache component of a family cache, if any (the SSM
    family's O(1) state has nothing to page)."""
    if isinstance(cache, cm.PagedKVCache):
        return cache
    attn = getattr(cache, "attn", None)
    return attn if isinstance(attn, cm.PagedKVCache) else None


class PagedServingEngine(ServingEngine):
    """Continuous batching over a PAGED KV pool with in-engine batched
    prefill.

    Memory layer: ``model.make_paged_cache`` backs attention KV with
    fixed-size pages from a shared pool (``n_pages``; default sized for
    zero overcommit).  The HOST owns allocation: a numpy page table +
    free list, synced into the cache pytree before every dispatch.
    Slots grow one page at a time as they decode; retirement returns
    pages to the pool (stale page contents are never read — validity is
    the per-slot length prefix, and positions are overwritten before
    they become valid).

    Admission layer: each round admits every FIFO request that fits
    (free slot + enough free pages for its prompt, else the head of the
    queue WAITS — pool backpressure), then prefills the whole batch with
    ONE jitted ``(n_admit_padded, padded_prompt_len)`` dispatch that
    scatter-writes straight into the assigned pages.  Prompt lengths are
    padded to a shared ``prefill_bucket`` multiple and the row count to a
    power of two, so mixed lengths share a dispatch and the jit cache
    stays small.

    A slot whose next page cannot be allocated mid-decode simply sits
    out ticks until pages free up (its tokens are unaffected — decode
    depends only on its own cache); if EVERY active slot is stalled, the
    youngest is preempted back to the queue (greedy continuation after
    re-prefill is token-identical).  Sizing the pool below
    ``ceil(max_prompt / page_size)`` can therefore starve admission —
    ``run()``'s tick budget still bounds the loop.

    Decode keeps the batched engine's contract: ONE ``(max_slots, 1)``
    dispatch per tick, greedy output token-identical to
    ``PerSlotServingEngine``.

    Prefix caching (``EngineConfig(prefix_cache=True)``, docs/serving.md
    §Prefix caching): prompts are chain-hashed in page-size chunks into
    a host-side map from chunk chain → physical page.  A cache-hit
    admission points its page-table row at the shared pages (per-page
    refcounts) and re-prefills ONLY the non-shared suffix through the
    chunked-continuation dispatch; freed pages park in an LRU tier
    reclaimed under pool pressure, and copy-on-write clones any shared
    page before a write could touch it.  Off (the default) the engine
    runs the pre-cache allocator byte for byte.
    """

    def __init__(self, model, params, cfg: ModelConfig, *,
                 config: EngineConfig | None = None, **legacy):
        config = resolve_engine_config(config, legacy)
        self.page_size = config.page_size
        self.prefill_bucket = config.prefill_bucket
        self._n_pages_arg = config.n_pages
        super().__init__(model, params, cfg, config=config)
        policy = config.policy
        self._prefill_paged = _jitted_paged_prefill(model, cfg, policy)
        self._prefill_paged_fb = (
            _jitted_paged_prefill(model, cfg, self._fb_policy)
            if self._fb_policy is not None else None)
        self._admit_seq = 0
        self._admitted_at = [0] * self.max_slots
        # chunked prefill: prompts longer than ``prefill_chunk`` stream
        # through bounded (n, chunk) continuation dispatches interleaved
        # with decode ticks, so a long admit can't stall a tick's worth
        # of streaming tokens.  Requires per-row start offsets in the
        # family's prefill_paged — families without the continuation
        # path (SSM scan state, per-invocation hybrid KV, MLA latent
        # pools) fall back to whole-prompt prefill, recorded in stats().
        self.prefill_chunk = config.prefill_chunk
        self._chunked = (bool(config.prefill_chunk) and self._pt is not None
                         and getattr(model, "supports_chunked_prefill",
                                     False))
        # the prefix cache's suffix re-prefill rides the same per-row
        # ``start=`` continuation jit, so it is built for either feature
        if self._chunked or self._prefix_on:
            self._prefill_cont = _jitted_chunked_prefill(model, cfg, policy)
            self._prefill_cont_fb = (
                _jitted_chunked_prefill(model, cfg, self._fb_policy)
                if self._fb_policy is not None else None)
        # speculative decoding (docs/speculative.md): a draft model
        # autoregressively proposes spec_k tokens per ready slot against
        # its OWN slot-major dense cache, the target scores all k+1
        # positions in ONE batched ragged verify dispatch per tick, and
        # greedy acceptance (longest matching prefix + one corrected
        # token) keeps output bit-identical to the plain path.  Gated
        # like the prefix cache on the per-row ``start`` continuation
        # machinery (verify IS a continuation dispatch returning
        # all-position logits); families without ``verify_paged`` serve
        # identically with ``stats()["spec"]["enabled"] is False``.
        self._spec_on = (config.spec_k > 0 and self._pt is not None
                         and getattr(model, "supports_chunked_prefill",
                                     False)
                         and getattr(model, "verify_paged", None)
                         is not None)
        if self._spec_on:
            self._verify = _jitted_verify(model, cfg, policy)
            self._verify_fb = (_jitted_verify(model, cfg, self._fb_policy)
                               if self._fb_policy is not None else None)
            dcfg = config.spec_draft_config
            if dcfg is None:
                # self-draft: the target drafts for itself through the
                # dense batch-slot decode path (the per-slot oracle's
                # numerics, including the int8-KV roundtrip), so every
                # draft matches the verify argmax and each dispatch
                # emits k+1 tokens — the bench's acceptance ceiling
                self.draft_model, self.draft_cfg = model, cfg
                self.draft_params = params
                self._draft_policy = policy
                self._draft_bits = self.kv_bits
            else:
                if dcfg.vocab_size != cfg.vocab_size:
                    raise ValueError(
                        "spec_draft_config vocab_size "
                        f"{dcfg.vocab_size} != target vocab_size "
                        f"{cfg.vocab_size}: draft and target must share "
                        "a token space")
                from repro.models.api import get_model

                self.draft_model = get_model(dcfg)
                self.draft_cfg = dcfg
                self.draft_params = self.draft_model.init(
                    jax.random.PRNGKey(11), dcfg)
                self._draft_policy = None
                self._draft_bits = None
            self._draft_prefill, self._draft_decode = _jitted(
                self.draft_model, self.draft_cfg, self._draft_policy)
            # headroom past max_len: a fully accepted run's draft length
            # reaches _len + spec_k + 1 before the next tick's writes
            self._draft_max_len = self.max_len + config.spec_k + 1
            self._draft_cache = cm.batch_slot_cache(
                self.draft_model.make_cache(self.draft_cfg, self.max_slots,
                                            self._draft_max_len,
                                            bits=self._draft_bits))

    # -- memory layer -------------------------------------------------------

    def _init_caches(self):
        self.cache = self.model.make_paged_cache(
            self.cfg, self.max_slots, self.max_len, page_size=self.page_size,
            n_pages=self._n_pages_arg, bits=self.kv_bits)
        part = _paged_part(self.cache)
        if part is None:                 # ssm: O(1) state, nothing to page
            self.n_pages, self.table_width = 0, 0
            self._pt, self._free = None, []
        else:
            self.n_pages = part.n_pages
            self.table_width = part.page_table.shape[1]
            self._pt = np.full((self.max_slots, self.table_width), -1,
                               np.int32)
            self._free = list(range(self.n_pages - 1, -1, -1))  # pop() → 0 first
        self._len = np.zeros((self.max_slots,), np.int32)
        # speculative draft sync state: slot i's draft cache holds KV
        # for positions [0, _draft_len[i]); in-sync means equal to
        # _len[i] (lazy — _spec_step re-prefills on mismatch)
        self._draft_len = np.zeros((self.max_slots,), np.int32)
        self.peak_pages_in_use = 0
        self._prefilling: dict[int, int] = {}   # slot → prompt tokens done
        # prefix cache (docs/serving.md §Prefix caching): content-chained
        # chunk hashes → physical pages, per-page slot refcounts, and an
        # LRU tier of cached-but-unreferenced pages reclaimed under pool
        # pressure.  Gated on the chunked-prefill continuation dispatch
        # (the suffix re-prefill needs per-row ``start`` offsets):
        # families without it admit every request as a miss, and the
        # knob defaults OFF — a cache-off engine runs the pre-cache
        # allocator byte for byte.
        self._prefix_on = (bool(self.config.prefix_cache)
                           and self._pt is not None
                           and getattr(self.model,
                                       "supports_chunked_prefill", False))
        # refcounts are maintained whenever a pool exists (cache on or
        # off — with the map empty they reduce to the old free list)
        self._ref = (np.zeros((self.n_pages,), np.int64)
                     if self._pt is not None else None)
        self._cache_map: dict[int, int] = {}   # chain key → physical page
        self._page_key: dict[int, int] = {}    # physical page → chain key
        self._lru: dict[int, int] = {}         # chain key → last-use seq
        self._lru_seq = 0

    def _host_state_cache(self):
        """Cache pytree with the HOST-authoritative page table + per-slot
        lengths pushed in (stalled/inactive rows never advance)."""
        c = self.cache
        if isinstance(c, cm.PagedKVCache):
            return dataclasses.replace(c, page_table=jnp.asarray(self._pt),
                                       length=jnp.asarray(self._len))
        if _paged_part(c) is not None:   # hybrid: paged attn component
            # NOTE: distinct length buffers — prefill donates the cache,
            # and one array aliased into two leaves donates twice
            return dataclasses.replace(
                c, attn=dataclasses.replace(
                    c.attn, page_table=jnp.asarray(self._pt),
                    length=jnp.asarray(np.array(self._len))),
                length=jnp.asarray(np.array(self._len, copy=True)))
        return dataclasses.replace(c, length=jnp.asarray(self._len))

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free) if self._pt is not None else 0

    def _note_occupancy(self):
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)

    @property
    def paged_attention_backend(self) -> str:
        """Resolved executor for decode attention over the paged pool —
        "pallas"/"interpret" (the in-VMEM Pallas kernel), "xla" (the
        ``paged_view`` gather fallback; always the case for MLA latent
        pools), or "none" (pure-SSM: nothing to page).
        ``common.paged_attn_backend`` is the single dispatch authority
        (docs/paged_attention.md)."""
        return cm.paged_attn_backend(self.cfg, self.policy)

    def _pool_stats(self) -> dict:
        n = max(self.n_pages, 1)
        c = self._metrics.counter
        hits = int(c("prefix.hits").value)
        misses = int(c("prefix.misses").value)
        return {"page_size": self.page_size, "n_pages": self.n_pages,
                "table_width": self.table_width,
                "pages_in_use": self.pages_in_use,
                "peak_pages_in_use": self.peak_pages_in_use,
                "page_occupancy": self.pages_in_use / n,
                "page_occupancy_peak": self.peak_pages_in_use / n,
                "paged_attention_backend": self.paged_attention_backend,
                "prefill_chunk": self.prefill_chunk or 0,
                "chunked_prefill": self._chunked,
                "prefix": {
                    "enabled": self._prefix_on,
                    "hits": hits, "misses": misses,
                    "hit_rate": hits / max(hits + misses, 1),
                    "shared_pages": int(c("prefix.shared_pages").value),
                    "cow_copies": int(c("prefix.cow_copies").value),
                    "evictions": int(c("prefix.evictions").value),
                    "cached_pages": len(self._page_key),
                    "saved_prefill_tokens": int(
                        c("prefix.saved_prefill_tokens").value),
                    "saved_prefill_flops": int(
                        c("prefix.saved_prefill_flops").value),
                    "saved_hbm_bytes": int(
                        c("prefix.saved_hbm_bytes").value)},
                "spec": self._spec_stats()}

    def _spec_stats(self) -> dict:
        c = self._metrics.counter
        drafted = int(c("spec.drafted").value)
        accepted = int(c("spec.accepted").value)
        emitted = int(c("spec.emitted_tokens").value)
        verifies = int(c("spec.verify_dispatches").value)
        return {"enabled": getattr(self, "_spec_on", False),
                "k": self.config.spec_k,
                "self_draft": self.config.spec_draft_config is None,
                "drafted": drafted, "accepted": accepted,
                "rejected": int(c("spec.rejected").value),
                "acceptance_rate": accepted / max(drafted, 1),
                "emitted_tokens": emitted,
                "verify_dispatches": verifies,
                "draft_dispatches": int(c("spec.draft_dispatches").value),
                "draft_prefill_dispatches": int(
                    c("spec.draft_prefill_dispatches").value),
                "accepted_per_dispatch": emitted / max(verifies, 1)}

    def _pages_needed(self, n_tokens: int) -> int:
        if self._pt is None:
            return 0
        return cm.pages_per_slot(n_tokens, self.page_size)

    @property
    def prompt_capacity(self) -> int:
        cap = self.max_len
        if self._pt is not None:
            cap = min(cap, self.table_width * self.page_size,
                      self.n_pages * self.page_size)
        return cap

    def submit(self, req: Request):
        """Reject prompts that could NEVER be admitted up front: the
        dense engines clamp out-of-range cache writes, but a paged slot
        cannot outgrow its page-table width or the whole pool — such a
        request would starve the FIFO queue forever."""
        cap = self.prompt_capacity
        if len(req.prompt) > cap:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds the paged "
                f"engine's capacity of {cap} (max_len={self.max_len}, "
                f"page_size={self.page_size}, n_pages={self.n_pages})")
        super().submit(req)

    def _release_slot(self, slot: int):
        """Free the slot and drop its page references.  A page returns
        to the free list only at refcount 0 AND out of the cache map —
        prefix-shared pages survive a co-resident's retirement, and
        cached pages park in the LRU eviction tier instead."""
        if self._pt is not None:
            for p in self._pt[slot]:
                if p >= 0:
                    self._decref(int(p))
            self._pt[slot] = -1
        self._len[slot] = 0
        # a reused slot's draft cache is stale by construction: zeroing
        # the sync mark forces a draft re-prefill before it drafts again
        self._draft_len[slot] = 0
        self.slots[slot] = None
        self._prefilling.pop(slot, None)

    def _evict_slot(self, slot: int):
        self._release_slot(slot)

    # -- page allocator (refcounts + prefix-cache LRU tier) -----------------

    def _decref(self, p: int):
        self._ref[p] -= 1
        if self._ref[p] == 0 and p not in self._page_key:
            self._free.append(p)

    def _alloc_page(self) -> int | None:
        """Pop a free page, reclaiming an LRU cached-but-unreferenced
        page first when the free list is dry.  Returns None when nothing
        can be reclaimed (the caller stalls or backpressures)."""
        if not self._free and self._prefix_on:
            self._evict_lru()
        return self._free.pop() if self._free else None

    def _evict_lru(self) -> bool:
        """Reclaim the least-recently-used cached page no slot
        references.  Evicting a mid-chain entry orphans its descendants
        (their keys stop being reachable by any match walk) — they age
        out of the LRU the same way, a deliberate simplification over
        cascading the eviction."""
        for key in sorted(self._lru, key=self._lru.get):
            p = self._cache_map[key]
            if self._ref[p] == 0:
                del self._cache_map[key]
                del self._page_key[p]
                del self._lru[key]
                self._free.append(p)
                self._metrics.counter("prefix.evictions").inc()
                return True
        return False

    def _avail_pages(self) -> int:
        """Pages an admission could obtain: the free list plus the LRU
        eviction tier (cached pages no slot references)."""
        n = len(self._free)
        if self._prefix_on:
            n += sum(1 for p in self._page_key if self._ref[p] == 0)
        return n

    def _page_shared(self, p: int) -> bool:
        """A write must never mutate this page in place: another slot
        still references it, or the cache map could hand it to a future
        admission."""
        return self._ref[p] > 1 or p in self._page_key

    def _cow_slot_page(self, slot: int, pi: int) -> bool:
        """Copy-on-write: clone the slot's logical page ``pi`` into a
        fresh physical page before a write would hit pool memory other
        rows (or the cache map) still reference — ``paged_update``
        itself never mutates a shared page.  Returns False when no page
        can be allocated for the clone (the caller stalls, exactly like
        an allocation failure)."""
        dst = self._alloc_page()
        if dst is None:
            return False
        src = int(self._pt[slot, pi])
        self._clone_pool_page(src, dst)
        self._pt[slot, pi] = dst
        self._ref[dst] += 1
        self._decref(src)
        self._metrics.counter("prefix.cow_copies").inc()
        return True

    def _clone_pool_page(self, src: int, dst: int):
        """Copy one physical page across every pool data leaf (k/v +
        int8 scales) with a donated jit per leaf, so the pool updates in
        place instead of re-materializing."""
        part = _paged_part(self.cache)
        src_j, dst_j = jnp.asarray(src), jnp.asarray(dst)
        rep = {name: _page_copy(getattr(part, name), src_j, dst_j)
               for name in ("k", "v", "k_scale", "v_scale")
               if getattr(part, name) is not None}
        new_part = dataclasses.replace(part, **rep)
        if isinstance(self.cache, cm.PagedKVCache):
            self.cache = new_part
        else:
            self.cache = dataclasses.replace(self.cache, attn=new_part)

    # -- prefix cache (chunk-chain hashing over the page pool) --------------

    def _chain_keys(self, ctx: np.ndarray) -> list[int]:
        """Chain hash per FULL page-size chunk of ``ctx``: key_k folds
        key_{k-1} with chunk k's tokens, so a chunk only matches under
        an identical full prefix — page k's KV depends on positions
        ``[0, k*page)`` as much as on its own tokens.  A partial tail
        chunk gets no key (only whole pages are ever shared)."""
        ps = self.page_size
        keys, prev = [], 0
        for k in range(len(ctx) // ps):
            prev = hash((prev,
                         np.asarray(ctx[k * ps:(k + 1) * ps],
                                    np.int64).tobytes()))
            keys.append(prev)
        return keys

    def _touch(self, key: int):
        self._lru_seq += 1
        self._lru[key] = self._lru_seq

    def _match_prefix(self, ctx: np.ndarray) -> tuple[list[int], int]:
        """Longest cached chunk chain prefixing ``ctx`` → (physical
        pages, matched token count).  Touches every matched entry, so
        LRU eviction drops the deepest chain nodes first."""
        if not self._prefix_on:
            return [], 0
        pages = []
        for key in self._chain_keys(ctx):
            p = self._cache_map.get(key)
            if p is None:
                break
            self._touch(key)
            pages.append(p)
        return pages, len(pages) * self.page_size

    def _register_prefix(self, slot: int, ctx: np.ndarray):
        """Publish the slot's freshly prefilled FULL prompt pages into
        the chain map (the partial tail page stays exclusive — decode
        keeps writing into it).  Runs AFTER the prefill dispatch that
        wrote the pages completes, so a same-round co-admission can
        never point at not-yet-written pool memory.  Existing entries
        win: the content is identical, and re-pointing would strand the
        old page's cache hold."""
        if not self._prefix_on:
            return
        for k, key in enumerate(self._chain_keys(ctx)):
            p = int(self._pt[slot, k])
            if p < 0:
                break
            self._touch(key)
            if key in self._cache_map:
                continue
            self._cache_map[key] = p
            self._page_key[p] = key

    def _note_prefix_hit(self, req: Request, slot: int, ctx: np.ndarray,
                         start: int, n_shared: int):
        """Attribution for one cache-hit admission: hit counters, the
        roofline-modeled prefill work the shared pages avoided, and the
        ``prefix_hit`` trace event."""
        self._metrics.counter("prefix.hits").inc()
        self._metrics.counter("prefix.shared_pages").inc(n_shared)
        self._metrics.counter("prefix.saved_prefill_tokens").inc(start)
        self._metrics.counter("prefix.saved_prefill_flops").inc(
            serving_prefill_flops(self.cfg, 1, start))
        self._metrics.counter("prefix.saved_hbm_bytes").inc(
            serving_prefill_hbm_bytes(
                self.cfg, 1, start,
                weight_bits=self.policy.weight_bits if self.policy else None,
                kv_bits=self.kv_bits))
        if self.obs is not None:
            self._tracer.emit("prefix_hit", ts=self._clock(), uid=req.uid,
                              slot=slot, matched_tokens=start,
                              shared_pages=n_shared,
                              suffix_tokens=len(ctx) - start)

    # -- admission layer ----------------------------------------------------

    def _admit(self):
        # rounds: a request finishing at prefill frees its slot and pages
        # for the same tick's next round (matches the per-slot oracle's
        # keep-admitting-into-the-slot behaviour)
        while self._admit_round():
            pass

    def _admit_round(self) -> bool:
        free_slots = [i for i in range(self.max_slots)
                      if self.slots[i] is None]
        batch: list[tuple[int, Request, np.ndarray]] = []
        hits: list[tuple[int, Request, np.ndarray, int]] = []
        admitted_deferred = False
        while free_slots and self.queue:
            req = self.queue[0]
            ctx = self._resume_ctx(req)
            total = self._pages_needed(len(ctx))
            if self._pt is not None and total > min(self.n_pages,
                                                    self.table_width):
                # a resumed context that can NEVER fit again (watchdog
                # re-admission can outgrow a small pool): retire
                # truncated, exactly like _preempt_youngest — leaving
                # it at the FIFO head would starve everything behind
                self.queue.popleft()
                self._retire(req)
                continue
            shared, matched = self._match_prefix(ctx)
            # a FULL match still re-prefills the last token for its
            # next-token logits; that write lands inside the final
            # shared page, so admission reserves one page for the COW
            # clone and the suffix start backs up to len-1
            full = shared and matched == len(ctx)
            need = total - len(shared) + (1 if full else 0)
            if self._pt is not None:
                if need > self._avail_pages():
                    break                # backpressure: FIFO head waits
                if (self._faults is not None
                        and self._fire("page_alloc_fail", uid=req.uid,
                                       op="admit")):
                    break                # injected exhaustion: head waits
            self.queue.popleft()
            slot = free_slots.pop(0)
            if self._pt is not None:
                # shared pages first: the incref pins them against any
                # eviction the fresh allocations below may trigger
                for j, p in enumerate(shared):
                    self._pt[slot, j] = p
                    self._ref[p] += 1
                for j in range(len(shared), total):
                    p = self._alloc_page()
                    self._pt[slot, j] = p
                    self._ref[p] += 1
            if shared:
                start = min(matched, len(ctx) - 1)
                self._note_prefix_hit(req, slot, ctx, start, len(shared))
                if full:
                    self._cow_slot_page(slot, total - 1)
                self.slots[slot] = req
                self._len[slot] = start
                self._admitted_at[slot] = self._admit_seq
                self._admit_seq += 1
                if self.obs is not None:
                    self._obs_admitted(req, slot)
                if self._chunked and len(ctx) - start > self.prefill_chunk:
                    # long suffix: ride the chunked-prefill continuation
                    # machinery from the matched offset
                    self._prefilling[slot] = start
                    admitted_deferred = True
                else:
                    hits.append((slot, req, ctx, start))
                continue
            if self._prefix_on:
                self._metrics.counter("prefix.misses").inc()
            if self._chunked and len(ctx) > self.prefill_chunk:
                # chunked-prefill path: the slot and ALL its prompt pages
                # are assigned now (backpressure semantics unchanged) but
                # the prompt streams through bounded per-tick chunks
                # (_advance_prefill) instead of this round's dispatch
                self.slots[slot] = req
                self._len[slot] = 0
                self._prefilling[slot] = 0
                self._admitted_at[slot] = self._admit_seq
                self._admit_seq += 1
                if self.obs is not None:
                    self._obs_admitted(req, slot)
                admitted_deferred = True
                continue
            batch.append((slot, req, ctx))
        if not batch:
            if hits:
                self._prefill_suffix(hits)
            self._note_occupancy()
            return bool(hits) or admitted_deferred
        # ONE (n_pad, s_pad) prefill dispatch for the whole batch:
        # prompt lengths bucket-padded, row count padded to a power of
        # two (sentinel rows' writes drop in the kernel)
        n_pad = 1 << (len(batch) - 1).bit_length()
        s_max = max(len(ctx) for _, _, ctx in batch)
        s_pad = min(self.max_len,
                    -(-s_max // self.prefill_bucket) * self.prefill_bucket)
        toks = np.zeros((n_pad, s_pad), np.int32)
        lens = np.zeros((n_pad,), np.int32)
        rows = np.full((n_pad,), self.max_slots, np.int32)
        for r, (slot, req, ctx) in enumerate(batch):
            toks[r, :len(ctx)] = ctx
            lens[r] = len(ctx)
            rows[r] = slot
        if self.obs is not None:
            t0 = self._clock()
            for slot, req, _ in batch:
                self._obs_admitted(req, slot)
        toks_j, lens_j = jnp.asarray(toks), jnp.asarray(lens)
        rows_j = jnp.asarray(rows)
        # the cache is DONATED: each closure materializes its own host-
        # state pytree, so a breaker retry never touches consumed buffers
        (logits, self.cache), used = self._dispatch_guarded(
            "prefill",
            lambda: self._prefill_paged(self.params, toks_j, lens_j,
                                        self._host_state_cache(), rows_j),
            None if self._prefill_paged_fb is None else
            (lambda: self._prefill_paged_fb(self.params, toks_j, lens_j,
                                            self._host_state_cache(),
                                            rows_j)))
        self._c_prefill.inc()
        self._attr_prefill_dispatch(n_pad, s_pad, used)
        if self.obs is not None:
            logits.block_until_ready()
            now = self._clock()
            self._metrics.histogram("engine.prefill_s").observe(now - t0)
            self._tracer.emit("prefill", ts=now, n_requests=len(batch),
                              n_tokens=int(lens.sum()), rows=n_pad,
                              padded_len=s_pad, dur_s=now - t0)
        for r, (slot, req, ctx) in enumerate(batch):
            self._count_prefill(req, int(lens[r]))
            # register AFTER the dispatch wrote the pages; BEFORE the
            # finish check, so a one-shot request (the system-prompt
            # seeding shape) still populates the cache as it retires
            self._register_prefix(slot, ctx)
            nxt = int(_sample_one(logits[r], req.temperature, self._step,
                                  req.uid)[0])
            if self.obs is not None:
                self._obs_prefill_token(req)
            self._append_token(req, nxt)
            if self._finished(req, nxt):
                self._retire(req)
                self._release_slot(slot)
            else:
                self.slots[slot] = req
                self._len[slot] = int(lens[r])
                self._admitted_at[slot] = self._admit_seq
                self._admit_seq += 1
        if hits:
            self._prefill_suffix(hits)
        self._note_occupancy()
        return True

    def _prefill_suffix(self, hits: list):
        """ONE batched continuation dispatch for this round's cache-hit
        admissions: each row re-prefills ONLY its non-shared suffix at
        its per-row ``start`` offset, attending over the shared pages
        through its page table (prefix caching requires
        ``supports_chunked_prefill`` for exactly this dispatch).  Suffix
        lengths are bucket-padded and the row count padded to a power of
        two like the whole-prompt path.  Only the dispatched suffix
        tokens are counted as prefill work — the acceptance pin for "the
        second admit prefills only the non-shared suffix"."""
        n_pad = 1 << (len(hits) - 1).bit_length()
        s_max = max(len(ctx) - start for _, _, ctx, start in hits)
        s_pad = min(self.max_len,
                    -(-s_max // self.prefill_bucket) * self.prefill_bucket)
        toks = np.zeros((n_pad, s_pad), np.int32)
        lens = np.zeros((n_pad,), np.int32)
        starts = np.zeros((n_pad,), np.int32)
        rows = np.full((n_pad,), self.max_slots, np.int32)
        for r, (slot, req, ctx, start) in enumerate(hits):
            suffix = ctx[start:]
            toks[r, :len(suffix)] = suffix
            lens[r] = len(suffix)
            starts[r] = start
            rows[r] = slot
        t0 = self._clock() if self.obs is not None else 0.0
        toks_j, lens_j = jnp.asarray(toks), jnp.asarray(lens)
        starts_j, rows_j = jnp.asarray(starts), jnp.asarray(rows)
        (logits, self.cache), used = self._dispatch_guarded(
            "prefill",
            lambda: self._prefill_cont(self.params, toks_j, lens_j, starts_j,
                                       self._host_state_cache(), rows_j),
            None if self._prefill_cont_fb is None else
            (lambda: self._prefill_cont_fb(self.params, toks_j, lens_j,
                                           starts_j,
                                           self._host_state_cache(),
                                           rows_j)))
        self._c_prefill.inc()
        self._attr_prefill_dispatch(n_pad, s_pad, used)
        if self.obs is not None:
            logits.block_until_ready()
            now = self._clock()
            self._metrics.histogram("engine.prefill_s").observe(now - t0)
            self._tracer.emit("prefill", ts=now, n_requests=len(hits),
                              n_tokens=int(lens.sum()), rows=n_pad,
                              padded_len=s_pad, dur_s=now - t0, prefix=True)
        for r, (slot, req, ctx, start) in enumerate(hits):
            took = int(lens[r])
            self._count_prefill(req, took)
            self._len[slot] = start + took
            # deepen the chain: matched entries are skipped, the hit's
            # own full suffix pages register as new descendants
            self._register_prefix(slot, ctx)
            nxt = int(_sample_one(logits[r], req.temperature, self._step,
                                  req.uid)[0])
            if self.obs is not None:
                self._obs_prefill_token(req)
            self._append_token(req, nxt)
            if self._finished(req, nxt):
                self._retire(req)
                self._release_slot(slot)

    def _advance_prefill(self):
        """Advance every chunk-prefilling slot by ONE bounded chunk with
        a single batched continuation dispatch (row count padded to a
        power of two, chunk length fixed — the jit cache stays small).
        Rows whose prompt completes sample their first token from the
        final chunk's logits and become decode-active; the interleave
        with ``step()``'s decode dispatch is what bounds the per-token
        gap concurrent streams see during a long admit."""
        if not self._prefilling:
            return
        items = sorted(self._prefilling.items())
        chunk = self.prefill_chunk
        n_pad = 1 << (len(items) - 1).bit_length()
        toks = np.zeros((n_pad, chunk), np.int32)
        lens = np.zeros((n_pad,), np.int32)
        starts = np.zeros((n_pad,), np.int32)
        rows = np.full((n_pad,), self.max_slots, np.int32)
        for r, (slot, done) in enumerate(items):
            # resume contexts are stable mid-chunking: a chunk-prefilling
            # slot sits out decode, so out_tokens cannot grow under it
            src = self._resume_ctx(self.slots[slot])
            take = min(chunk, len(src) - done)
            toks[r, :take] = src[done:done + take]
            lens[r] = take
            starts[r] = done
            rows[r] = slot
        t0 = self._clock() if self.obs is not None else 0.0
        toks_j, lens_j = jnp.asarray(toks), jnp.asarray(lens)
        starts_j, rows_j = jnp.asarray(starts), jnp.asarray(rows)
        (logits, self.cache), used = self._dispatch_guarded(
            "prefill",
            lambda: self._prefill_cont(self.params, toks_j, lens_j, starts_j,
                                       self._host_state_cache(), rows_j),
            None if self._prefill_cont_fb is None else
            (lambda: self._prefill_cont_fb(self.params, toks_j, lens_j,
                                           starts_j,
                                           self._host_state_cache(),
                                           rows_j)))
        self._c_prefill.inc()
        self._attr_prefill_dispatch(n_pad, chunk, used)
        if self.obs is not None:
            logits.block_until_ready()
            now = self._clock()
            self._metrics.histogram("engine.prefill_s").observe(now - t0)
            self._tracer.emit("prefill", ts=now, n_requests=len(items),
                              n_tokens=int(lens.sum()), rows=n_pad,
                              padded_len=chunk, dur_s=now - t0, chunked=True)
        for r, (slot, done) in enumerate(items):
            req = self.slots[slot]
            took = int(lens[r])
            self._count_prefill(req, took)
            self._len[slot] = done + took
            if done + took < len(self._resume_ctx(req)):
                self._prefilling[slot] = done + took
                continue
            del self._prefilling[slot]
            # the prompt is fully written: publish its full pages (a
            # cache-hit slot chunking from a matched offset deepens the
            # chain — its matched entries are skipped)
            self._register_prefix(slot, self._resume_ctx(req))
            nxt = int(_sample_one(logits[r], req.temperature, self._step,
                                  req.uid)[0])
            if self.obs is not None:
                self._obs_prefill_token(req)
            self._append_token(req, nxt)
            if self._finished(req, nxt):
                self._retire(req)
                self._release_slot(slot)

    def _preempt_youngest(self, active: list[int]):
        """Deadlock breaker: every active slot needs a page and none are
        free.  The youngest occupant requeues; re-admission re-prefills
        its full context (``_resume_ctx``: prompt + generated tokens) —
        that reproduces the pending decode input's logits, so the greedy
        continuation is token-identical.  ``req.prompt`` itself is NOT
        touched: the caller's Request must come back exactly as
        submitted.  A context that can NEVER fit again (more pages than
        the whole pool / table width — the pool is simply too small for
        the request) retires truncated instead of requeueing: leaving it
        at the FIFO head would starve every request behind it forever."""
        i = max(active, key=lambda j: self._admitted_at[j])
        req = self.slots[i]
        self._metrics.counter("engine.preemptions").inc()
        if self.obs is not None:
            self._tracer.emit("preempt", ts=self._clock(), uid=req.uid,
                              slot=i, n_generated=len(req.out_tokens))
        self._release_slot(i)
        ctx_len = len(req.prompt) + len(req.out_tokens)
        if self._pages_needed(ctx_len) > min(self.n_pages,
                                             self.table_width):
            self._retire(req)
        else:
            self.queue.appendleft(req)
            if self.obs is not None:
                # queue wait for the resumed admission is measured from
                # HERE, not the original submit (see _obs_admitted)
                self._wait_from[req.uid] = self._clock()

    # -- one engine tick ----------------------------------------------------

    def step(self) -> int:
        if self._faults is not None:
            self._fire_slow_tick()
        self._admit()
        self._step += 1
        # one bounded prefill chunk per tick, BEFORE the decode dispatch:
        # decoding slots and a chunk-prefilling long prompt make progress
        # in the same tick (slots mid-chunking sit out the decode)
        self._advance_prefill()
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and i not in self._prefilling]
        if not active:
            return 0
        if self._spec_on:
            return self._spec_step(active)
        t0 = self._clock() if self.obs is not None else 0.0
        # on-demand growth: a slot whose next write starts a new page
        # allocates it now; allocation failure stalls the slot this tick
        # (its write would have no destination and is dropped anyway)
        ready = []
        for i in active:
            if self._pt is not None:
                pi = self._len[i] // self.page_size
                if pi < self.table_width and self._pt[i, pi] < 0:
                    # an injected allocation failure behaves exactly like
                    # a genuinely exhausted pool: the slot stalls this
                    # tick (its tokens are unaffected — decode depends
                    # only on its own cache), and the existing stall /
                    # preempt machinery takes over
                    if (self._faults is not None
                            and self._fire("page_alloc_fail",
                                           uid=self.slots[i].uid,
                                           op="grow")):
                        continue
                    p = self._alloc_page()
                    if p is None:
                        continue
                    self._pt[i, pi] = p
                    self._ref[p] += 1
                elif (self._prefix_on and pi < self.table_width
                      and self._page_shared(int(self._pt[i, pi]))
                      and not self._cow_slot_page(i, pi)):
                    # shared/cached pages are always FULL pages, so a
                    # decode write (position >= the prefilled length)
                    # structurally lands in an exclusive tail or a fresh
                    # page — this guard is the paged_update contract's
                    # backstop, and a failed clone stalls like an
                    # allocation failure
                    continue
            ready.append(i)
        self._note_occupancy()
        if not ready:
            self._preempt_youngest(active)
            return 0
        last = np.zeros((self.max_slots, 1), np.int32)
        temps = np.zeros((self.max_slots,), np.float32)
        uids = np.zeros((self.max_slots,), np.int32)
        for i in ready:
            req = self.slots[i]
            last[i, 0] = req.out_tokens[-1]
            temps[i] = req.temperature
            uids[i] = req.uid
        t_alloc = self._clock() if self.obs is not None else 0.0
        before = self._host_state_cache()
        last_j = jnp.asarray(last)
        # decode is NOT donated, so ``before`` stays valid for both the
        # breaker's fallback retry and the ssm rollback below
        (logits, self.cache), used = self._dispatch_guarded(
            "decode",
            lambda: self._decode(self.params, last_j, before),
            None if self._decode_fb is None else
            (lambda: self._decode_fb(self.params, last_j, before)))
        self._c_decode.inc()
        self._c_ticks.inc()
        self._attr_decode_dispatch(self.max_slots, used)
        self._metrics.counter(
            f"dispatch.paged_attention.{self.paged_attention_backend}").inc()
        stalled = [i for i in active if i not in ready]
        if stalled and hasattr(self.cache, "ssm"):
            # paged-KV writes of stalled rows drop (no destination page),
            # but the hybrid family's recurrent state leaves DID advance
            # on the garbage tick — roll those rows back
            sl = np.asarray(stalled)
            self.cache = dataclasses.replace(
                self.cache,
                ssm=self.cache.ssm.at[:, sl].set(before.ssm[:, sl]),
                conv=self.cache.conv.at[:, sl].set(before.conv[:, sl]))
        if self._faults is not None:
            logits = self._poison_logits(logits, ready)
        failed = self._guard_rows(logits, ready)
        toks = np.asarray(self._sample_batch(logits[:, -1], temps, uids))
        if self.obs is not None:
            # toks materialized ⇒ the decode dispatch completed; failed
            # rows stream no token so they leave the tick's uid list
            now = self._clock()
            self._metrics.histogram("engine.tick_s").observe(now - t0)
            self._tracer.emit("tick", ts=now, tick=self.ticks,
                              n_active=len(ready),
                              uids=[self.slots[i].uid for i in ready
                                    if i not in failed],
                              n_stalled=len(stalled), dur_s=now - t0,
                              alloc_dur_s=t_alloc - t0)
        for i in ready:
            req = self.slots[i]
            if i in failed:
                # guard containment: retire failed, pages back to the
                # pool — co-scheduled slots' tokens are bit-identical to
                # a fault-free run (the guard read only this row)
                self._fail_slot(i)
                continue
            self._len[i] += 1
            nxt = int(toks[i])
            self._append_token(req, nxt)
            if self._finished(req, nxt):
                self._retire(req)
                self._release_slot(i)
        self._maybe_quant_health()
        return len(ready)

    # -- speculative decoding (docs/speculative.md) -------------------------

    def _draft_sync(self, slot: int):
        """Re-prefill one slot's context into its draft-cache slot (lazy:
        fresh admissions, preemption resumes, and slot reuse all land
        here the first tick they draft).  Batch-1 through the draft's
        own jit — counted under ``spec.*``, NOT the engine prefill
        counters (dispatch attribution stays target-only)."""
        req = self.slots[slot]
        ctx = self._resume_ctx(req)[:int(self._len[slot])]
        fresh = self.draft_model.make_cache(self.draft_cfg, 1,
                                            self._draft_max_len,
                                            bits=self._draft_bits)
        _, slot_cache = self._draft_prefill(
            self.draft_params, jnp.asarray(ctx[None, :], jnp.int32), fresh)
        # full-extent copy: no stale KV/scales from the slot's previous
        # occupant survive into the draft pass
        self._draft_cache = _write_slot(self._draft_cache, slot_cache, slot)
        self._draft_len[slot] = self._len[slot]
        self._metrics.counter("spec.draft_prefill_dispatches").inc()

    def _spec_budget(self, ready: list[int], active: list[int]) -> dict:
        """Per-slot draft depth + page allocation for the verify write
        range.  Row i's verify writes positions ``[L, L+k_i]`` into the
        pool, so every page covering that span is allocated (or COW'd
        out of sharing) NOW; a dry pool shrinks ``k_i`` to the allocated
        range, and a slot whose FIRST page can't be had stalls exactly
        like the plain path.  Temperature rows draft nothing (k_i = 0 —
        the verify row degenerates to the plain single-position decode,
        sampled with the same per-(tick, uid) key)."""
        ks: dict[int, int] = {}
        for i in active:
            req = self.slots[i]
            L = int(self._len[i])
            ki = 0
            if req.temperature <= 0:
                # one token is always emitted; drafts beyond the request's
                # remaining budget could never be accepted into out_tokens
                ki = max(0, min(self.config.spec_k,
                                req.max_new_tokens - len(req.out_tokens) - 1,
                                self.table_width * self.page_size - 1 - L))
            ok = True
            for pi in range(L // self.page_size,
                            (L + ki) // self.page_size + 1):
                if self._pt[i, pi] < 0:
                    p = None
                    if not (self._faults is not None
                            and self._fire("page_alloc_fail",
                                           uid=req.uid, op="grow")):
                        p = self._alloc_page()
                    if p is None:
                        if pi == L // self.page_size:
                            ok = False
                        else:
                            ki = pi * self.page_size - 1 - L
                        break
                    self._pt[i, pi] = p
                    self._ref[p] += 1
                elif (self._prefix_on
                      and self._page_shared(int(self._pt[i, pi]))
                      and not self._cow_slot_page(i, pi)):
                    if pi == L // self.page_size:
                        ok = False
                    else:
                        ki = pi * self.page_size - 1 - L
                    break
            if ok:
                ready.append(i)
                ks[i] = ki
        return ks

    def _spec_step(self, active: list[int]) -> int:
        """One speculative tick: draft up to k tokens per ready slot
        against the slot-major draft cache, verify every candidate
        position in ONE batched ragged target dispatch, emit the longest
        draft prefix the target's argmax agrees with plus one corrected
        token, and roll back rejected-suffix lengths/pages.  Keeps the
        plain tick's contracts: one decode dispatch, host-authoritative
        state pushed per dispatch, stall/preempt/guard semantics."""
        t0 = self._clock() if self.obs is not None else 0.0
        ready: list[int] = []
        ks = self._spec_budget(ready, active)
        self._note_occupancy()
        if not ready:
            self._preempt_youngest(active)
            return 0
        # -- draft phase: k_max+1 batched (max_slots, 1) dense dispatches.
        # Dispatch j consumes the previous token and WRITES its KV, so
        # the (k_max+1)-th writes the deepest draft's KV — on full
        # acceptance the draft cache is exactly in sync at the new
        # length and the next tick drafts with no re-prefill.
        kbig = max(ks.values())
        drafts: dict[int, list[int]] = {i: [] for i in ready}
        if kbig > 0:
            for i in ready:
                if ks[i] > 0 and self._draft_len[i] != self._len[i]:
                    self._draft_sync(i)
            last = np.zeros((self.max_slots, 1), np.int32)
            for i in ready:
                last[i, 0] = self.slots[i].out_tokens[-1]
            dlen = np.array(self._draft_len)
            for j in range(kbig + 1):
                cache = dataclasses.replace(self._draft_cache,
                                            length=jnp.asarray(dlen))
                logits, self._draft_cache = self._draft_decode(
                    self.draft_params, jnp.asarray(last), cache)
                self._metrics.counter("spec.draft_dispatches").inc()
                if j == kbig:
                    break               # KV-write-only: logits discarded
                toks = np.asarray(jnp.argmax(logits[:, -1], -1))
                for i in ready:
                    if len(drafts[i]) < ks[i]:
                        drafts[i].append(int(toks[i]))
                        last[i, 0] = int(toks[i])
                dlen += 1
        # -- verify phase: ONE (max_slots, spec_k+1) ragged dispatch.
        # Row i scores [out[-1], d_1 .. d_k_i] at start _len[i]; stalled
        # and empty rows ride as sentinels (slot id max_slots → writes
        # drop), exactly like batched-prefill padding rows.
        W = self.config.spec_k + 1
        toks = np.zeros((self.max_slots, W), np.int32)
        lens = np.zeros((self.max_slots,), np.int32)
        starts = np.zeros((self.max_slots,), np.int32)
        rows = np.full((self.max_slots,), self.max_slots, np.int32)
        temps = np.zeros((self.max_slots,), np.float32)
        uids = np.zeros((self.max_slots,), np.int32)
        for i in ready:
            req = self.slots[i]
            toks[i, 0] = req.out_tokens[-1]
            toks[i, 1:1 + ks[i]] = drafts[i]
            lens[i] = 1 + ks[i]
            starts[i] = self._len[i]
            rows[i] = i
            temps[i] = req.temperature
            uids[i] = req.uid
        t_alloc = self._clock() if self.obs is not None else 0.0
        toks_j, lens_j = jnp.asarray(toks), jnp.asarray(lens)
        starts_j, rows_j = jnp.asarray(starts), jnp.asarray(rows)
        # the verify jit DONATES the pool (like the prefill paths): each
        # closure materializes its own host-state pytree so a breaker
        # retry never touches consumed buffers
        (logits, self.cache), used = self._dispatch_guarded(
            "decode",
            lambda: self._verify(self.params, toks_j, lens_j, starts_j,
                                 self._host_state_cache(), rows_j),
            None if self._verify_fb is None else
            (lambda: self._verify_fb(self.params, toks_j, lens_j, starts_j,
                                     self._host_state_cache(), rows_j)))
        self._c_decode.inc()
        self._c_ticks.inc()
        self._attr_decode_dispatch(self.max_slots, used)
        self._metrics.counter(
            f"dispatch.paged_attention.{self.paged_attention_backend}").inc()
        self._metrics.counter("spec.verify_dispatches").inc()
        if self._faults is not None:
            logits = self._poison_logits(logits, ready)
        failed = []
        if self._nan_guard:
            # the guard spans each row's VALID positions (the plain
            # tick's last-position check would read verify padding)
            lg = np.asarray(logits, np.float32)
            failed = [i for i in ready
                      if not np.isfinite(lg[i, :int(lens[i])]).all()]
        greedy = np.asarray(jnp.argmax(logits, -1))
        samp = (np.asarray(self._sample_batch(logits[:, 0], temps, uids))
                if (temps > 0).any() else None)
        now = 0.0
        if self.obs is not None:
            now = self._clock()
            self._metrics.histogram("engine.tick_s").observe(now - t0)
            self._tracer.emit("tick", ts=now, tick=self.ticks,
                              n_active=len(ready),
                              uids=[self.slots[i].uid for i in ready
                                    if i not in failed],
                              n_stalled=len(active) - len(ready),
                              dur_s=now - t0, alloc_dur_s=t_alloc - t0)
        n_drafted = n_accepted = n_emitted_total = 0
        for i in ready:
            req = self.slots[i]
            if i in failed:
                self._fail_slot(i)
                continue
            if temps[i] > 0:
                emit = [int(samp[i])]
            else:
                emit = cm.spec_accept_greedy(drafts[i], greedy[i])
            n_drafted += ks[i]
            n_accepted += len(emit) - 1
            new_len, done = int(self._len[i]), False
            for n, tok in enumerate(emit):
                if n > 0 and self.obs is not None:
                    # each accepted token past the tick's first gets its
                    # own trace event, so per-uid token chains and
                    # trace-derived decode_tokens count ACCEPTED tokens
                    self._tracer.emit("token", ts=now, uid=req.uid)
                self._append_token(req, tok)
                new_len += 1
                n_emitted_total += 1
                if self._finished(req, tok):
                    done = True
                    break
            if done:
                self._retire(req)
                self._release_slot(i)
                continue
            self._len[i] = new_len
            # rejected-suffix rollback: pages past the accepted length
            # were allocated for verify writes that are now invalid —
            # return them (valid-prefix pages, including every
            # prefix-shared page, always sit below this range)
            for pi in range((new_len - 1) // self.page_size + 1,
                            self.table_width):
                p = int(self._pt[i, pi])
                if p < 0:
                    break
                self._decref(p)
                self._pt[i, pi] = -1
            if ks[i] > 0:
                self._draft_len[i] = new_len
        self._metrics.counter("spec.drafted").inc(n_drafted)
        self._metrics.counter("spec.accepted").inc(n_accepted)
        self._metrics.counter("spec.rejected").inc(n_drafted - n_accepted)
        self._metrics.counter("spec.emitted_tokens").inc(n_emitted_total)
        if self.obs is not None:
            self._tracer.emit("spec", ts=self._clock(), tick=self.ticks,
                              drafted=n_drafted, accepted=n_accepted,
                              rejected=n_drafted - n_accepted,
                              emitted=n_emitted_total,
                              n_rows=len(ready))
        self._maybe_quant_health()
        return len(ready)


class PerSlotServingEngine(_EngineBase):
    """The original per-slot loop: one (1, 1) decode dispatch per active
    slot per tick.  Kept as the equivalence oracle and the throughput
    baseline (benchmarks/serving_throughput.py); the batched engine must
    match its greedy tokens exactly."""

    def _init_caches(self):
        self.caches = [self.model.make_cache(self.cfg, 1, self.max_len,
                                             bits=self.kv_bits)
                       for _ in range(self.max_slots)]

    def _install_slot_cache(self, slot: int, cache):
        self.caches[slot] = cache

    def step(self) -> int:
        if self._faults is not None:
            self._fire_slow_tick()
        self._admit()
        self._step += 1
        active = 0
        t0 = self._clock() if self.obs is not None else 0.0
        uids = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            active += 1
            tok = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            (logits, self.caches[i]), used = self._dispatch_guarded(
                "decode",
                lambda t=tok, c=self.caches[i]: self._decode(self.params,
                                                             t, c),
                None if self._decode_fb is None else
                (lambda t=tok, c=self.caches[i]: self._decode_fb(
                    self.params, t, c)))
            self._c_decode.inc()
            self._attr_decode_dispatch(1, used)
            if (self._faults is not None
                    and self._fire("nan_logits", uid=req.uid,
                                   tick=self._step)):
                logits = logits.at[0].set(jnp.nan)
            if self._nan_guard and not np.isfinite(
                    np.asarray(logits[:, -1], np.float32)).all():
                self._fail_slot(i)
                continue
            uids.append(req.uid)
            nxt = int(_sample_one(logits[:, -1], req.temperature, self._step,
                                  req.uid)[0])
            self._append_token(req, nxt)
            if self._finished(req, nxt):
                self._retire(req)
                self.slots[i] = None
        if active:
            self._c_ticks.inc()
            if self.obs is not None:
                now = self._clock()
                self._metrics.histogram("engine.tick_s").observe(now - t0)
                self._tracer.emit("tick", ts=now, tick=self.ticks,
                                  n_active=active, uids=uids, dur_s=now - t0)
            self._maybe_quant_health()
        return active
