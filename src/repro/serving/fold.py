"""Offline calibrate→fold→quantize pipeline (the paper as a deployment step).

Turns bf16 training params into a serving param tree where every linear
leaf is a folded, RTN-quantized :class:`QuantizedWeight`:

    smooth       : W ← diag(s)·W   (runtime divides x by s;  Eq. 4)
    rotate       : W ← Rᵀ·W        (runtime applies x·R online — fast
                                    Kronecker apply / fused Pallas kernel)
    smooth_rotate: both, scaling FIRST (the paper's hybrid, §IV-E)

The per-module policy is a :class:`repro.core.transforms.TransformPlan`;
the default follows the paper's §V recommendation (SmoothRotation on
down_proj-type inputs, rotation elsewhere).  Calibration stats come from
``collect_calibration`` (a with-taps forward over a calibration stream).

MoE experts are quantized per-expert (storage savings); their ragged
compute path dequantizes to bf16 before the grouped einsum — dense
linears use the full int8-MXU path (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.calibration import CalibStats, smoothing_scales_from_stats, update_stats
from repro.core.hadamard import apply_hadamard
from repro.core.qlinear import QuantPolicy, QuantizedWeight, quantize_weight
from repro.core.transforms import TransformKind, TransformPlan

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# calibration driver
# ---------------------------------------------------------------------------


def collect_calibration(model, params, cfg: ModelConfig, batches) -> dict[str, CalibStats]:
    """Run the model's with-taps forward over calibration batches and
    accumulate per-module per-channel absmax (taps stacked over layers)."""
    tap_fn = jax.jit(
        lambda toks=None, embeds=None: model.forward_with_taps(
            params, cfg, toks, embeds=embeds)[1])
    stats: dict[str, CalibStats] | None = None
    for batch in batches:
        taps = tap_fn(batch.get("tokens"), batch.get("embeds"))
        stats = update_stats(stats, taps)
    if stats is None:
        raise ValueError("empty calibration stream")
    return stats


# ---------------------------------------------------------------------------
# single-linear fold
# ---------------------------------------------------------------------------


def _fold_one(w: jax.Array, kind: TransformKind, act_absmax: jax.Array | None,
              *, alpha: float, policy: QuantPolicy) -> QuantizedWeight:
    """w: (c_in, c_out). act_absmax: (c_in,) or None."""
    w = w.astype(jnp.float32)
    s = None
    if kind in ("smooth", "smooth_rotate"):
        if act_absmax is None:
            raise ValueError(f"'{kind}' needs calibration stats")
        s = smoothing_scales_from_stats(act_absmax, w, alpha)
        w = w * s[:, None]
    had = 0
    if kind in ("rotate", "smooth_rotate"):
        w = apply_hadamard(w, axis=0)
        had = w.shape[0]
    return quantize_weight(w, bits=policy.weight_bits,
                           pack=policy.pack_weights, had_dim=had, smooth=s)


def _fold_stacked(w: jax.Array, kind: TransformKind,
                  act_absmax: jax.Array | None, *, alpha: float,
                  policy: QuantPolicy, bias: jax.Array | None = None) -> Params:
    """Fold a (L, c_in, c_out) or (L, E, c_in, c_out) stacked linear.
    Returns the params leaf {"qw": QuantizedWeight[, "b": bias]}."""
    fn = functools.partial(_fold_one, kind=kind, alpha=alpha, policy=policy)
    n_lead = w.ndim - 2
    for _ in range(n_lead):
        fn = jax.vmap(fn)
    if act_absmax is None:
        qw = fn(w, act_absmax=None) if n_lead == 0 else _vmap_nostat(
            w, kind, alpha, policy, n_lead)
    else:
        am = act_absmax
        # broadcast stats over expert axis if weights have one more lead dim
        while am.ndim < n_lead + 1:
            am = jnp.broadcast_to(am[..., None, :],
                                  (*am.shape[:-1], w.shape[am.ndim - 1], am.shape[-1]))
        qw = fn(w, act_absmax=am)
    out: Params = {"qw": qw}
    if bias is not None:
        out["b"] = bias
    return out


def _vmap_nostat(w, kind, alpha, policy, n_lead):
    fn = functools.partial(_fold_one, kind=kind, act_absmax=None, alpha=alpha,
                           policy=policy)
    for _ in range(n_lead):
        fn = jax.vmap(fn)
    return fn(w)


def _stat(stats: dict[str, CalibStats] | None, name: str):
    if stats is None or name not in stats:
        return None
    return stats[name].act_absmax


def _need_stats(kind: TransformKind) -> bool:
    return kind in ("smooth", "smooth_rotate")


def _effective(kind: TransformKind, stat) -> TransformKind:
    """Degrade smooth→rotate when stats are unavailable (logged policy)."""
    if _need_stats(kind) and stat is None:
        return "rotate" if "rotate" in kind else "none"
    return kind


def _fold_linear_leaf(leaf: Params, kind: TransformKind, stat, *, alpha,
                      policy) -> Params:
    kind = _effective(kind, stat)
    return _fold_stacked(leaf["w"], kind, stat, alpha=alpha, policy=policy,
                         bias=leaf.get("b"))


# ---------------------------------------------------------------------------
# per-family folds
# ---------------------------------------------------------------------------


def _fold_attn(attn: Params, stats, plan: TransformPlan, policy: QuantPolicy) -> Params:
    f = functools.partial(_fold_linear_leaf, alpha=plan.alpha, policy=policy)
    return {
        "wq": f(attn["wq"], plan.attn_in, _stat(stats, "k_proj")),
        "wk": f(attn["wk"], plan.attn_in, _stat(stats, "k_proj")),
        "wv": f(attn["wv"], plan.attn_in, _stat(stats, "k_proj")),
        "wo": f(attn["wo"], plan.attn_out, _stat(stats, "o_proj")),
        "ln": attn["ln"],
    }


def _fold_mla(attn: Params, stats, plan: TransformPlan, policy: QuantPolicy) -> Params:
    f = functools.partial(_fold_linear_leaf, alpha=plan.alpha, policy=policy)
    return {
        "wq": f(attn["wq"], plan.attn_in, _stat(stats, "k_proj")),
        "wdkv": f(attn["wdkv"], plan.attn_in, _stat(stats, "k_proj")),
        "wukv": f(attn["wukv"], plan.attn_in, _stat(stats, "kv_up")),
        "wo": f(attn["wo"], plan.attn_out, _stat(stats, "o_proj")),
        "ln": attn["ln"], "kv_ln": attn["kv_ln"],
    }


def _fold_mlp(mlp: Params, stats, plan: TransformPlan, policy: QuantPolicy,
              *, tap_prefix: str = "") -> Params:
    f = functools.partial(_fold_linear_leaf, alpha=plan.alpha, policy=policy)
    out = {
        "wg": f(mlp["wg"], plan.mlp_in, _stat(stats, tap_prefix + "gate_proj")),
        "wu": f(mlp["wu"], plan.mlp_in, _stat(stats, tap_prefix + "gate_proj")),
        "wd": f(mlp["wd"], plan.mlp_out, _stat(stats, tap_prefix + "down_proj")),
    }
    if "ln" in mlp:
        out["ln"] = mlp["ln"]
    return out


def _fold_moe_ffn(moe: Params, stats, plan: TransformPlan, policy: QuantPolicy,
                  cfg: ModelConfig) -> Params:
    """Experts: per-expert quantization; gate/up get the block input stats
    (routed subsets share the block input → absmax is an upper bound);
    expert down_proj has no per-expert calibration stream → rotation
    (DESIGN.md §5).  Router stays f32 (it is tiny and precision-critical)."""
    f = functools.partial(_fold_stacked, alpha=plan.alpha, policy=policy)
    # experts never get runtime smoothing (per-expert division is not in
    # the dispatch path; DESIGN.md §5) — rotation-only there:
    e_kind: TransformKind = "rotate" if "rotate" in plan.mlp_in else "none"
    out = {
        "router": moe["router"],
        "wg": {"qw": f(moe["wg"], e_kind, None)["qw"]},
        "wu": {"qw": f(moe["wu"], e_kind, None)["qw"]},
        "wd": {"qw": f(moe["wd"], "rotate", None)["qw"]},
        "ln": moe["ln"],
    }
    if "shared" in moe:
        # shared experts share the block-input tap for gate/up, but their
        # internal width (n_shared·f) has no calibrated stream → the down
        # projection degrades to rotation (stats=None)
        out["shared"] = _fold_mlp(moe["shared"], None, plan, policy)
    if "dense" in moe:  # Arctic parallel-dense FFN: width == d_ff, taps ok
        out["dense"] = _fold_mlp(moe["dense"], stats, plan, policy)
    return out


def _fold_mamba(layer: Params, stats, plan: TransformPlan, policy: QuantPolicy) -> Params:
    f = functools.partial(_fold_linear_leaf, alpha=plan.alpha, policy=policy)
    out = dict(layer)
    out["in_proj"] = f(layer["in_proj"], plan.mlp_in, _stat(stats, "in_proj"))
    out["out_proj"] = f(layer["out_proj"], plan.mlp_out, _stat(stats, "out_proj"))
    return out


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def fold_quantize(params: Params, cfg: ModelConfig, *,
                  policy: QuantPolicy = QuantPolicy(),
                  plan: TransformPlan = TransformPlan(),
                  stats: dict[str, CalibStats] | None = None) -> Params:
    """bf16 params → serving params (quantized linears, rest untouched)."""
    out: Params = {"embed": params["embed"], "final_ln": params["final_ln"]}
    if policy.quantize_lm_head:
        out["lm_head"] = _fold_linear_leaf(
            params["lm_head"], "rotate", None, alpha=plan.alpha, policy=policy)
    else:
        out["lm_head"] = params["lm_head"]

    if cfg.family in ("dense", "audio", "vlm"):
        out["layers"] = {
            "attn": _fold_attn(params["layers"]["attn"], stats, plan, policy),
            "mlp": _fold_mlp(params["layers"]["mlp"], stats, plan, policy),
        }
    elif cfg.family == "moe":
        attn_fold = _fold_mla if cfg.kv_lora_rank else _fold_attn
        out["moe_layers"] = {
            "attn": attn_fold(params["moe_layers"]["attn"], stats, plan, policy),
            "moe": _fold_moe_ffn(params["moe_layers"]["moe"], stats, plan,
                                 policy, cfg),
        }
        if "dense_layers" in params:
            # leading dense layers calibrated by the moe-layer taps (same
            # module classes); reuse those stats conservatively
            out["dense_layers"] = {
                "attn": attn_fold(params["dense_layers"]["attn"], _first_layer(stats),
                                  plan, policy),
                "mlp": _fold_mlp(params["dense_layers"]["mlp"], _first_layer(stats),
                                 plan, policy),
            }
    elif cfg.family == "ssm":
        out["layers"] = _fold_mamba(params["layers"], stats, plan, policy)
    elif cfg.family == "hybrid":
        out["layers"] = _fold_mamba(params["layers"], stats, plan, policy)
        out["shared"] = {
            "attn": _fold_attn(params["shared"]["attn"], None, plan, policy),
            "mlp": _fold_mlp(params["shared"]["mlp"], None, plan, policy),
        }
    else:
        raise ValueError(cfg.family)
    return out


def _first_layer(stats):
    """Slice layer-stacked stats down to a single (broadcastable) layer."""
    if stats is None:
        return None
    return {k: dataclasses.replace(v, act_absmax=v.act_absmax[:1])
            for k, v in stats.items()}
