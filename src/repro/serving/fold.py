"""Offline calibrate→fold→quantize pipeline (the paper as a deployment step).

Turns bf16 training params into a serving param tree where every linear
leaf is a folded, RTN-quantized :class:`QuantizedWeight`:

    smooth       : W ← diag(s)·W   (runtime divides x by s;  Eq. 4)
    rotate       : W ← Rᵀ·W        (runtime applies x·R online — fast
                                    Kronecker apply / fused Pallas kernel)
    smooth_rotate: both, scaling FIRST (the paper's hybrid, §IV-E)

    The runtime side of every folded leaf is the ONE-pass fused qlinear
    kernel (docs/kernels.md); mixed layerwise stacks emit a traced
    ``had_mask`` gate that the kernel multiplexes in-VMEM, so searched
    plans stay on the fast path.  The folded tree serves unchanged from
    every engine — including the paged engine's batched
    ``prefill_paged`` dispatch and its int8 paged KV pool
    (docs/serving.md): quantization state lives entirely in the leaves,
    never in the cache layout.

The per-module policy is a :class:`repro.core.transforms.TransformPlan`;
the default follows the paper's §V recommendation (SmoothRotation on
down_proj-type inputs, rotation elsewhere).  Calibration stats come from
``collect_calibration`` (a with-taps forward over a calibration stream).

MoE experts are quantized per-expert (storage savings); their ragged
compute path dequantizes to bf16 before the grouped einsum — dense
linears use the full int8-MXU path (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.autoplan.plan import LayerwisePlan, ModuleChoice
from repro.configs.base import ModelConfig
from repro.core.calibration import (
    CalibStats,
    collect_stats,
    smoothing_scales_from_stats,
)
from repro.core.hadamard import apply_hadamard
from repro.core.qlinear import QuantPolicy, QuantizedWeight, quantize_weight
from repro.core.transforms import TransformKind, TransformPlan

Params = dict[str, Any]
PlanLike = Union[TransformPlan, LayerwisePlan]


# ---------------------------------------------------------------------------
# calibration driver
# ---------------------------------------------------------------------------


def collect_calibration(model, params, cfg: ModelConfig, batches,
                        keep_samples: int = 0) -> dict[str, CalibStats]:
    """Run the model's with-taps forward over calibration batches and
    accumulate per-module per-channel absmax (taps stacked over layers).

    ``keep_samples > 0`` also retains that many raw activation tokens per
    module per layer (CalibStats.act_samples) for the autoplan search.
    """
    tap_fn = jax.jit(
        lambda toks=None, embeds=None: model.forward_with_taps(
            params, cfg, toks, embeds=embeds)[1])
    return collect_stats(
        lambda batch: tap_fn(batch.get("tokens"), batch.get("embeds")),
        batches, keep_samples)


# ---------------------------------------------------------------------------
# single-linear fold
# ---------------------------------------------------------------------------


def _fold_one(w: jax.Array, kind: TransformKind, act_absmax: jax.Array | None,
              *, alpha: float, policy: QuantPolicy) -> QuantizedWeight:
    """w: (c_in, c_out). act_absmax: (c_in,) or None."""
    w = w.astype(jnp.float32)
    s = None
    if kind in ("smooth", "smooth_rotate"):
        if act_absmax is None:
            raise ValueError(f"'{kind}' needs calibration stats")
        s = smoothing_scales_from_stats(act_absmax, w, alpha)
        w = w * s[:, None]
    had = 0
    if kind in ("rotate", "smooth_rotate"):
        w = apply_hadamard(w, axis=0)
        had = w.shape[0]
    return quantize_weight(w, bits=policy.weight_bits,
                           pack=policy.pack_weights, had_dim=had, smooth=s)


def _fold_stacked(w: jax.Array, kind: TransformKind,
                  act_absmax: jax.Array | None, *, alpha: float,
                  policy: QuantPolicy, bias: jax.Array | None = None) -> Params:
    """Fold a (L, c_in, c_out) or (L, E, c_in, c_out) stacked linear.
    Returns the params leaf {"qw": QuantizedWeight[, "b": bias]}."""
    fn = functools.partial(_fold_one, kind=kind, alpha=alpha, policy=policy)
    n_lead = w.ndim - 2
    for _ in range(n_lead):
        fn = jax.vmap(fn)
    if act_absmax is None:
        qw = fn(w, act_absmax=None) if n_lead == 0 else _vmap_nostat(
            w, kind, alpha, policy, n_lead)
    else:
        am = act_absmax
        # broadcast stats over expert axis if weights have one more lead dim
        while am.ndim < n_lead + 1:
            am = jnp.broadcast_to(am[..., None, :],
                                  (*am.shape[:-1], w.shape[am.ndim - 1], am.shape[-1]))
        qw = fn(w, act_absmax=am)
    out: Params = {"qw": qw}
    if bias is not None:
        out["b"] = bias
    return out


def _vmap_nostat(w, kind, alpha, policy, n_lead):
    fn = functools.partial(_fold_one, kind=kind, act_absmax=None, alpha=alpha,
                           policy=policy)
    for _ in range(n_lead):
        fn = jax.vmap(fn)
    return fn(w)


def _stat(stats: dict[str, CalibStats] | None, name: str):
    if stats is None or name not in stats:
        return None
    return stats[name].act_absmax


def _need_stats(kind: TransformKind) -> bool:
    return kind in ("smooth", "smooth_rotate")


def _effective(kind: TransformKind, stat) -> TransformKind:
    """Degrade smooth→rotate when stats are unavailable (logged policy)."""
    if _need_stats(kind) and stat is None:
        return "rotate" if "rotate" in kind else "none"
    return kind


# ---------------------------------------------------------------------------
# plan resolution (global TransformPlan | per-layer LayerwisePlan)
# ---------------------------------------------------------------------------


def _base_plan(plan: PlanLike) -> TransformPlan:
    return plan.base if isinstance(plan, LayerwisePlan) else plan


def _resolve(plan: PlanLike, module: str, w: jax.Array):
    """(kind, alpha) for a uniform fold, or per-layer ModuleChoices when
    the plan is layer-dependent AND matches this stack's layer count.

    Mismatched stacks (MoE leading dense layers, hybrid shared blocks,
    unstacked linears) fall back to the plan's global base — the same
    conservative reuse the uniform path always applied.
    """
    if isinstance(plan, LayerwisePlan):
        choices = plan.modules.get(module)
        if (choices is not None and w.ndim >= 3
                and w.shape[0] == len(choices)):
            if len(set(choices)) > 1:
                return tuple(choices)
            return choices[0].kind, choices[0].alpha
        base = plan.base
        return base.kind_for(module), base.alpha
    return plan.kind_for(module), plan.alpha


def _fold_stacked_layerwise(w: jax.Array, choices: tuple[ModuleChoice, ...],
                            act_absmax: jax.Array | None, *,
                            policy: QuantPolicy,
                            bias: jax.Array | None = None) -> Params:
    """Mixed per-layer kinds/αs on a (L, c_in, c_out) stack.

    The scan shares ONE QuantizedWeight structure across layers, so the
    static metadata must be uniform: rotated and un-rotated layers
    coexist through the traced ``had_mask`` gate, and smoothing uses
    identity scales on layers that don't smooth.  Layers are grouped by
    (kind, α) and each group folds through the same vmapped math as the
    uniform path.
    """
    if w.ndim != 3:
        raise ValueError("layerwise fold expects a (L, c_in, c_out) stack")
    L, c_in, _ = w.shape
    wf = w.astype(jnp.float32)
    smooth = jnp.ones((L, c_in), jnp.float32)
    rot = np.zeros(L, bool)
    any_smooth = False

    groups: dict[tuple[TransformKind, float], list[int]] = {}
    for l, c in enumerate(choices):
        eff = _effective(c.kind, act_absmax)
        groups.setdefault((eff, c.alpha), []).append(l)

    for (kind, alpha), idx in groups.items():
        ia = jnp.asarray(idx)
        wi = wf[ia]
        if kind in ("smooth", "smooth_rotate"):
            am = (act_absmax[ia] if act_absmax.ndim == 2
                  else jnp.broadcast_to(act_absmax, (len(idx), c_in)))
            s = smoothing_scales_from_stats(am, wi, alpha)
            wi = wi * s[..., None]
            smooth = smooth.at[ia].set(s)
            any_smooth = True
        if kind in ("rotate", "smooth_rotate"):
            wi = apply_hadamard(wi, axis=-2)
            rot[idx] = True
        wf = wf.at[ia].set(wi)

    had_dim = c_in if rot.any() else 0
    q = functools.partial(quantize_weight, bits=policy.weight_bits,
                          pack=policy.pack_weights, had_dim=had_dim)
    if any_smooth:
        qw = jax.vmap(lambda ww, ss: q(ww, smooth=ss))(wf, smooth)
    else:
        qw = jax.vmap(lambda ww: q(ww))(wf)
    if had_dim and not rot.all():
        qw = dataclasses.replace(qw, had_mask=jnp.asarray(rot, jnp.float32))
    out: Params = {"qw": qw}
    if bias is not None:
        out["b"] = bias
    return out


def _fold_linear_leaf(leaf: Params, plan: PlanLike, module: str, stat, *,
                      policy) -> Params:
    spec = _resolve(plan, module, leaf["w"])
    if isinstance(spec[0], str):           # uniform (kind, alpha)
        kind, alpha = spec
        kind = _effective(kind, stat)
        return _fold_stacked(leaf["w"], kind, stat, alpha=alpha,
                             policy=policy, bias=leaf.get("b"))
    return _fold_stacked_layerwise(leaf["w"], spec, stat, policy=policy,
                                   bias=leaf.get("b"))


def _fold_experts_rotation(w: jax.Array, rot: np.ndarray, *,
                           policy: QuantPolicy) -> Params:
    """Per-layer rotate/none on an (L, E, c_in, c_out) expert stack.

    Experts never smooth (per-expert division is not in the dispatch
    path; DESIGN.md §5), so a layerwise plan reduces to a per-layer
    rotation choice — realized with the same had_mask gate the dense
    layerwise fold uses (moe dispatch rotates the block input once and
    selects per layer)."""
    wf = w.astype(jnp.float32)
    if rot.any():
        ia = jnp.asarray(np.nonzero(rot)[0])
        wf = wf.at[ia].set(apply_hadamard(wf[ia], axis=-2))
    had_dim = w.shape[-2] if rot.any() else 0
    q = functools.partial(quantize_weight, bits=policy.weight_bits,
                          pack=policy.pack_weights, had_dim=had_dim)
    qw = jax.vmap(jax.vmap(q))(wf)
    if had_dim and not rot.all():
        qw = dataclasses.replace(qw, had_mask=jnp.asarray(rot, jnp.float32))
    return {"qw": qw}


# ---------------------------------------------------------------------------
# per-family folds
# ---------------------------------------------------------------------------


def _fold_attn(attn: Params, stats, plan: PlanLike, policy: QuantPolicy) -> Params:
    f = functools.partial(_fold_linear_leaf, policy=policy)
    return {
        "wq": f(attn["wq"], plan, "k_proj", _stat(stats, "k_proj")),
        "wk": f(attn["wk"], plan, "k_proj", _stat(stats, "k_proj")),
        "wv": f(attn["wv"], plan, "k_proj", _stat(stats, "k_proj")),
        "wo": f(attn["wo"], plan, "o_proj", _stat(stats, "o_proj")),
        "ln": attn["ln"],
    }


def _fold_mla(attn: Params, stats, plan: PlanLike, policy: QuantPolicy) -> Params:
    f = functools.partial(_fold_linear_leaf, policy=policy)
    return {
        "wq": f(attn["wq"], plan, "k_proj", _stat(stats, "k_proj")),
        "wdkv": f(attn["wdkv"], plan, "k_proj", _stat(stats, "k_proj")),
        "wukv": f(attn["wukv"], plan, "kv_up", _stat(stats, "kv_up")),
        "wo": f(attn["wo"], plan, "o_proj", _stat(stats, "o_proj")),
        "ln": attn["ln"], "kv_ln": attn["kv_ln"],
    }


def _fold_mlp(mlp: Params, stats, plan: PlanLike, policy: QuantPolicy,
              *, tap_prefix: str = "") -> Params:
    f = functools.partial(_fold_linear_leaf, policy=policy)
    out = {
        "wg": f(mlp["wg"], plan, "gate_proj",
                _stat(stats, tap_prefix + "gate_proj")),
        "wu": f(mlp["wu"], plan, "gate_proj",
                _stat(stats, tap_prefix + "gate_proj")),
        "wd": f(mlp["wd"], plan, "down_proj",
                _stat(stats, tap_prefix + "down_proj")),
    }
    if "ln" in mlp:
        out["ln"] = mlp["ln"]
    return out


def _fold_moe_ffn(moe: Params, stats, plan: PlanLike, policy: QuantPolicy,
                  cfg: ModelConfig) -> Params:
    """Experts: per-expert quantization; gate/up get the block input stats
    (routed subsets share the block input → absmax is an upper bound);
    expert down_proj has no per-expert calibration stream → rotation
    (DESIGN.md §5).  Router stays f32 (it is tiny and precision-critical)."""
    gplan = _base_plan(plan)
    f = functools.partial(_fold_stacked, alpha=gplan.alpha, policy=policy)
    # experts never get runtime smoothing (per-expert division is not in
    # the dispatch path; DESIGN.md §5) — per-layer rotation only:
    spec = _resolve(plan, "gate_proj", moe["wg"])
    if isinstance(spec[0], str):           # uniform
        rot = np.full(moe["wg"].shape[0], "rotate" in spec[0])
    else:                                  # layerwise gate_proj choices
        rot = np.asarray(["rotate" in c.kind for c in spec])
    out = {
        "router": moe["router"],
        "wg": _fold_experts_rotation(moe["wg"], rot, policy=policy),
        "wu": _fold_experts_rotation(moe["wu"], rot, policy=policy),
        "wd": {"qw": f(moe["wd"], "rotate", None)["qw"]},
        "ln": moe["ln"],
    }
    if "shared" in moe:
        # shared experts share the block-input tap for gate/up, but their
        # internal width (n_shared·f) has no calibrated stream → the down
        # projection degrades to rotation (stats=None)
        out["shared"] = _fold_mlp(moe["shared"], None, plan, policy)
    if "dense" in moe:  # Arctic parallel-dense FFN: width == d_ff, taps ok
        out["dense"] = _fold_mlp(moe["dense"], stats, plan, policy)
    return out


def _fold_mamba(layer: Params, stats, plan: PlanLike, policy: QuantPolicy) -> Params:
    f = functools.partial(_fold_linear_leaf, policy=policy)
    out = dict(layer)
    out["in_proj"] = f(layer["in_proj"], plan, "in_proj",
                       _stat(stats, "in_proj"))
    out["out_proj"] = f(layer["out_proj"], plan, "out_proj",
                        _stat(stats, "out_proj"))
    return out


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def fold_quantize(params: Params, cfg: ModelConfig, *,
                  policy: QuantPolicy = QuantPolicy(),
                  plan: PlanLike = TransformPlan(),
                  stats: dict[str, CalibStats] | None = None) -> Params:
    """bf16 params → serving params (quantized linears, rest untouched).

    ``plan`` is either the legacy global :class:`TransformPlan` or a
    per-layer :class:`repro.autoplan.plan.LayerwisePlan`; a uniform
    layerwise plan folds identically to its global equivalent.
    """
    out: Params = {"embed": params["embed"], "final_ln": params["final_ln"]}
    if policy.quantize_lm_head:
        out["lm_head"] = _fold_stacked(
            params["lm_head"]["w"], "rotate", None, alpha=_base_plan(plan).alpha,
            policy=policy, bias=params["lm_head"].get("b"))
    else:
        out["lm_head"] = params["lm_head"]

    if cfg.family in ("dense", "audio", "vlm"):
        out["layers"] = {
            "attn": _fold_attn(params["layers"]["attn"], stats, plan, policy),
            "mlp": _fold_mlp(params["layers"]["mlp"], stats, plan, policy),
        }
    elif cfg.family == "moe":
        attn_fold = _fold_mla if cfg.kv_lora_rank else _fold_attn
        out["moe_layers"] = {
            "attn": attn_fold(params["moe_layers"]["attn"], stats, plan, policy),
            "moe": _fold_moe_ffn(params["moe_layers"]["moe"], stats, plan,
                                 policy, cfg),
        }
        if "dense_layers" in params:
            # leading dense layers calibrated by the moe-layer taps (same
            # module classes); reuse those stats conservatively
            out["dense_layers"] = {
                "attn": attn_fold(params["dense_layers"]["attn"], _first_layer(stats),
                                  plan, policy),
                "mlp": _fold_mlp(params["dense_layers"]["mlp"], _first_layer(stats),
                                 plan, policy),
            }
    elif cfg.family == "ssm":
        out["layers"] = _fold_mamba(params["layers"], stats, plan, policy)
    elif cfg.family == "hybrid":
        out["layers"] = _fold_mamba(params["layers"], stats, plan, policy)
        out["shared"] = {
            "attn": _fold_attn(params["shared"]["attn"], None, plan, policy),
            "mlp": _fold_mlp(params["shared"]["mlp"], None, plan, policy),
        }
    else:
        raise ValueError(cfg.family)
    return out


def _first_layer(stats):
    """Slice layer-stacked stats down to a single (broadcastable) layer."""
    if stats is None:
        return None
    return {k: dataclasses.replace(
                v, act_absmax=v.act_absmax[:1],
                act_samples=None if v.act_samples is None
                else v.act_samples[:1])
            for k, v in stats.items()}
