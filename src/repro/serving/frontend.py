"""Async streaming HTTP front-end over the serving engines (stdlib only).

The request-facing surface the ROADMAP's serving item calls for: clients
POST a prompt and stream tokens back as they are sampled, with
per-request deadlines and admission control, while the engine keeps its
single-threaded batched tick.

Architecture — two threads, one owner each:

  * the **engine thread** owns ALL engine state.  It drains a control
    queue (submits, cancels) between ticks, drives ``step()`` +
    ``pop_retired()``, and parks on an event when idle.  Tokens leave
    through the engine's streaming hooks (``on_token``/``on_retire``),
    which forward to the asyncio loop via ``call_soon_threadsafe`` — the
    only cross-thread channel out.
  * the **asyncio loop** owns the sockets.  ``asyncio.start_server``
    accepts connections; HTTP/1.1 is hand-rolled (no new deps) and
    token streams go out as chunked transfer-encoded ndjson.

Protocol (docs/serving.md):

    POST /generate   {"prompt": [ints], "max_new_tokens": N,
                      "temperature": T, "deadline_s": D}
        → 200, one ndjson record per token {"token": t}, then a final
          {"done": true, "uid": u, "tokens": [...], "n_tokens": n,
           "expired": bool, "cancelled": bool}
        → 400 invalid body / over-capacity prompt
        → 503 admission control shed ({"error": "shed", ...})
    GET /healthz     → 200 {"ok": true}
    GET /stats       → 200 engine stats() + front-end counters

Admission control sheds BEFORE the engine sees the request: hard cap on
queue depth, plus a load score ``queue_depth × pool_occupancy`` (an
empty pool never sheds; a full pool sheds at shallow queues).  Deadlines
are enforced between streamed tokens: on expiry the front-end cancels
the request in the engine (slot + pages free at the next tick boundary),
emits a ``deadline`` trace event, and finishes the stream with
``expired: true`` — already-streamed tokens stand.

The engine emits the SAME trace-event schema as offline runs, so
``repro.obs.summarize``, ``python -m repro.obs`` and the BENCH latency
gate cover front-end traffic unchanged; shed/deadline events ride along
in the same JSONL.
"""

from __future__ import annotations

import asyncio
import collections
import json
import threading

import numpy as np

from repro.serving.engine import Request

__all__ = ["ServingFrontend", "http_generate", "http_get"]


def _json_bytes(obj) -> bytes:
    return (json.dumps(obj) + "\n").encode()


class ServingFrontend:
    """Asyncio HTTP server wrapping one engine behind submit → stream.

    ``await start()`` binds the socket and launches the engine thread;
    ``await stop()`` closes both.  Also usable as an async context
    manager.  ``port=0`` binds an ephemeral port (tests); the bound port
    is ``self.port`` after ``start()``.
    """

    def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 0,
                 max_queue_depth: int = 64, shed_score: float = 32.0,
                 default_deadline_s: float | None = None):
        self.engine = engine
        self.host, self.port = host, port
        self.max_queue_depth = max_queue_depth
        self.shed_score = shed_score
        self.default_deadline_s = default_deadline_s
        self.server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._streams: dict[int, asyncio.Queue] = {}
        self._control: collections.deque = collections.deque()
        self._work = threading.Event()
        self._stop_flag = threading.Event()
        self._thread: threading.Thread | None = None
        self._next_uid = 0
        self._uid_lock = threading.Lock()
        # front-end outcome counters (engine stats() covers the rest)
        self.accepted = 0
        self.shed = 0
        self.expired = 0

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "ServingFrontend":
        self._loop = asyncio.get_running_loop()
        eng = self.engine
        eng.on_token = self._on_token
        eng.on_retire = self._on_retire
        self._thread = threading.Thread(target=self._engine_loop,
                                        name="engine-loop", daemon=True)
        self._thread.start()
        self.server = await asyncio.start_server(self._serve_client,
                                                 self.host, self.port)
        self.port = self.server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self.server is not None:
            self.server.close()
            await self.server.wait_closed()
        self._stop_flag.set()
        self._work.set()
        if self._thread is not None:
            await asyncio.to_thread(self._thread.join)
        self.engine.on_token = None
        self.engine.on_retire = None

    async def __aenter__(self) -> "ServingFrontend":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- engine thread ------------------------------------------------------

    def _engine_loop(self) -> None:
        eng = self.engine
        while not self._stop_flag.is_set():
            while self._control:
                op, arg = self._control.popleft()
                if op == "submit":
                    eng.submit(arg)
                else:                            # "cancel"
                    eng.cancel(arg)
            if eng.queue or any(eng.slots):
                eng.step()
                eng.pop_retired()    # on_retire already forwarded them
            else:
                self._work.wait(timeout=0.05)
                self._work.clear()

    def _on_token(self, req, tok: int) -> None:
        """Engine-thread hook: forward one sampled token to its open
        stream (if the client is still connected)."""
        q = self._streams.get(req.uid)
        if q is not None:
            self._loop.call_soon_threadsafe(q.put_nowait, ("token", tok))

    def _on_retire(self, req) -> None:
        q = self._streams.get(req.uid)
        if q is not None:
            self._loop.call_soon_threadsafe(q.put_nowait, ("done", req))

    # -- admission control --------------------------------------------------

    def _occupancy(self) -> float:
        eng = self.engine
        n_pages = getattr(eng, "n_pages", 0)
        if n_pages:
            return eng.pages_in_use / n_pages
        busy = sum(r is not None for r in eng.slots)
        return busy / max(eng.max_slots, 1)

    def _shed_verdict(self) -> dict | None:
        """None to admit, else the shed record (trace event + 503 body).

        Depth counts engine-queued requests plus control-queue submits
        not yet applied; occupancy is page-pool (or slot) utilization.
        The product crossing ``shed_score`` sheds — load must be high on
        BOTH axes — and ``max_queue_depth`` is the hard cap."""
        depth = len(self.engine.queue) + sum(
            1 for op, _ in list(self._control) if op == "submit")
        occ = self._occupancy()
        score = depth * occ
        if depth >= self.max_queue_depth or score >= self.shed_score:
            return {"queue_depth": depth, "occupancy": occ, "score": score}
        return None

    # -- HTTP plumbing ------------------------------------------------------

    async def _serve_client(self, reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
        try:
            req_line = await reader.readline()
            if not req_line:
                return
            parts = req_line.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, path = parts[0], parts[1]
            headers = {}
            while True:
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                key, _, val = line.decode("latin-1").partition(":")
                headers[key.strip().lower()] = val.strip()
            body = b""
            n = int(headers.get("content-length", "0") or 0)
            if n:
                body = await reader.readexactly(n)
            if method == "POST" and path == "/generate":
                await self._handle_generate(body, writer)
            elif method == "GET" and path == "/healthz":
                self._respond(writer, 200, {"ok": True})
            elif method == "GET" and path == "/stats":
                self._respond(writer, 200, self._stats())
            else:
                self._respond(writer, 404, {"error": "not found"})
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()

    def _stats(self) -> dict:
        try:
            st = self.engine.stats()
        except RuntimeError:
            # stats() iterates live queue/slot state the engine thread
            # mutates; losing one poll to the race beats locking the tick
            st = {}
        st.pop("per_request", None)
        st["frontend"] = {"accepted": self.accepted, "shed": self.shed,
                          "expired": self.expired,
                          "open_streams": len(self._streams)}
        return st

    @staticmethod
    def _respond(writer: asyncio.StreamWriter, status: int, obj) -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  503: "Service Unavailable"}[status]
        body = _json_bytes(obj)
        writer.write(
            f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n".encode() + body)

    @staticmethod
    def _chunk(writer: asyncio.StreamWriter, data: bytes) -> None:
        writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")

    # -- the streaming endpoint --------------------------------------------

    async def _handle_generate(self, body: bytes,
                               writer: asyncio.StreamWriter) -> None:
        try:
            payload = json.loads(body.decode() or "{}")
            prompt = np.asarray(payload["prompt"], np.int64).reshape(-1)
        except (ValueError, KeyError, TypeError):
            self._respond(writer, 400, {"error": "invalid body"})
            return
        if len(prompt) == 0 or len(prompt) > self.engine.prompt_capacity:
            self._respond(writer, 400, {
                "error": "prompt length out of range",
                "capacity": self.engine.prompt_capacity})
            return
        verdict = self._shed_verdict()
        if verdict is not None:
            self.shed += 1
            if self.engine.obs is not None:
                self.engine.obs.tracer.emit("shed", **verdict)
            self._respond(writer, 503, {"error": "shed", **verdict})
            return
        with self._uid_lock:
            uid = self._next_uid
            self._next_uid += 1
        deadline_s = payload.get("deadline_s", self.default_deadline_s)
        req = Request(uid=uid, prompt=prompt,
                      max_new_tokens=int(payload.get("max_new_tokens", 32)),
                      temperature=float(payload.get("temperature", 0.0)))
        queue: asyncio.Queue = asyncio.Queue()
        self._streams[uid] = queue
        self.accepted += 1
        self._control.append(("submit", req))
        self._work.set()

        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Transfer-Encoding: chunked\r\n"
                     b"Connection: close\r\n\r\n")
        loop = asyncio.get_running_loop()
        deadline_at = (loop.time() + deadline_s
                       if deadline_s is not None else None)
        expired, n_streamed, final = False, 0, None
        try:
            while final is None:
                timeout = None
                if deadline_at is not None and not expired:
                    timeout = max(deadline_at - loop.time(), 0.0)
                try:
                    kind, val = await asyncio.wait_for(queue.get(), timeout)
                except asyncio.TimeoutError:
                    # deadline expired mid-stream: cancel in the engine
                    # and drain until the retire confirmation arrives
                    # (the engine may still race one more token out)
                    expired = True
                    self.expired += 1
                    if self.engine.obs is not None:
                        self.engine.obs.tracer.emit(
                            "deadline", uid=uid, deadline_s=deadline_s,
                            n_streamed=n_streamed)
                    self._control.append(("cancel", uid))
                    self._work.set()
                    continue
                if kind == "token":
                    n_streamed += 1
                    self._chunk(writer, _json_bytes({"token": int(val)}))
                    await writer.drain()
                else:
                    final = val
            self._chunk(writer, _json_bytes({
                "done": True, "uid": uid,
                "tokens": [int(t) for t in final.out_tokens],
                "n_tokens": len(final.out_tokens),
                "expired": expired, "cancelled": final.cancelled}))
            writer.write(b"0\r\n\r\n")
        finally:
            del self._streams[uid]


# ---------------------------------------------------------------------------
# minimal async client (tests, benchmarks/load_gen.py, serve.py self-drive)
# ---------------------------------------------------------------------------


async def http_generate(host: str, port: int, payload: dict,
                        clock=None) -> dict:
    """POST /generate and consume the token stream.

    Returns {"status", "body" (final record or error body), "tokens",
    "token_times" (client receive timestamp per token, from ``clock`` —
    default the running loop's clock)}.
    """
    clock = clock or asyncio.get_running_loop().time
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(payload).encode()
        writer.write(f"POST /generate HTTP/1.1\r\nHost: {host}\r\n"
                     f"Content-Type: application/json\r\n"
                     f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await writer.drain()
        status, headers = await _read_head(reader)
        tokens, times, final = [], [], None
        async for rec in _ndjson_records(reader, headers):
            if "token" in rec:
                tokens.append(rec["token"])
                times.append(clock())
            else:
                final = rec
        return {"status": status, "body": final, "tokens": tokens,
                "token_times": times}
    finally:
        writer.close()


async def http_get(host: str, port: int, path: str) -> dict:
    """GET a one-shot JSON endpoint (/healthz, /stats)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n".encode())
        await writer.drain()
        status, headers = await _read_head(reader)
        n = int(headers.get("content-length", "0") or 0)
        body = json.loads((await reader.readexactly(n)).decode() or "{}")
        return {"status": status, "body": body}
    finally:
        writer.close()


async def _read_head(reader: asyncio.StreamReader):
    status_line = await reader.readline()
    status = int(status_line.split()[1])
    headers = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        key, _, val = line.decode("latin-1").partition(":")
        headers[key.strip().lower()] = val.strip()
    return status, headers


async def _ndjson_records(reader: asyncio.StreamReader, headers: dict):
    """Yield ndjson records from a chunked or content-length body."""
    buf = b""
    if headers.get("transfer-encoding", "").lower() == "chunked":
        while True:
            size_line = await reader.readline()
            size = int(size_line.strip() or b"0", 16)
            if size == 0:
                await reader.readline()          # trailing CRLF
                break
            data = await reader.readexactly(size + 2)   # chunk + CRLF
            buf += data[:-2]
            while b"\n" in buf:
                line, buf = buf.split(b"\n", 1)
                if line.strip():
                    yield json.loads(line)
    else:
        n = int(headers.get("content-length", "0") or 0)
        for line in (await reader.readexactly(n)).splitlines():
            if line.strip():
                yield json.loads(line)
