"""Async streaming HTTP front-end over the serving engines (stdlib only).

The request-facing surface the ROADMAP's serving item calls for: clients
POST a prompt and stream tokens back as they are sampled, with
per-request deadlines and admission control, while the engine keeps its
single-threaded batched tick.

Architecture — two threads, one owner each:

  * the **engine thread** owns ALL engine state.  It drains a control
    queue (submits, cancels) between ticks, drives ``step()`` +
    ``pop_retired()``, and parks on an event when idle.  Tokens leave
    through the engine's streaming hooks (``on_token``/``on_retire``),
    which forward to the asyncio loop via ``call_soon_threadsafe`` — the
    only cross-thread channel out.
  * the **asyncio loop** owns the sockets.  ``asyncio.start_server``
    accepts connections; HTTP/1.1 is hand-rolled (no new deps) and
    token streams go out as chunked transfer-encoded ndjson.

Protocol, wire version 1 (docs/api.md has the full field-by-field
schema; versioning is additive — new fields may appear, existing ones
never change meaning, and ``WIRE_VERSION`` bumps only on a break):

    POST /generate   {"prompt": [ints], "max_new_tokens": N,
                      "temperature": T, "deadline_s": D}
        → 200, one ndjson record per token {"token": t}, then a final
          {"done": true, "uid": u, "tokens": [...], "n_tokens": n,
           "expired": bool, "cancelled": bool, "failed": bool}
        → 400 invalid body / over-capacity prompt / unknown request
          fields (named in the error, so client typos fail loudly
          instead of being silently ignored)
        → 503 admission control shed ({"error": "shed", ...})
    GET /healthz     → 200 {"v": 1, "ok": true, "state": "ok"|
                            "recovering"|"degraded", "restarts": n}
                       (503 once failed)
    GET /stats       → 200 {"v": 1, ...engine stats(), "frontend": {...}}

Admission control sheds BEFORE the engine sees the request: hard cap on
queue depth, plus a load score ``queue_depth × pool_occupancy`` (an
empty pool never sheds; a full pool sheds at shallow queues).  503 shed
responses carry a ``Retry-After`` header so well-behaved clients back
off instead of hammering (benchmarks/load_gen.py).  Deadlines are
enforced between streamed tokens: on expiry the front-end cancels the
request in the engine (slot + pages free at the next tick boundary),
emits a ``deadline`` trace event, and finishes the stream with
``expired: true`` — already-streamed tokens stand.  A client that
disconnects mid-stream is cancelled the same way (slot evicted, pages
freed) instead of decoding into a dead queue.

Fault tolerance (docs/resilience.md): the engine thread is supervised.
Any exception escaping the tick loop is reported to the event loop —
every open stream terminates with an ``error`` record instead of
hanging.  With an ``engine_factory`` the watchdog goes further: it
detects a dead OR stuck thread (heartbeat), rebuilds the engine, and
re-admits queued + in-flight requests through the engine's
``_resume_ctx`` machinery, so surviving streams continue token-exact;
``/healthz`` reports ``ok``/``recovering``/``degraded``/``failed``.

The engine emits the SAME trace-event schema as offline runs, so
``repro.obs.summarize``, ``python -m repro.obs`` and the BENCH latency
gate cover front-end traffic unchanged; shed/deadline events ride along
in the same JSONL.
"""

from __future__ import annotations

import asyncio
import collections
import json
import threading
import time

import numpy as np

from repro.resilience.faults import FaultPlan
from repro.serving.engine import Request

__all__ = ["ServingFrontend", "http_generate", "http_get", "WIRE_VERSION"]

# wire-contract version stamped into /stats and /healthz JSON; request
# fields outside GENERATE_FIELDS are a 400 (tests/test_frontend.py pins
# the schema so future fields stay additive)
WIRE_VERSION = 1
GENERATE_FIELDS = frozenset(
    {"prompt", "max_new_tokens", "temperature", "deadline_s"})


def _json_bytes(obj) -> bytes:
    return (json.dumps(obj) + "\n").encode()


class ServingFrontend:
    """Asyncio HTTP server wrapping one engine behind submit → stream.

    ``await start()`` binds the socket and launches the engine thread;
    ``await stop()`` closes both.  Also usable as an async context
    manager.  ``port=0`` binds an ephemeral port (tests); the bound port
    is ``self.port`` after ``start()``.
    """

    def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 0,
                 max_queue_depth: int = 64, shed_score: float = 32.0,
                 default_deadline_s: float | None = None,
                 engine_factory=None, max_restarts: int = 2,
                 watchdog_interval_s: float = 0.25,
                 watchdog_stall_s: float = 10.0,
                 retry_after_s: float = 0.05,
                 faults: FaultPlan | None = None):
        self.engine = engine
        self.host, self.port = host, port
        self.max_queue_depth = max_queue_depth
        self.shed_score = shed_score
        self.default_deadline_s = default_deadline_s
        # watchdog/recovery knobs (docs/resilience.md): without a
        # factory the watchdog can only fail streams fast — with one it
        # rebuilds the engine and resumes in-flight requests
        self.engine_factory = engine_factory
        self.max_restarts = max_restarts
        self.watchdog_interval_s = watchdog_interval_s
        self.watchdog_stall_s = watchdog_stall_s
        self.retry_after_s = retry_after_s
        self.faults = faults                 # client_disconnect site only
        self.server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._streams: dict[int, asyncio.Queue] = {}
        self._control: collections.deque = collections.deque()
        self._work = threading.Event()
        self._stop_flag = threading.Event()
        self._thread: threading.Thread | None = None
        self._next_uid = 0
        self._uid_lock = threading.Lock()
        # engine-thread supervision state: ``_gen`` fences stale threads
        # (a superseded loop exits at its next iteration), ``_beat`` is
        # the heartbeat the stall detector reads
        self._gen = 0
        self._beat = time.monotonic()
        self._health = "ok"          # ok | recovering | degraded | failed
        self._engine_exc: BaseException | None = None
        self._kick: asyncio.Event | None = None
        self._watchdog: asyncio.Task | None = None
        # front-end outcome counters (engine stats() covers the rest)
        self.accepted = 0
        self.shed = 0
        self.expired = 0
        self.disconnected = 0
        self.restarts = 0

    # -- lifecycle ----------------------------------------------------------

    def _start_engine_thread(self, eng) -> None:
        eng.on_token = self._on_token
        eng.on_retire = self._on_retire
        self._beat = time.monotonic()
        self._thread = threading.Thread(
            target=self._engine_loop, args=(eng, self._gen),
            name=f"engine-loop-{self._gen}", daemon=True)
        self._thread.start()

    async def start(self) -> "ServingFrontend":
        self._loop = asyncio.get_running_loop()
        self._kick = asyncio.Event()
        self._start_engine_thread(self.engine)
        self._watchdog = self._loop.create_task(self._watchdog_loop())
        self.server = await asyncio.start_server(self._serve_client,
                                                 self.host, self.port)
        self.port = self.server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self.server is not None:
            self.server.close()
            await self.server.wait_closed()
        if self._watchdog is not None:
            self._watchdog.cancel()
            try:
                await self._watchdog
            except asyncio.CancelledError:
                pass
        self._stop_flag.set()
        self._work.set()
        if self._thread is not None:
            await asyncio.to_thread(self._thread.join)
        self.engine.on_token = None
        self.engine.on_retire = None

    async def __aenter__(self) -> "ServingFrontend":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- engine thread ------------------------------------------------------

    def _engine_loop(self, eng, gen: int) -> None:
        """Tick loop for ONE engine generation.  A superseded generation
        (watchdog rebuilt the engine) exits at its next iteration; an
        exception escaping the loop is reported to the event loop so no
        client ever hangs on a silently dead thread."""
        try:
            while not self._stop_flag.is_set() and self._gen == gen:
                self._beat = time.monotonic()
                while self._control:
                    op, arg = self._control.popleft()
                    if op == "submit":
                        eng.submit(arg)
                    elif op == "resubmit":       # watchdog re-admission
                        eng.resubmit(arg)
                    else:                        # "cancel"
                        eng.cancel(arg)
                if eng.queue or any(eng.slots):
                    eng.step()
                    eng.pop_retired()    # on_retire already forwarded them
                else:
                    self._work.wait(timeout=0.05)
                    self._work.clear()
        except BaseException as exc:  # noqa: BLE001 — anything must surface
            if self._stop_flag.is_set() or self._gen != gen:
                return
            self._loop.call_soon_threadsafe(self._engine_died, exc, gen)

    def _engine_died(self, exc: BaseException, gen: int) -> None:
        """Event-loop side of an engine-thread crash: record it, then
        either wake the watchdog for a rebuild or — with no recovery
        configured — terminate every open stream with an error record
        (the no-hung-clients guarantee holds even without a factory)."""
        if gen != self._gen or self._stop_flag.is_set():
            return
        self._engine_exc = exc
        if self.engine.obs is not None:
            self.engine.obs.tracer.emit("watchdog", action="engine_error",
                                        error=repr(exc))
        if self.engine_factory is not None and self.restarts < self.max_restarts:
            self._health = "recovering"
            self._kick.set()
        else:
            self._health = "failed"
            self._fail_open_streams(f"engine thread died: {exc!r}")

    def _fail_open_streams(self, msg: str) -> None:
        """Push an error sentinel to every open stream (loop thread).
        Streams that already hold their retire record finish on it
        first; the sentinel only catches the ones that would hang."""
        for q in list(self._streams.values()):
            q.put_nowait(("error", msg))

    # -- watchdog -----------------------------------------------------------

    async def _watchdog_loop(self) -> None:
        """Supervise the engine thread: rebuild on death (kicked by
        ``_engine_died``) and on heartbeat stalls (a tick stuck longer
        than ``watchdog_stall_s`` while work is pending)."""
        while not self._stop_flag.is_set():
            try:
                await asyncio.wait_for(self._kick.wait(),
                                       self.watchdog_interval_s)
            except asyncio.TimeoutError:
                pass
            self._kick.clear()
            if self._stop_flag.is_set():
                return
            if self._health == "failed":     # terminal: nothing to do
                continue
            dead = self._health == "recovering" or not self._thread.is_alive()
            pending = bool(self._streams) or bool(self._control)
            stalled = (pending and time.monotonic() - self._beat
                       > self.watchdog_stall_s)
            if dead or stalled:
                await self._recover("died" if dead else "stalled")

    async def _recover(self, why: str) -> None:
        """Rebuild the engine and resume every live request.

        Order matters: bump ``_gen`` (fences the old loop), detach the
        old engine's hooks (a straggler thread finishing its tick can no
        longer forward tokens), THEN snapshot live requests into fresh
        Request copies — each resumes via the engine's ``_resume_ctx``
        machinery (prompt + tokens so far), so clients see the exact
        continuation with nothing duplicated or lost."""
        if self.engine_factory is None or self.restarts >= self.max_restarts:
            self._health = "failed"
            if self.engine.obs is not None:
                self.engine.obs.tracer.emit("watchdog", action="give_up",
                                            reason=why,
                                            restarts=self.restarts)
            self._fail_open_streams(f"engine {why}; recovery exhausted")
            return
        self._health = "recovering"
        old = self.engine
        self._gen += 1
        old.on_token = None
        old.on_retire = None
        live, seen = [], set()
        for r in list(old.queue) + [s for s in old.slots if s is not None]:
            if r is None or r.done or r.uid in seen:
                continue
            seen.add(r.uid)
            live.append(Request(uid=r.uid, prompt=np.asarray(r.prompt),
                                max_new_tokens=r.max_new_tokens,
                                temperature=r.temperature,
                                out_tokens=list(r.out_tokens)))
        pending_uids = {a.uid for op, a in list(self._control)
                        if op in ("submit", "resubmit")}
        new_eng = await asyncio.to_thread(self.engine_factory)
        self.engine = new_eng
        self.restarts += 1
        for r in reversed(live):
            self._control.appendleft(("resubmit", r))
        # any stream covered by neither the snapshot nor a pending
        # submit cannot produce a retire record anymore — fail it now
        for uid, q in list(self._streams.items()):
            if uid not in seen and uid not in pending_uids:
                q.put_nowait(("error", f"engine {why}; request lost in "
                                       f"restart"))
        self._start_engine_thread(new_eng)
        self._work.set()
        self._health = "degraded"
        if new_eng.obs is not None:
            new_eng.obs.tracer.emit("watchdog", action="restart",
                                    reason=why, n_resumed=len(live),
                                    restarts=self.restarts)

    def _on_token(self, req, tok: int) -> None:
        """Engine-thread hook: forward one sampled token to its open
        stream (if the client is still connected)."""
        q = self._streams.get(req.uid)
        if q is not None:
            self._loop.call_soon_threadsafe(q.put_nowait, ("token", tok))

    def _on_retire(self, req) -> None:
        q = self._streams.get(req.uid)
        if q is not None:
            self._loop.call_soon_threadsafe(q.put_nowait, ("done", req))

    # -- admission control --------------------------------------------------

    def _occupancy(self) -> float:
        eng = self.engine
        n_pages = getattr(eng, "n_pages", 0)
        if n_pages:
            return eng.pages_in_use / n_pages
        busy = sum(r is not None for r in eng.slots)
        return busy / max(eng.max_slots, 1)

    def _shed_verdict(self) -> dict | None:
        """None to admit, else the shed record (trace event + 503 body).

        Depth counts engine-queued requests plus control-queue submits
        not yet applied; occupancy is page-pool (or slot) utilization.
        The product crossing ``shed_score`` sheds — load must be high on
        BOTH axes — and ``max_queue_depth`` is the hard cap."""
        depth = len(self.engine.queue) + sum(
            1 for op, _ in list(self._control) if op == "submit")
        occ = self._occupancy()
        score = depth * occ
        if depth >= self.max_queue_depth or score >= self.shed_score:
            return {"queue_depth": depth, "occupancy": occ, "score": score}
        return None

    # -- HTTP plumbing ------------------------------------------------------

    async def _serve_client(self, reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
        try:
            req_line = await reader.readline()
            if not req_line:
                return
            parts = req_line.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, path = parts[0], parts[1]
            headers = {}
            while True:
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                key, _, val = line.decode("latin-1").partition(":")
                headers[key.strip().lower()] = val.strip()
            body = b""
            n = int(headers.get("content-length", "0") or 0)
            if n:
                body = await reader.readexactly(n)
            if method == "POST" and path == "/generate":
                await self._handle_generate(body, writer)
            elif method == "GET" and path == "/healthz":
                ok = self._health != "failed"
                self._respond(writer, 200 if ok else 503,
                              {"v": WIRE_VERSION, "ok": ok,
                               "state": self._health,
                               "restarts": self.restarts})
            elif method == "GET" and path == "/stats":
                self._respond(writer, 200, self._stats())
            else:
                self._respond(writer, 404, {"error": "not found"})
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()

    def _stats(self) -> dict:
        try:
            st = self.engine.stats()
        except RuntimeError:
            # stats() iterates live queue/slot state the engine thread
            # mutates; losing one poll to the race beats locking the tick
            st = {}
        st.pop("per_request", None)
        st["v"] = WIRE_VERSION
        st["frontend"] = {"accepted": self.accepted, "shed": self.shed,
                          "expired": self.expired,
                          "disconnected": self.disconnected,
                          "restarts": self.restarts,
                          "health": self._health,
                          "open_streams": len(self._streams)}
        return st

    @staticmethod
    def _respond(writer: asyncio.StreamWriter, status: int, obj,
                 headers: dict | None = None) -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  503: "Service Unavailable"}[status]
        body = _json_bytes(obj)
        extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
        writer.write(
            f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n{extra}"
            f"Connection: close\r\n\r\n".encode() + body)

    @staticmethod
    def _chunk(writer: asyncio.StreamWriter, data: bytes) -> None:
        writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")

    # -- the streaming endpoint --------------------------------------------

    async def _handle_generate(self, body: bytes,
                               writer: asyncio.StreamWriter) -> None:
        try:
            payload = json.loads(body.decode() or "{}")
            prompt = np.asarray(payload["prompt"], np.int64).reshape(-1)
        except (ValueError, KeyError, TypeError):
            self._respond(writer, 400, {"error": "invalid body"})
            return
        unknown = sorted(set(payload) - GENERATE_FIELDS)
        if unknown:
            # fail typos loudly: the v1 contract names the accepted
            # fields instead of silently dropping the unknown ones
            self._respond(writer, 400, {
                "error": f"unknown fields: {', '.join(unknown)}",
                "known_fields": sorted(GENERATE_FIELDS)})
            return
        if len(prompt) == 0 or len(prompt) > self.engine.prompt_capacity:
            self._respond(writer, 400, {
                "error": "prompt length out of range",
                "capacity": self.engine.prompt_capacity})
            return
        if self._health == "failed":
            self._respond(writer, 503,
                          {"error": "engine_failed", "restarts": self.restarts})
            return
        verdict = self._shed_verdict()
        if verdict is not None:
            self.shed += 1
            if self.engine.obs is not None:
                self.engine.obs.tracer.emit("shed", **verdict)
            self._respond(writer, 503,
                          {"error": "shed",
                           "retry_after_s": self.retry_after_s, **verdict},
                          headers={"Retry-After": f"{self.retry_after_s:g}"})
            return
        with self._uid_lock:
            uid = self._next_uid
            self._next_uid += 1
        deadline_s = payload.get("deadline_s", self.default_deadline_s)
        req = Request(uid=uid, prompt=prompt,
                      max_new_tokens=int(payload.get("max_new_tokens", 32)),
                      temperature=float(payload.get("temperature", 0.0)))
        queue: asyncio.Queue = asyncio.Queue()
        self._streams[uid] = queue
        self.accepted += 1
        self._control.append(("submit", req))
        self._work.set()

        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Transfer-Encoding: chunked\r\n"
                     b"Connection: close\r\n\r\n")
        loop = asyncio.get_running_loop()
        deadline_at = (loop.time() + deadline_s
                       if deadline_s is not None else None)
        expired, n_streamed, final, error = False, 0, None, None
        try:
            while final is None and error is None:
                timeout = None
                if deadline_at is not None and not expired:
                    timeout = max(deadline_at - loop.time(), 0.0)
                try:
                    kind, val = await asyncio.wait_for(queue.get(), timeout)
                except asyncio.TimeoutError:
                    # deadline expired mid-stream: cancel in the engine
                    # and drain until the retire confirmation arrives
                    # (the engine may still race one more token out)
                    expired = True
                    self.expired += 1
                    if self.engine.obs is not None:
                        self.engine.obs.tracer.emit(
                            "deadline", uid=uid, deadline_s=deadline_s,
                            n_streamed=n_streamed)
                    self._control.append(("cancel", uid))
                    self._work.set()
                    continue
                if kind == "token":
                    if (self.faults is not None and
                            self.faults.fire("client_disconnect", uid=uid)):
                        if self.engine.obs is not None:
                            self.engine.obs.tracer.emit(
                                "fault", site="client_disconnect", uid=uid)
                        raise ConnectionResetError(
                            f"injected client disconnect uid={uid}")
                    n_streamed += 1
                    self._chunk(writer, _json_bytes({"token": int(val)}))
                    await writer.drain()
                elif kind == "error":
                    error = val
                else:
                    final = val
            if error is not None:
                # engine died and recovery could not cover this stream:
                # terminate with an error record instead of hanging
                self._chunk(writer, _json_bytes({
                    "done": True, "uid": uid, "error": error,
                    "tokens": None, "n_tokens": n_streamed,
                    "expired": expired, "cancelled": False, "failed": True}))
            else:
                self._chunk(writer, _json_bytes({
                    "done": True, "uid": uid,
                    "tokens": [int(t) for t in final.out_tokens],
                    "n_tokens": len(final.out_tokens),
                    "expired": expired, "cancelled": final.cancelled,
                    "failed": final.failed}))
            writer.write(b"0\r\n\r\n")
        except ConnectionError:
            # client went away mid-stream: cancel in the engine so the
            # slot/pages free at the next tick instead of decoding into
            # a dead socket (tests/test_frontend.py pins this)
            self.disconnected += 1
            if self.engine.obs is not None:
                self.engine.obs.tracer.emit("disconnect", uid=uid,
                                            n_streamed=n_streamed)
            self._control.append(("cancel", uid))
            self._work.set()
            raise
        finally:
            self._streams.pop(uid, None)


# ---------------------------------------------------------------------------
# minimal async client (tests, benchmarks/load_gen.py, serve.py self-drive)
# ---------------------------------------------------------------------------


async def http_generate(host: str, port: int, payload: dict,
                        clock=None) -> dict:
    """POST /generate and consume the token stream.

    Returns {"status", "body" (final record or error body), "tokens",
    "token_times" (client receive timestamp per token, from ``clock`` —
    default the running loop's clock), "headers" (lower-cased response
    headers — retry clients read ``retry-after`` off 503 sheds)}.
    """
    clock = clock or asyncio.get_running_loop().time
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(payload).encode()
        writer.write(f"POST /generate HTTP/1.1\r\nHost: {host}\r\n"
                     f"Content-Type: application/json\r\n"
                     f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await writer.drain()
        status, headers = await _read_head(reader)
        tokens, times, final = [], [], None
        async for rec in _ndjson_records(reader, headers):
            if "token" in rec:
                tokens.append(rec["token"])
                times.append(clock())
            else:
                final = rec
        return {"status": status, "body": final, "tokens": tokens,
                "token_times": times, "headers": headers}
    finally:
        writer.close()


async def http_get(host: str, port: int, path: str) -> dict:
    """GET a one-shot JSON endpoint (/healthz, /stats)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n".encode())
        await writer.drain()
        status, headers = await _read_head(reader)
        n = int(headers.get("content-length", "0") or 0)
        body = json.loads((await reader.readexactly(n)).decode() or "{}")
        return {"status": status, "body": body}
    finally:
        writer.close()


async def _read_head(reader: asyncio.StreamReader):
    status_line = await reader.readline()
    status = int(status_line.split()[1])
    headers = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        key, _, val = line.decode("latin-1").partition(":")
        headers[key.strip().lower()] = val.strip()
    return status, headers


async def _ndjson_records(reader: asyncio.StreamReader, headers: dict):
    """Yield ndjson records from a chunked or content-length body."""
    buf = b""
    if headers.get("transfer-encoding", "").lower() == "chunked":
        while True:
            size_line = await reader.readline()
            size = int(size_line.strip() or b"0", 16)
            if size == 0:
                await reader.readline()          # trailing CRLF
                break
            data = await reader.readexactly(size + 2)   # chunk + CRLF
            buf += data[:-2]
            while b"\n" in buf:
                line, buf = buf.split(b"\n", 1)
                if line.strip():
                    yield json.loads(line)
    else:
        n = int(headers.get("content-length", "0") or 0)
        for line in (await reader.readexactly(n)).splitlines():
            if line.strip():
                yield json.loads(line)
