"""Shared greedy-equivalence harness for the serving-engine suites.

One copy of the cross-family model setup, request factory, dispatch
counter, and page-accounting invariant that ``test_serving_batched.py``,
``test_serving_paged.py``, ``test_prefix_cache.py``, and
``test_speculative.py`` all drive their differential matrices through
(the first three carried private copies until the speculative suite
would have made it four).  ``setup`` is process-cached, so every suite
sharing a (arch, quantization) cell also shares its folded params and
jit caches.
"""

import functools

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.models.api import get_model
from repro.serving.engine import Request
from repro.serving.fold import collect_calibration, fold_quantize

KEY = jax.random.PRNGKey(0)

# one arch per family (moe uses DeepSeek: MLA latent cache + leading
# dense layers — the hardest cache layout)
FAMILY_ARCHS = {
    "dense": "stablelm_3b",
    "moe": "deepseek_v2_lite_16b",
    "ssm": "mamba2_780m",
    "hybrid": "zamba2_12b",
}


@functools.lru_cache(maxsize=None)
def setup(arch: str, quantized: bool = False, use_kernels: str = "never"):
    """(cfg, model, params, policy) for one matrix cell.  ``quantized``
    folds a W8A8 model under ``use_kernels`` ("never" = pure XLA,
    "interpret" = the kernel path with a fallback jit — what the chaos
    plans need so dispatch_raise is recoverable)."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    policy = None
    if quantized:
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        stats = collect_calibration(model, params, cfg, [{"tokens": toks}])
        policy = QuantPolicy(weight_bits=8, act_bits=8, pack_weights=False,
                             use_kernels=use_kernels)
        params = fold_quantize(params, cfg, policy=policy, stats=stats)
    return cfg, model, params, policy


def mk_requests(cfg, n=3, max_new=4, temperature=0.0):
    return [Request(uid=i,
                    prompt=np.random.default_rng(i).integers(
                        0, cfg.vocab_size, size=(3 + i,)),
                    max_new_tokens=max_new, temperature=temperature)
            for i in range(n)]


def count_decodes(eng):
    """Wrap eng._decode with a call counter (list the test inspects)."""
    calls = []
    orig = eng._decode

    def counting(*a):
        calls.append(1)
        return orig(*a)

    eng._decode = counting
    return calls


def serve(eng, reqs, max_ticks=300):
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_ticks=max_ticks)
    return {r.uid: list(map(int, r.out_tokens)) for r in done}


def assert_partition(eng):
    """The paged allocator's page-accounting invariant: the free list,
    the cached-but-unreferenced tier, and the referenced pages partition
    ``range(n_pages)`` — disjoint, no page lost, none double-entered."""
    free = {int(p) for p in eng._free}
    assert len(free) == len(eng._free)          # no double-free
    referenced = {p for p in range(eng.n_pages) if eng._ref[p] > 0}
    cached0 = {p for p in eng._page_key if eng._ref[p] == 0}
    assert not free & referenced
    assert not free & cached0
    assert not referenced & cached0
    assert sorted(free | referenced | cached0) == list(range(eng.n_pages))
