"""Optional-import shim for ``hypothesis``.

The property tests use a small, fixed subset of the hypothesis API
(``given``, ``settings``, ``st.integers`` / ``st.floats`` /
``st.sampled_from``).  When hypothesis is installed (requirements-dev.txt)
the real library is used unchanged; when it is absent the fallback below
replays a deterministic pseudo-random sample of examples so the property
tests still execute instead of killing collection with an ImportError.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Minimal strategy: a callable drawing one example from an RNG."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    st = _Strategies()

    _DEFAULT_EXAMPLES = 10

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_kw):
        """Record max_examples on the (already given-wrapped) test."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        """Run the test over a deterministic sample of drawn examples."""

        def deco(fn):
            # NOTE: the wrapper must take NO parameters — pytest resolves
            # wrapper signature params as fixtures, and functools.wraps
            # would re-expose the strategy params through __wrapped__.
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(0)
                for _ in range(n):
                    drawn = tuple(s.example(rng) for s in strategies)
                    fn(*drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
