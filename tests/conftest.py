"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 device by
design (the 512-device forcing is exclusively dryrun.py's, per task spec).
"""

import jax
import pytest
from repro.launch import compat


@pytest.fixture(scope="session")
def test_mesh():
    """(1,1) mesh with production axis names (shard_map code paths need
    the named axes to exist)."""
    return compat.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(autouse=True)
def _under_mesh(test_mesh):
    with compat.set_mesh(test_mesh):
        yield
