"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 device by
design (the 512-device forcing is exclusively dryrun.py's, per task spec).
"""

import jax
import pytest


@pytest.fixture(scope="session")
def test_mesh():
    """(1,1) mesh with production axis names (shard_map code paths need
    the named axes to exist)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(autouse=True)
def _under_mesh(test_mesh):
    with jax.set_mesh(test_mesh):
        yield
