"""Autoplan subsystem tests: LayerwisePlan serde/interop, layerwise fold
(uniform round-trip + mixed kinds via had_mask), the difficulty-guided
search, calibration sample retention, fold degradation paths, and the
ServingEngine regression fixes that ride in the same PR."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autoplan import (
    LayerwisePlan,
    ModuleChoice,
    SearchConfig,
    collect_telemetry,
    plan_errors,
    search_plan,
)
from repro.configs.base import get_config
from repro.core.calibration import update_stats
from repro.core.qlinear import QuantPolicy
from repro.core.transforms import TransformPlan
from repro.models.api import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.fold import collect_calibration, fold_quantize

KEY = jax.random.PRNGKey(0)
POLICY = QuantPolicy(weight_bits=4, act_bits=4, use_kernels="never")


def _setup(arch="stablelm_3b", keep_samples=0, **overrides):
    cfg = get_config(arch).reduced(**overrides)
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    stats = collect_calibration(model, params, cfg, [{"tokens": toks}],
                                keep_samples=keep_samples)
    return cfg, model, params, toks, stats


# --- plan IR ---------------------------------------------------------------


def test_plan_json_roundtrip():
    plan = LayerwisePlan(
        num_layers=2,
        modules={"down_proj": (ModuleChoice("smooth_rotate", 0.7),
                               ModuleChoice("rotate")),
                 "k_proj": (ModuleChoice("rotate"), ModuleChoice("none"))},
        base=TransformPlan(alpha=0.6), arch="test")
    again = LayerwisePlan.from_json(plan.to_json())
    assert again == plan
    assert again.choice_for("down_proj", 0) == ModuleChoice("smooth_rotate", 0.7)
    # unplanned module falls back to base
    assert again.choice_for("o_proj", 1).kind == "rotate"
    assert again.choice_for("o_proj", 1).alpha == 0.6


def test_plan_global_interop():
    g = TransformPlan(alpha=0.65)
    lw = LayerwisePlan.from_global(g, num_layers=3)
    assert lw.is_uniform()
    assert lw.to_global() == g
    mixed = LayerwisePlan(
        num_layers=2,
        modules={"k_proj": (ModuleChoice("rotate"), ModuleChoice("none"))})
    assert not mixed.is_uniform()
    with pytest.raises(ValueError):
        mixed.to_global()


def test_plan_validates_layer_count():
    with pytest.raises(ValueError):
        LayerwisePlan(num_layers=3,
                      modules={"k_proj": (ModuleChoice("rotate"),)})


def test_transform_plan_kind_for_fallback():
    """Unknown module names get the conservative rotation default."""
    plan = TransformPlan(attn_in="none", attn_out="none", mlp_in="none",
                         mlp_out="none")
    assert plan.kind_for("some_new_proj") == "rotate"
    assert plan.kind_for("q_proj") == "none"


# --- layerwise fold --------------------------------------------------------


def test_fold_uniform_layerwise_matches_global():
    """Acceptance: global plan and its uniform LayerwisePlan broadcast
    fold to IDENTICAL serving params (and logits)."""
    cfg, model, params, toks, stats = _setup()
    g = TransformPlan()
    lw = LayerwisePlan.from_global(g, cfg.num_layers, arch=cfg.name)
    qg = fold_quantize(params, cfg, policy=POLICY, plan=g, stats=stats)
    ql = fold_quantize(params, cfg, policy=POLICY, plan=lw, stats=stats)
    la, lb = jax.tree.leaves(qg), jax.tree.leaves(ql)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    og = model.forward(qg, cfg, toks, policy=POLICY)
    ol = model.forward(ql, cfg, toks, policy=POLICY)
    np.testing.assert_array_equal(np.asarray(og), np.asarray(ol))


def test_fold_mixed_kinds_per_layer():
    """A rotate/none mixed stack folds each layer with its own kind:
    per-layer weights match the corresponding uniform folds, and the
    had_mask gates the online rotation."""
    cfg, model, params, toks, stats = _setup()
    mixed = LayerwisePlan(
        num_layers=cfg.num_layers,
        modules={"k_proj": (ModuleChoice("rotate"), ModuleChoice("none"))})
    qm = fold_quantize(params, cfg, policy=POLICY, plan=mixed, stats=stats)
    rot = fold_quantize(params, cfg, policy=POLICY,
                        plan=TransformPlan(attn_in="rotate"), stats=stats)
    none = fold_quantize(params, cfg, policy=POLICY,
                         plan=TransformPlan(attn_in="none"), stats=stats)
    qw_m = qm["layers"]["attn"]["wq"]["qw"]
    qw_r = rot["layers"]["attn"]["wq"]["qw"]
    qw_n = none["layers"]["attn"]["wq"]["qw"]
    assert qw_m.had_dim == cfg.d_model
    np.testing.assert_array_equal(np.asarray(qw_m.had_mask), [1.0, 0.0])
    np.testing.assert_array_equal(np.asarray(qw_m.w_q[0]),
                                  np.asarray(qw_r.w_q[0]))
    np.testing.assert_array_equal(np.asarray(qw_m.w_q[1]),
                                  np.asarray(qw_n.w_q[1]))
    logits = model.forward(qm, cfg, toks, policy=POLICY)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_fold_mixed_alphas_per_layer():
    """Same kind, different α per layer: folds via the grouped path and
    stays numerically sane end to end."""
    cfg, model, params, toks, stats = _setup()
    mixed = LayerwisePlan(
        num_layers=cfg.num_layers,
        modules={"down_proj": (ModuleChoice("smooth_rotate", 0.5),
                               ModuleChoice("smooth_rotate", 0.8))})
    qm = fold_quantize(params, cfg, policy=POLICY, plan=mixed, stats=stats)
    qw = qm["layers"]["mlp"]["wd"]["qw"]
    assert qw.had_mask is None          # both layers rotate → no gate
    assert qw.smooth is not None and qw.smooth.shape[0] == cfg.num_layers
    lf = np.asarray(model.forward(params, cfg, toks), np.float32)
    lq = np.asarray(model.forward(qm, cfg, toks, policy=POLICY), np.float32)
    assert np.linalg.norm(lq - lf) / np.linalg.norm(lf) < 1.0


def test_fold_moe_experts_honor_per_layer_rotation():
    """A mixed gate_proj plan reaches the EXPERT stacks too: rotated
    layers fold Rᵀ into wg/wu and the dispatch path gates the online
    rotation with had_mask."""
    cfg, model, params, toks, stats = _setup("arctic_480b")
    assert cfg.first_dense_layers == 0 and cfg.num_layers == 2
    mixed = LayerwisePlan(
        num_layers=cfg.num_layers,
        modules={"gate_proj": (ModuleChoice("rotate"), ModuleChoice("none"))})
    qm = fold_quantize(params, cfg, policy=POLICY, plan=mixed, stats=stats)
    qw = qm["moe_layers"]["moe"]["wg"]["qw"]
    assert qw.had_dim == cfg.d_model
    np.testing.assert_array_equal(np.asarray(qw.had_mask), [1.0, 0.0])
    out = model.forward(qm, cfg, toks, policy=POLICY)
    logits = out[0] if isinstance(out, tuple) else out
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_search_moe_gate_proj_rotation_only():
    """The search must not plan smoothing for moe gate_proj — experts
    cannot deploy it (no per-expert division in the dispatch path)."""
    cfg, model, params, toks, stats = _setup("deepseek_v2_lite_16b",
                                             keep_samples=32)
    plan, _ = search_plan(params, cfg, stats,
                          search=SearchConfig(alpha_grid=(0.5,), top_k=10))
    assert "gate_proj" in plan.modules
    for c in plan.choices_for("gate_proj"):
        assert c.kind in ("none", "rotate")


@pytest.mark.parametrize("arch", ["mamba2_780m", "deepseek_v2_lite_16b"])
def test_fold_layerwise_other_families(arch):
    """ssm/moe families accept a searched LayerwisePlan end to end."""
    cfg, model, params, toks, stats = _setup(arch, keep_samples=32)
    plan, _ = search_plan(params, cfg, stats,
                          search=SearchConfig(alpha_grid=(0.5, 0.7), top_k=2))
    q = fold_quantize(params, cfg, policy=POLICY, plan=plan, stats=stats)
    out = model.forward(q, cfg, toks, policy=POLICY)
    logits = out[0] if isinstance(out, tuple) else out
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# --- fold degradation paths (previously untested) --------------------------


def test_fold_degrades_smooth_rotate_to_rotate_without_stats():
    cfg, model, params, toks, _ = _setup()
    q = fold_quantize(params, cfg, policy=POLICY,
                      plan=TransformPlan(mlp_out="smooth_rotate"), stats=None)
    qw = q["layers"]["mlp"]["wd"]["qw"]
    assert qw.had_dim > 0               # rotation survived
    assert qw.smooth is None            # smoothing silently dropped


def test_fold_degrades_smooth_to_none_without_stats():
    cfg, model, params, toks, _ = _setup()
    q = fold_quantize(params, cfg, policy=POLICY,
                      plan=TransformPlan(attn_in="smooth", attn_out="smooth",
                                         mlp_in="smooth", mlp_out="smooth"),
                      stats=None)
    qw = q["layers"]["mlp"]["wd"]["qw"]
    assert qw.had_dim == 0 and qw.smooth is None


# --- calibration sample retention ------------------------------------------


def test_calibration_keeps_samples_capped():
    cfg, model, params, toks, stats = _setup(keep_samples=16)
    st = stats["down_proj"]
    L = cfg.num_layers
    assert st.act_samples is not None
    assert st.act_samples.shape == (L, 16, cfg.d_ff)   # down_proj input = d_ff
    # a second batch must not grow past the cap — but MUST contribute:
    # merging thins evenly instead of freezing on the first batch's prefix
    taps = {"down_proj": jnp.full((L, 2, 16, cfg.d_ff), 7.0)}
    stats2 = update_stats(stats, taps, keep_samples=16)
    s2 = stats2["down_proj"].act_samples
    assert s2.shape == (L, 16, cfg.d_ff)
    assert bool(jnp.any(s2 == 7.0))        # second batch represented
    assert bool(jnp.any(s2 != 7.0))        # first batch still represented
    assert stats2["down_proj"].n_batches == st.n_batches + 1


def test_calibration_without_samples_unchanged():
    cfg, model, params, toks, stats = _setup(keep_samples=0)
    assert all(v.act_samples is None for v in stats.values())


# --- the search ------------------------------------------------------------


def test_search_beats_or_matches_fixed_plan():
    """The searched plan force-includes the fixed plan's choices, so its
    summed Eq. (2) error can never exceed the fixed §V plan's."""
    cfg, model, params, toks, stats = _setup(keep_samples=64)
    search = SearchConfig(alpha_grid=(0.5, 0.7), top_k=2)
    auto, info = search_plan(params, cfg, stats, search=search)
    fixed = LayerwisePlan.from_global(TransformPlan(), auto.num_layers)
    e_auto = sum(float(np.sum(v)) for v in
                 plan_errors(auto, params, cfg, stats, search).values())
    e_fixed = sum(float(np.sum(v)) for v in
                  plan_errors(fixed, params, cfg, stats, search).values())
    assert e_auto <= e_fixed * (1 + 1e-6), (e_auto, e_fixed)
    assert auto.modules                 # actually planned something
    for module, mi in info.items():
        assert np.isfinite(mi["error"][mi["best"],
                                       np.arange(len(mi["best"]))]).all()


def test_telemetry_profiles():
    cfg, model, params, toks, stats = _setup(keep_samples=32)
    plan, _ = search_plan(params, cfg, stats,
                          search=SearchConfig(alpha_grid=(0.5,), top_k=2))
    tel = collect_telemetry(plan, params, cfg, stats)
    assert set(tel) == set(plan.modules)
    for t in tel.values():
        assert len(t.difficulty_pre) == plan.num_layers
        assert all(np.isfinite(t.difficulty_post))


# --- ServingEngine regressions ---------------------------------------------


def test_engine_admit_preserves_kv_bits():
    """_admit used to rebuild slot caches with bits=None, silently
    discarding the configured KV-cache quantization."""
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    eng = ServingEngine(model, params, cfg, max_slots=1, max_len=32,
                        kv_bits=8)
    assert eng.cache.quantized
    eng.submit(Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=2))
    eng.step()
    assert eng.cache.quantized      # admitted slot kept int8 storage


def test_engine_respects_max_new_tokens_one():
    """The prefill-sampled token can already complete a request; the old
    admit path parked it in a slot and decoded one token too many."""
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    eng = ServingEngine(model, params, cfg, max_slots=1, max_len=32)
    req = Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                  max_new_tokens=1)
    eng.submit(req)
    done = eng.run(max_ticks=10)
    assert [r.uid for r in done] == [0]
    assert len(req.out_tokens) == 1


def test_engine_run_returns_all_retired():
    """run() used to snapshot the queue and lose requests admitted before
    or submitted after the snapshot."""
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    eng = ServingEngine(model, params, cfg, max_slots=1, max_len=64)
    r1 = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32),
                 max_new_tokens=3)
    eng.submit(r1)
    eng.step()                          # r1 admitted into a slot (not queue)
    r2 = Request(uid=2, prompt=np.asarray([4, 5], np.int32),
                 max_new_tokens=3)
    eng.submit(r2)                      # submitted "mid-run"
    done = eng.run(max_ticks=50)
    assert {r.uid for r in done} == {1, 2}
    assert all(r.done for r in done)
