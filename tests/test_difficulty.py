"""Quantization-difficulty metric tests (paper §II-B, §IV-B) — including
the error ∝ difficulty² correlation claim (>0.97)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.difficulty import (
    channel_magnitudes,
    flatness_profile,
    kurtosis,
    layerwise_error,
    quantization_difficulty,
)
from repro.core.outliers import OutlierSpec, synth_activations

KEY = jax.random.PRNGKey(0)


def test_channel_magnitudes_shape():
    x = jax.random.normal(KEY, (4, 8, 32))
    assert channel_magnitudes(x).shape == (32,)


def test_difficulty_zero_for_uniform_channels():
    x = jnp.ones((16, 64))
    assert float(quantization_difficulty(x)) < 1e-6


def test_difficulty_increases_with_outlier_channels():
    base = synth_activations(KEY, OutlierSpec(n_tokens=64, d=128,
                                              n_systematic=0))
    hot = synth_activations(KEY, OutlierSpec(n_tokens=64, d=128,
                                             n_systematic=6))
    assert (float(quantization_difficulty(hot))
            > 3 * float(quantization_difficulty(base)))


def test_flatness_profile_sorted():
    x = synth_activations(KEY, OutlierSpec())
    prof = np.asarray(flatness_profile(x))
    assert (np.diff(prof) <= 1e-6).all()


def test_kurtosis_heavy_tails():
    gauss = jax.random.normal(KEY, (4000,))
    heavy = gauss.at[:20].mul(50.0)
    assert float(kurtosis(heavy)) > float(kurtosis(gauss)) + 1


def test_error_scales_with_weight_norm():
    """Eq. (2): error amplified by ||W|| (paper §II-B)."""
    x = synth_activations(KEY, OutlierSpec(n_tokens=32, d=128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 32)) * 0.05
    assert (float(layerwise_error(x, 10 * w))
            > 50 * float(layerwise_error(x, w)))


def test_correlation_error_vs_difficulty_squared():
    """§IV-B: corr(error, difficulty²) > 0.97 across 'layers' without
    massive outliers (the paper's headline analysis claim)."""
    errors, diff2 = [], []
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 64)) * 0.04
    for i, sys_scale in enumerate(np.linspace(2.0, 40.0, 12)):
        spec = OutlierSpec(n_tokens=96, d=256, n_systematic=6,
                           systematic_scale=float(sys_scale),
                           n_massive_tokens=0)
        x = synth_activations(jax.random.PRNGKey(100 + i), spec)
        errors.append(float(layerwise_error(x, w)))
        diff2.append(float(quantization_difficulty(x)) ** 2)
    corr = np.corrcoef(errors, diff2)[0, 1]
    assert corr > 0.97, corr
