"""Every relative link in README.md and docs/*.md must resolve — the
same contract the CI lint job enforces via tools/check_links.py."""

import glob
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "tools"))

from check_links import check_file  # noqa: E402


def test_readme_and_docs_links_resolve():
    paths = [os.path.join(ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(ROOT, "docs", "*.md")))
    assert paths and all(os.path.exists(p) for p in paths)
    broken = [b for p in paths for b in check_file(p)]
    assert not broken, f"broken relative links: {broken}"
