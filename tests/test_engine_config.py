"""Unified EngineConfig surface (repro.serving.config + docs/api.md).

Pins the api_redesign satellite's contracts:

  * ONE frozen value object configures all three engines — every engine
    accepts ``config=EngineConfig(...)`` and serves with it;
  * JSON round trip like FaultPlan (policy and faults embedded; ``obs``
    is runtime-only and dropped), unknown fields rejected by name;
  * field validation at construction (bounds, kv_bits, eviction policy);
  * the legacy per-kwarg constructor still works through a deprecation
    shim that warns ONCE per process, rejects unknown kwargs with a
    TypeError, and refuses to mix both forms;
  * config-built and shim-built engines are behaviorally IDENTICAL:
    same greedy tokens, same deterministic run_stats counters.
"""

import dataclasses
import functools
import warnings

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.models.api import get_model
from repro.resilience.faults import FaultPlan, FaultSpec
from repro.serving import config as config_mod
from repro.serving.engine import (EngineConfig, PagedServingEngine,
                                  PerSlotServingEngine, Request,
                                  ServingEngine)

KEY = jax.random.PRNGKey(0)

ENGINES = {
    "per_slot": PerSlotServingEngine,
    "batched": ServingEngine,
    "paged": PagedServingEngine,
}


@functools.lru_cache(maxsize=None)
def _setup():
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    return cfg, model, model.init(KEY, cfg)


def _requests(cfg, n=3, max_new=4):
    return [Request(uid=i,
                    prompt=np.random.default_rng(i).integers(
                        0, cfg.vocab_size, size=(3 + i,)),
                    max_new_tokens=max_new) for i in range(n)]


def _serve(eng, cfg, **kw):
    for r in _requests(cfg, **kw):
        eng.submit(r)
    done = eng.run(max_ticks=300)
    return {r.uid: list(map(int, r.out_tokens)) for r in done}


# ---------------------------------------------------------------------------
# the value object
# ---------------------------------------------------------------------------


def test_json_round_trip():
    ec = EngineConfig(max_slots=2, max_len=32,
                      policy=QuantPolicy(weight_bits=8, act_bits=8,
                                         pack_weights=False,
                                         use_kernels="never"),
                      kv_bits=8, page_size=4, n_pages=12, prefill_chunk=8,
                      faults=FaultPlan([FaultSpec("dispatch_raise",
                                                  op="decode", at=3)]),
                      nan_guard=True, prefix_cache=True)
    rt = EngineConfig.from_json(ec.to_json())
    # FaultPlan carries mutable firing state and compares by identity,
    # so equality is checked via the spec list + the JSON fixed point
    assert rt.faults.specs == ec.faults.specs
    assert rt.to_json() == ec.to_json()
    assert dataclasses.replace(rt, faults=None) == dataclasses.replace(
        ec, faults=None)
    # defaults round-trip too, and obs is runtime-only: never serialized
    assert EngineConfig.from_json(EngineConfig().to_json()) == EngineConfig()
    assert '"obs"' not in ec.to_json()


def test_from_json_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown EngineConfig fields"):
        EngineConfig.from_json('{"max_slots": 2, "max_new_tokens": 4}')


@pytest.mark.parametrize("bad", [dict(max_slots=0), dict(max_len=0),
                                 dict(page_size=0), dict(prefill_bucket=-1),
                                 dict(n_pages=0), dict(prefill_chunk=0),
                                 dict(kv_bits=4),
                                 dict(prefix_evict="fifo")])
def test_validation(bad):
    with pytest.raises(ValueError):
        EngineConfig(**bad)


def test_frozen():
    ec = EngineConfig()
    with pytest.raises(Exception):
        ec.max_slots = 8


# ---------------------------------------------------------------------------
# the legacy-kwarg shim
# ---------------------------------------------------------------------------


def test_legacy_kwargs_warn_once_per_process(monkeypatch):
    cfg, model, params = _setup()
    monkeypatch.setattr(config_mod, "_legacy_warned", False)
    with pytest.warns(DeprecationWarning, match="config=EngineConfig"):
        ServingEngine(model, params, cfg, max_slots=2, max_len=32)
    # second legacy construction: silent (property tests build hundreds)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ServingEngine(model, params, cfg, max_slots=2, max_len=32)
    assert config_mod._legacy_warned


def test_unknown_kwarg_is_typeerror():
    cfg, model, params = _setup()
    with pytest.raises(TypeError, match="unknown engine kwargs.*max_slotz"):
        ServingEngine(model, params, cfg, max_slotz=2)


def test_mixing_config_and_kwargs_is_typeerror():
    cfg, model, params = _setup()
    with pytest.raises(TypeError, match="not both"):
        ServingEngine(model, params, cfg, config=EngineConfig(), max_slots=2)


# ---------------------------------------------------------------------------
# engines under the config
# ---------------------------------------------------------------------------


def test_all_engines_accept_one_config():
    """ONE config builds any engine (non-paged engines ignore the
    page-pool fields) and every engine serves under it."""
    cfg, model, params = _setup()
    ec = EngineConfig(max_slots=2, max_len=32, page_size=4, prefill_bucket=8)
    outs = {}
    for name, cls in ENGINES.items():
        eng = cls(model, params, cfg, config=ec)
        assert eng.config == ec
        outs[name] = _serve(eng, cfg)
    # greedy equivalence across engine families still holds via config
    assert outs["per_slot"] == outs["batched"] == outs["paged"]


def test_config_and_shim_builds_identical():
    """A config-built engine and a legacy-kwarg-built engine are the
    SAME engine: identical greedy tokens and deterministic counters."""
    cfg, model, params = _setup()
    kw = dict(max_slots=2, max_len=32, page_size=4, prefill_bucket=8,
              prefill_chunk=8, kv_bits=8)
    via_config = PagedServingEngine(model, params, cfg,
                                    config=EngineConfig(**kw))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        via_shim = PagedServingEngine(model, params, cfg, **kw)
    assert via_shim.config == via_config.config
    toks_c = _serve(via_config, cfg)
    toks_s = _serve(via_shim, cfg)
    assert toks_c == toks_s
    st_c, st_s = via_config.run_stats, via_shim.run_stats
    for key in ("decode_tokens", "prefill_tokens", "decode_dispatches",
                "prefill_dispatches", "ticks", "n_pages", "page_size",
                "prefill_chunk", "prefix"):
        assert st_c[key] == st_s[key], key
