"""Flash-attention (XLA online-softmax) vs naive oracle: exactness,
causality, GQA grouping, and the MLA/windowed dispatch boundaries."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.models.common import attention_scores, flash_attention

KEY = jax.random.PRNGKey(0)


def _qkv(b, s, hq, hkv, d, seed=0, dtype=jnp.bfloat16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, s, hq, d)).astype(dtype),
            jax.random.normal(ks[1], (b, s, hkv, d)).astype(dtype),
            jax.random.normal(ks[2], (b, s, hkv, d)).astype(dtype))


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (2, 1024, 8, 4, 32), (1, 2048, 4, 4, 16), (2, 1024, 16, 2, 8),
])
def test_flash_matches_naive(b, s, hq, hkv, d):
    q, k, v = _qkv(b, s, hq, hkv, d)
    o_n = np.asarray(attention_scores(q, k, v, causal=True), np.float32)
    o_f = np.asarray(flash_attention(q, k, v, causal=True, bf16_io=False),
                     np.float32)
    np.testing.assert_allclose(o_f, o_n, atol=3e-2)


def test_flash_causality():
    q, k, v = _qkv(1, 1024, 4, 4, 16)
    o1 = flash_attention(q, k, v, causal=True, bf16_io=False)
    # perturb the future: first 512 outputs must not move
    k2 = k.at[:, 900:].add(3.0)
    v2 = v.at[:, 900:].add(3.0)
    o2 = flash_attention(q, k2, v2, causal=True, bf16_io=False)
    np.testing.assert_allclose(np.asarray(o1[:, :512], np.float32),
                               np.asarray(o2[:, :512], np.float32),
                               atol=1e-3)


def test_flash_bf16_io_close():
    q, k, v = _qkv(1, 1024, 4, 2, 32, seed=3)
    o_f32 = np.asarray(flash_attention(q, k, v, causal=True, bf16_io=False),
                       np.float32)
    o_bf16 = np.asarray(flash_attention(q, k, v, causal=True, bf16_io=True),
                        np.float32)
    rel = np.abs(o_f32 - o_bf16).max() / (np.abs(o_f32).max() + 1e-9)
    assert rel < 0.05, rel


def test_flash_length_mask():
    """length= caps the visible prefix exactly like the naive mask."""
    q, k, v = _qkv(1, 1024, 4, 4, 16, seed=5)
    o_n = attention_scores(q, k, v, causal=False, length=700)
    o_f = flash_attention(q, k, v, causal=False, length=700, bf16_io=False)
    np.testing.assert_allclose(np.asarray(o_f, np.float32),
                               np.asarray(o_n, np.float32), atol=3e-2)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([512, 1024]), st.sampled_from([(4, 4), (8, 2)]),
       st.integers(0, 1000))
def test_property_flash_rowsum_one(s, heads, seed):
    """Softmax invariant: outputs are convex combos of V rows, so with
    V=1 everywhere the output is exactly 1."""
    hq, hkv = heads
    q, k, _ = _qkv(1, s, hq, hkv, 16, seed=seed)
    v = jnp.ones((1, s, hkv, 16), jnp.bfloat16)
    o = np.asarray(flash_attention(q, k, v, causal=True, bf16_io=False),
                   np.float32)
    np.testing.assert_allclose(o, 1.0, atol=2e-2)
