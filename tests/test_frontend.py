"""Async streaming HTTP front-end (repro.serving.frontend).

Behavior matrix over a LIVE loopback server (stdlib asyncio client, the
engine ticking on its own thread):

  * streamed tokens are byte-identical to an offline ``run()`` of the
    same engine on the same prompts (greedy);
  * a deadline expiring mid-stream cancels the request in the engine —
    slot and pages free, the stream finishes with ``expired: true``,
    and the trace carries the ``deadline`` + cancelled ``retire``
    events;
  * admission control sheds with 503 BEFORE the engine sees the
    request, and a saturating burst is fully accounted
    (completed + shed == offered);
  * preemption mid-stream (tiny page pool) resumes without duplicating
    or dropping a single streamed token, and the JSONL trace of the
    run replays to the identical summary;
  * /healthz, /stats, 404 and 400 validation paths;
  * fault tolerance (docs/resilience.md): a client disconnecting
    mid-stream cancels the request in the engine (slot + pages free); an
    exception escaping the engine loop terminates every open stream with
    an error record instead of hanging; with an ``engine_factory`` the
    watchdog rebuilds the engine and surviving streams continue
    token-exact.
"""

import asyncio
import functools
import json

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.api import get_model
from repro.obs import Observability, load_trace, summarize
from repro.resilience.faults import FaultPlan, FaultSpec
from repro.serving.engine import EngineConfig, PagedServingEngine, Request
from repro.serving.frontend import ServingFrontend, http_generate, http_get

KEY = jax.random.PRNGKey(0)
HOST = "127.0.0.1"


@functools.lru_cache(maxsize=None)
def _setup():
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    return cfg, model, model.init(KEY, cfg)


def _engine(obs=None, **kw):
    cfg, model, params = _setup()
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("page_size", 4)
    cfgE = EngineConfig(prefill_bucket=8, obs=obs, **kw)
    return PagedServingEngine(model, params, cfg, config=cfgE)


def _prompts(n):
    cfg, _, _ = _setup()
    return [np.random.default_rng(100 + i).integers(
        0, cfg.vocab_size, size=(3 + i % 4,)) for i in range(n)]


async def _gen(port, payload):
    return await http_generate(HOST, port, payload)


def test_http_stream_matches_offline_run():
    """Concurrent HTTP streams return exactly the tokens an offline
    ``run()`` produces for the same prompts (greedy determinism survives
    the thread hop + chunked-transfer framing)."""
    prompts = _prompts(4)
    offline = _engine()
    for i, p in enumerate(prompts):
        offline.submit(Request(uid=i, prompt=p, max_new_tokens=5))
    ref = {r.uid: list(r.out_tokens) for r in offline.run(max_ticks=300)}

    async def go():
        async with ServingFrontend(_engine()) as fe:
            return await asyncio.gather(*[
                _gen(fe.port, {"prompt": p.tolist(), "max_new_tokens": 5})
                for p in prompts])

    results = asyncio.run(go())
    for i, r in enumerate(results):
        assert r["status"] == 200
        # streamed records == the final record's authoritative list
        assert r["tokens"] == r["body"]["tokens"] == ref[i]
        assert r["body"]["n_tokens"] == 5
        assert not r["body"]["expired"] and not r["body"]["cancelled"]


def test_deadline_expiry_cancels_and_frees_pages():
    """A deadline expiring mid-stream cancels in the engine: the stream
    closes with expired/cancelled set, pages and slots free, and the
    trace records the deadline + cancelled retire."""
    obs = Observability()
    eng = _engine(obs=obs, max_len=256, page_size=8)

    async def go():
        async with ServingFrontend(eng) as fe:
            r = await _gen(fe.port, {"prompt": [3, 1, 4],
                                     "max_new_tokens": 500,
                                     "deadline_s": 0.05})
            # the retire confirmation precedes the engine thread's page
            # release by a hair — poll the pool briefly
            for _ in range(200):
                if eng.pages_in_use == 0 and not any(eng.slots):
                    break
                await asyncio.sleep(0.01)
            return r

    r = asyncio.run(go())
    assert r["status"] == 200
    assert r["body"]["expired"] is True and r["body"]["cancelled"] is True
    assert r["tokens"] == r["body"]["tokens"]
    assert len(r["tokens"]) < 500                     # cut short
    assert eng.pages_in_use == 0 and not any(eng.slots)
    kinds = [e["ev"] for e in obs.tracer.events]
    assert "deadline" in kinds
    retire = next(e for e in obs.tracer.events if e["ev"] == "retire")
    assert retire["cancelled"] is True


def test_admission_control_sheds():
    """max_queue_depth=0 sheds everything with 503 (the engine never
    sees the request); a saturating burst against a shallow bound is
    fully accounted: completed + shed == offered."""
    obs = Observability()
    eng = _engine(obs=obs)

    async def all_shed():
        async with ServingFrontend(eng, max_queue_depth=0) as fe:
            r = await _gen(fe.port, {"prompt": [1, 2, 3],
                                     "max_new_tokens": 3})
            st = await http_get(HOST, fe.port, "/stats")
        return r, st

    r, st = asyncio.run(all_shed())
    assert r["status"] == 503 and r["body"]["error"] == "shed"
    assert st["body"]["frontend"]["shed"] == 1
    assert eng.stats()["requests"] == 0               # engine untouched
    assert any(e["ev"] == "shed" for e in obs.tracer.events)

    async def burst():
        async with ServingFrontend(_engine(), max_queue_depth=1) as fe:
            return await asyncio.gather(*[
                _gen(fe.port, {"prompt": p.tolist(), "max_new_tokens": 4})
                for p in _prompts(12)])

    results = asyncio.run(burst())
    completed = [r for r in results if r["status"] == 200]
    shed = [r for r in results if r["status"] == 503]
    assert len(completed) + len(shed) == 12
    assert completed and shed
    for r in completed:
        assert r["tokens"] == r["body"]["tokens"]


def test_preemption_mid_stream_no_dup_or_missing_tokens(tmp_path):
    """Tiny pool: both requests fill it exactly, decode growth forces a
    preemption while streams are open.  Every stream still equals the
    offline run token for token — the resume is invisible to clients —
    and the run's JSONL trace replays to the identical summary."""
    # 3-page pool, each request needs all 3 pages at full length: ANY
    # overlap of the two streams (a 5-tick window; HTTP arrival jitter
    # is 1-2 engine-loop iterations) forces a preemption — requiring
    # same-tick admission (2-page pool, where one decode tick of the
    # first request exhausts the pool) made this assertion racy
    kw = dict(max_len=32, n_pages=3)
    reqs = [Request(uid=0, prompt=np.arange(1, 5), max_new_tokens=6),
            Request(uid=1, prompt=np.arange(3, 7), max_new_tokens=6)]
    offline = _engine(**kw)
    for r in reqs:
        offline.submit(r)
    ref = {r.uid: list(r.out_tokens) for r in offline.run(max_ticks=300)}

    trace = tmp_path / "frontend_trace.jsonl"
    obs = Observability(trace_path=str(trace))
    eng = _engine(obs=obs, **kw)

    async def go():
        async with ServingFrontend(eng) as fe:
            return await asyncio.gather(*[
                _gen(fe.port, {"prompt": r.prompt.tolist(),
                               "max_new_tokens": 6}) for r in reqs])

    results = asyncio.run(go())
    for i, r in enumerate(results):
        assert r["status"] == 200
        assert r["tokens"] == r["body"]["tokens"] == ref[i]
    s = obs.summary()
    assert s["counts"]["preemptions"] >= 1 and s["counts"]["resumes"] >= 1
    # trace-derived token count == every token every client received
    streamed = sum(len(r["tokens"]) for r in results)
    assert s["counts"]["decode_tokens"] + s["ttft_s"]["count"] == streamed
    # JSONL replay byte-identical (acceptance: python -m repro.obs on a
    # front-end trace reproduces the live summary)
    mem = obs.summary()
    obs.close()
    assert summarize(load_trace(str(trace))) == mem


def test_endpoints_and_validation():
    eng = _engine()

    async def go():
        async with ServingFrontend(eng) as fe:
            h = await http_get(HOST, fe.port, "/healthz")
            st = await http_get(HOST, fe.port, "/stats")
            nf = await http_get(HOST, fe.port, "/nope")
            bad = await _gen(fe.port, {"max_new_tokens": 3})
            huge = await _gen(fe.port, {"prompt": list(range(1000))})
        return h, st, nf, bad, huge

    h, st, nf, bad, huge = asyncio.run(go())
    assert h["status"] == 200
    assert h["body"] == {"v": 1, "ok": True, "state": "ok", "restarts": 0}
    assert st["status"] == 200
    assert st["body"]["frontend"]["open_streams"] == 0
    assert nf["status"] == 404
    assert bad["status"] == 400
    assert huge["status"] == 400
    assert huge["body"]["capacity"] == eng.prompt_capacity


def test_wire_schema_v1():
    """The wire schema pin (docs/api.md): /healthz and /stats carry the
    version tag, and a POST body with fields outside the documented
    /generate schema is a 400 NAMING the offenders — versioning is
    additive, so an old client never silently loses a field."""
    from repro.serving.frontend import GENERATE_FIELDS, WIRE_VERSION

    assert WIRE_VERSION == 1
    assert GENERATE_FIELDS == {"prompt", "max_new_tokens", "temperature",
                               "deadline_s"}

    async def go():
        async with ServingFrontend(_engine()) as fe:
            h = await http_get(HOST, fe.port, "/healthz")
            st = await http_get(HOST, fe.port, "/stats")
            unk = await _gen(fe.port, {"prompt": [1, 2, 3], "max_new": 3,
                                       "stop": ["x"]})
            ok = await _gen(fe.port, {"prompt": [1, 2, 3],
                                      "max_new_tokens": 2,
                                      "temperature": 0.0})
        return h, st, unk, ok

    h, st, unk, ok = asyncio.run(go())
    assert h["body"]["v"] == WIRE_VERSION
    assert st["body"]["v"] == WIRE_VERSION
    assert unk["status"] == 400
    # the error names every unknown field (sorted) and the known set
    assert unk["body"]["error"] == "unknown fields: max_new, stop"
    assert unk["body"]["known_fields"] == sorted(GENERATE_FIELDS)
    assert ok["status"] == 200 and ok["body"]["n_tokens"] == 2


def test_client_disconnect_cancels_request_in_engine():
    """A client socket aborting mid-stream cancels the request in the
    engine: slot evicted, pages freed, ``disconnect`` trace event +
    cancelled retire — the engine never decodes into a dead socket."""
    obs = Observability()
    eng = _engine(obs=obs, max_len=256, page_size=8)

    async def go():
        async with ServingFrontend(eng) as fe:
            reader, writer = await asyncio.open_connection(HOST, fe.port)
            body = json.dumps({"prompt": [3, 1, 4],
                               "max_new_tokens": 200}).encode()
            writer.write(f"POST /generate HTTP/1.1\r\nHost: {HOST}\r\n"
                         f"Content-Length: {len(body)}\r\n\r\n".encode()
                         + body)
            await writer.drain()
            # the 200 header block is written EAGERLY, before the engine
            # admits — wait for an actual token chunk so the request is
            # provably live (slot held, pages in use) when we abort
            seen = b""
            while b'"token"' not in seen:
                seen += await reader.read(256)
            writer.transport.abort()
            for _ in range(500):
                if (eng.pages_in_use == 0 and not any(eng.slots)
                        and not eng.queue):
                    break
                await asyncio.sleep(0.01)
            st = await http_get(HOST, fe.port, "/stats")
        return st

    st = asyncio.run(go())
    assert eng.pages_in_use == 0 and not any(eng.slots)
    assert st["body"]["frontend"]["disconnected"] == 1
    assert st["body"]["frontend"]["open_streams"] == 0
    kinds = [e["ev"] for e in obs.tracer.events]
    assert "disconnect" in kinds
    retire = next(e for e in obs.tracer.events if e["ev"] == "retire")
    assert retire["cancelled"] is True
    assert obs.summary()["counts"]["disconnects"] == 1


def test_injected_disconnect_fault_site():
    """The deterministic ``client_disconnect`` fault site reproduces the
    organic disconnect path: stream aborts after exactly ``at`` tokens,
    the request cancels in the engine, pages restore."""
    obs = Observability()
    eng = _engine(obs=obs)
    plan = FaultPlan([FaultSpec("client_disconnect", uid=0, at=2)])

    async def go():
        async with ServingFrontend(eng, faults=plan) as fe:
            r = await http_generate(HOST, fe.port,
                                    {"prompt": [3, 1, 4],
                                     "max_new_tokens": 6})
            for _ in range(200):
                if eng.pages_in_use == 0 and not any(eng.slots):
                    break
                await asyncio.sleep(0.01)
        return r

    r = asyncio.run(go())
    assert r["body"] is None                  # no final record: aborted
    assert len(r["tokens"]) == 2              # exactly `at` streamed
    assert eng.pages_in_use == 0 and not any(eng.slots)
    assert len(plan.fired) == 1
    kinds = [e["ev"] for e in obs.tracer.events]
    assert "fault" in kinds and "disconnect" in kinds


def test_engine_crash_terminates_streams_with_error_record():
    """An exception escaping the engine loop (injected dispatch_raise on
    a bf16 engine: no fallback jit) must terminate every open stream
    with an error record — no client hangs — and flip /healthz + new
    submissions to failed/503."""
    obs = Observability()
    plan = FaultPlan([FaultSpec("dispatch_raise", op="decode", at=1)])
    eng = _engine(obs=obs, faults=plan)

    async def go():
        async with ServingFrontend(eng) as fe:      # no engine_factory
            rs = await asyncio.gather(*[
                _gen(fe.port, {"prompt": p.tolist(), "max_new_tokens": 6})
                for p in _prompts(2)])
            h = await http_get(HOST, fe.port, "/healthz")
            rejected = await _gen(fe.port, {"prompt": [1, 2, 3],
                                            "max_new_tokens": 2})
        return rs, h, rejected

    rs, h, rejected = asyncio.run(go())
    for r in rs:
        assert r["status"] == 200
        assert r["body"]["failed"] is True and "error" in r["body"]
        assert r["body"]["tokens"] is None
    assert h["status"] == 503
    assert h["body"] == {"v": 1, "ok": False, "state": "failed",
                         "restarts": 0}
    assert rejected["status"] == 503
    assert rejected["body"]["error"] == "engine_failed"
    wd = [e for e in obs.tracer.events if e["ev"] == "watchdog"]
    assert wd and wd[0]["action"] == "engine_error"


def test_watchdog_rebuilds_engine_and_stream_continues_token_exact():
    """With an ``engine_factory`` the watchdog recovers from an engine
    crash mid-stream: the rebuilt engine re-admits the in-flight request
    via resubmit/_resume_ctx and the client receives the EXACT token
    sequence of an uninterrupted run — nothing repeated, nothing lost."""
    prompt = [3, 1, 4, 1]
    offline = _engine()
    offline.submit(Request(uid=0, prompt=np.asarray(prompt),
                           max_new_tokens=6))
    [ref] = offline.run(max_ticks=300)

    obs = Observability()
    plan = FaultPlan([FaultSpec("dispatch_raise", op="decode", at=2)])

    def factory():
        return _engine(obs=obs)     # same obs: one trace across lives

    eng = _engine(obs=obs, faults=plan)

    async def go():
        async with ServingFrontend(eng, engine_factory=factory,
                                   watchdog_interval_s=0.05) as fe:
            r = await http_generate(HOST, fe.port,
                                    {"prompt": prompt, "max_new_tokens": 6})
            h = await http_get(HOST, fe.port, "/healthz")
            st = await http_get(HOST, fe.port, "/stats")
        return r, h, st

    r, h, st = asyncio.run(go())
    assert r["status"] == 200 and r["body"]["failed"] is False
    assert r["tokens"] == r["body"]["tokens"] == list(ref.out_tokens)
    assert h["body"] == {"v": 1, "ok": True, "state": "degraded",
                         "restarts": 1}
    assert st["body"]["frontend"]["restarts"] == 1
    wd = [e["action"] for e in obs.tracer.events if e["ev"] == "watchdog"]
    assert "engine_error" in wd and "restart" in wd
    restart = next(e for e in obs.tracer.events
                   if e["ev"] == "watchdog" and e["action"] == "restart")
    assert restart["n_resumed"] == 1 and restart["reason"] == "died"
    assert obs.summary()["counts"]["watchdog_restarts"] == 1


def test_chunked_prefill_engine_behind_frontend():
    """The chunked-prefill engine serves HTTP traffic token-identically
    to its own offline run (long prompt included)."""
    cfg, _, _ = _setup()
    kw = dict(max_len=64, prefill_chunk=8)
    prompts = [np.asarray([5, 3, 2]),
               np.arange(1, 40) % cfg.vocab_size]
    offline = _engine(**kw)
    for i, p in enumerate(prompts):
        offline.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    ref = {r.uid: list(r.out_tokens) for r in offline.run(max_ticks=300)}

    async def go():
        async with ServingFrontend(_engine(**kw)) as fe:
            return await asyncio.gather(*[
                _gen(fe.port, {"prompt": p.tolist(), "max_new_tokens": 4})
                for p in prompts])

    results = asyncio.run(go())
    for i, r in enumerate(results):
        assert r["status"] == 200
        assert r["tokens"] == r["body"]["tokens"] == ref[i]
