"""One-pass fused quant-linear kernel + unified backend dispatch.

Parity contract: ``fused_qlinear`` (interpret mode) must match the
``ref.fused_qlinear_ref`` oracle and ``qlinear``'s XLA path across the
full matrix {packed int4, unpacked int8} × {smooth, no-smooth} ×
{had_dim 0/rotated} × {had_mask None/0/1} × {act_bits 4, 8}, including
the serving engine's (max_slots, 1) decode shape.  Codes may flip ±1 on
exact rounding ties (bf16 inputs hit x/Δ = .5 often; XLA fuses the
divide differently than the interpreter), so comparisons are
tensor-level relative norms, not exact — matching tests/test_kernels.py.

Dispatch contract: ``ops.resolve_backend`` is the ONE authority mapping
``QuantPolicy.use_kernels`` to {pallas, xla, interpret}; ``qlinear``
must route auto/interpret through ``ops.fused_qlinear`` (ONE
``pallas_call`` per linear — asserted by counting kernel launches),
with NO XLA fallback for had_mask-gated mixed layerwise stacks.
"""

import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hadamard import apply_hadamard
from repro.core.qlinear import QuantPolicy, qlinear, quantize_weight
from repro.kernels import fused_qlinear as fq
from repro.kernels import ops, ref
from repro.serving.engine import ServingEngine

KEY = jax.random.PRNGKey(0)


def _rel(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-9)


def _mk_qw(d, m, *, w_bits=4, packed=True, smooth=False, had=True,
           had_mask=None, seed=1):
    """Fold a weight the way serving/fold.py would: smooth scaling first,
    then Rᵀ — except un-rotated layers of a mixed stack (had_mask=0),
    whose weights keep had_dim metadata but no rotation."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (d, m)) * 0.05
    s = None
    wf = w.astype(jnp.float32)
    if smooth:
        s = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 1), (d,))) + 0.5
        wf = wf * s[:, None]
    if had and (had_mask is None or had_mask > 0):
        wf = apply_hadamard(wf, axis=0)
    qw = quantize_weight(wf, bits=w_bits, pack=packed,
                         had_dim=d if had else 0, smooth=s)
    if had and had_mask is not None:
        qw = dc.replace(qw, had_mask=jnp.asarray(float(had_mask)))
    return qw


# ---------------------------------------------------------------------------
# parity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w_bits,packed", [(4, True), (8, False)])
@pytest.mark.parametrize("smooth", [False, True])
@pytest.mark.parametrize("had", [False, True])
@pytest.mark.parametrize("had_mask", [None, 0, 1])
@pytest.mark.parametrize("act_bits", [4, 8])
def test_parity_matrix(w_bits, packed, smooth, had, had_mask, act_bits):
    if not had and had_mask is not None:
        pytest.skip("had_mask only gates rotated stacks")
    d, m = 256, 64
    x = jax.random.normal(KEY, (8, d)).astype(jnp.bfloat16)
    qw = _mk_qw(d, m, w_bits=w_bits, packed=packed, smooth=smooth, had=had,
                had_mask=had_mask)
    y_fused = fq.fused_qlinear(x, qw, act_bits=act_bits, interpret=True)
    y_ref = ref.fused_qlinear_ref(x, qw, act_bits=act_bits)
    y_xla = qlinear(x, qw, QuantPolicy(act_bits=act_bits,
                                       use_kernels="never"))
    assert _rel(y_fused, y_ref) < 0.05, (w_bits, smooth, had, had_mask)
    assert _rel(y_fused, y_xla) < 0.06, (w_bits, smooth, had, had_mask)


@pytest.mark.parametrize("d,structure", [
    (1536, "paley-kronecker"),   # Paley_12 ⊗ H_128: leading factor in XLA
    (4096, "sylvester-split"),   # H_512 ⊗ H_8: the decode hot-path dim class
    (12, "pure-paley"),          # no fusable trailing factor: XLA rotation
    (24, "block-fallback"),      # grouped H_8 within 3 groups, fully fused
])
def test_parity_structured_dims(d, structure):
    m = 32
    x = jax.random.normal(KEY, (5, d)).astype(jnp.bfloat16)
    qw = _mk_qw(d, m, smooth=True, had=True)
    y_fused = fq.fused_qlinear(x, qw, interpret=True)
    y_ref = ref.fused_qlinear_ref(x, qw)
    y_xla = qlinear(x, qw, QuantPolicy(use_kernels="never"))
    assert _rel(y_fused, y_ref) < 0.05, structure
    assert _rel(y_fused, y_xla) < 0.06, structure


@pytest.mark.parametrize("had_mask", [0, 1])
def test_had_mask_gates_multifactor_on_fused_path(had_mask):
    """Mixed layerwise stacks on a Kronecker dim: the XLA pre-stage and
    the in-kernel trailing factor must gate CONSISTENTLY on the scalar."""
    d, m = 1536, 32
    x = jax.random.normal(KEY, (4, d)).astype(jnp.bfloat16)
    qw = _mk_qw(d, m, smooth=True, had=True, had_mask=had_mask)
    y_fused = fq.fused_qlinear(x, qw, interpret=True)
    y_xla = qlinear(x, qw, QuantPolicy(use_kernels="never"))
    assert _rel(y_fused, y_xla) < 0.06


def test_decode_slot_shapes():
    """The engine's (max_slots, 1) tick reaches qlinear as (slots·1, d)
    rows — tall-skinny tiles must pad, not degrade to divisor-1 grids."""
    d = 256
    qw = _mk_qw(d, 64, smooth=True, had=True)
    for slots in (1, 3, 4):
        x = jax.random.normal(KEY, (slots, 1, d)).astype(jnp.bfloat16)
        y_i = qlinear(x, qw, QuantPolicy(use_kernels="interpret"))
        y_r = ref.fused_qlinear_ref(x.reshape(slots, d), qw)
        y_x = qlinear(x, qw, QuantPolicy(use_kernels="never"))
        assert y_i.shape == (slots, 1, 64)
        assert _rel(y_i.reshape(slots, 64), y_r) < 0.05, slots
        # few rows × few cols: single ±1 tie flips carry more relative
        # weight than in the matrix tests — loose bound vs the bf16 XLA
        # path, tight bound vs the oracle above
        assert _rel(y_i, y_x) < 0.12, slots


def test_fused_matches_staged_composition():
    """The one-pass kernel must agree with the staged 3-round-trip
    composition it replaces (ops.fused_quant_matmul)."""
    d, m = 1536, 64
    x = jax.random.normal(KEY, (8, d)).astype(jnp.bfloat16)
    qw = _mk_qw(d, m, smooth=True, had=True)
    y_fused = fq.fused_qlinear(x, qw, interpret=True)
    y_staged = ops.fused_quant_matmul(x, qw, interpret=True)
    assert _rel(y_fused, y_staged) < 0.05


# ---------------------------------------------------------------------------
# dispatch: ops.resolve_backend is the single authority
# ---------------------------------------------------------------------------


def test_resolve_backend_table(monkeypatch):
    assert ops.resolve_backend("never") == "xla"
    assert ops.resolve_backend("interpret") == "interpret"
    assert ops.resolve_backend("auto") == "xla"        # CPU test host
    monkeypatch.setattr(ops, "use_pallas", lambda backend="auto": True)
    assert ops.resolve_backend("auto") == "pallas"     # TPU host
    with pytest.raises(ValueError):
        ops.resolve_backend("sometimes")


def test_auto_routes_through_ops_fused_qlinear(monkeypatch):
    """Regression (the PR-3 dispatch gap): use_kernels="auto" on a TPU
    host must call ops.fused_qlinear with interpret=False — the seed
    routed auto to the XLA path and never exercised the kernels."""
    d = 256
    x = jax.random.normal(KEY, (4, d)).astype(jnp.bfloat16)
    qw = _mk_qw(d, 32, had=True)
    seen = {}

    def recording(x2, qw_, *, act_bits=4, interpret=False):
        seen["interpret"] = interpret
        return fq.fused_qlinear(x2, qw_, act_bits=act_bits, interpret=True)

    monkeypatch.setattr(ops, "use_pallas", lambda backend="auto": True)
    monkeypatch.setattr(ops, "fused_qlinear", recording)
    qlinear(x, qw, QuantPolicy(use_kernels="auto"))
    assert seen == {"interpret": False}


def test_auto_on_cpu_and_never_stay_on_xla(monkeypatch):
    """auto (CPU host) and never must not touch the kernel layer."""
    d = 256
    x = jax.random.normal(KEY, (4, d)).astype(jnp.bfloat16)
    qw = _mk_qw(d, 32, had=True)

    def boom(*a, **k):
        raise AssertionError("XLA mode must not reach ops.fused_qlinear")

    monkeypatch.setattr(ops, "fused_qlinear", boom)
    qlinear(x, qw, QuantPolicy(use_kernels="auto"))
    qlinear(x, qw, QuantPolicy(use_kernels="never"))


@pytest.mark.parametrize("case", ["plain", "smooth_had", "had_mask",
                                  "kronecker"])
def test_interpret_issues_exactly_one_pallas_call(case, monkeypatch):
    """ONE pallas_call — one activation HBM read, one bf16 write — per
    quantized linear on the fused path, INCLUDING had_mask-gated mixed
    stacks (previously forced onto the XLA fallback)."""
    d = 1536 if case == "kronecker" else 256
    qw = {
        "plain": lambda: _mk_qw(d, 32, had=False),
        "smooth_had": lambda: _mk_qw(d, 32, smooth=True, had=True),
        "had_mask": lambda: _mk_qw(d, 32, smooth=True, had=True, had_mask=0),
        "kronecker": lambda: _mk_qw(d, 32, smooth=True, had=True, had_mask=1),
    }[case]()
    x = jax.random.normal(KEY, (4, d)).astype(jnp.bfloat16)
    calls = []
    orig = fq._pallas_call
    monkeypatch.setattr(fq, "_pallas_call",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    qlinear(x, qw, QuantPolicy(use_kernels="interpret"))
    assert len(calls) == 1, case


def test_engine_reports_resolved_backend():
    """The serving engine surfaces the resolved dispatch for ops teams;
    it must mirror ops.resolve_backend, not re-derive it."""
    eng = object.__new__(ServingEngine)
    eng.policy = QuantPolicy(use_kernels="interpret")
    assert eng.kernel_backend == "interpret"
    eng.policy = QuantPolicy(use_kernels="never")
    assert eng.kernel_backend == "xla"
    eng.policy = None
    assert eng.kernel_backend == "bf16"
