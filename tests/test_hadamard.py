"""Hadamard construction + fast-apply tests (paper §II-D, DESIGN §3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core.hadamard import (
    apply_hadamard,
    hadamard_factorization,
    hadamard_matrix,
    kernel_fusable_factor,
    paley,
    plan_hadamard,
    sylvester,
)

# every distinct channel dim appearing in the 10 assigned archs
ARCH_DIMS = [2048, 8192, 1536, 3072, 7168, 4864, 1408, 512, 16384, 53248,
             4096, 2560, 6912, 6144, 64, 80, 128, 3328]


@pytest.mark.parametrize("d", [2, 4, 64, 512])
def test_sylvester_orthogonal(d):
    h = sylvester(d).astype(np.float64)
    np.testing.assert_allclose(h @ h.T, d * np.eye(d), atol=1e-9)


@pytest.mark.parametrize("q", [3, 7, 11, 19, 43, 103, 151, 223])
def test_paley_orthogonal(q):
    h = paley(q).astype(np.float64)
    np.testing.assert_allclose(h @ h.T, (q + 1) * np.eye(q + 1), atol=1e-9)


@pytest.mark.parametrize("d", ARCH_DIMS)
def test_factorization_covers_arch_dims(d):
    f = hadamard_factorization(d)
    if f[0][0] != "block":
        assert int(np.prod([s for _, s in f])) == d
    else:  # documented grouped fallback
        assert d % f[0][1] == 0


@pytest.mark.parametrize("d", [12, 20, 44, 108, 152, 1536, 2560, 1408])
def test_rotation_orthonormal(d):
    r = hadamard_matrix(d).astype(np.float64)
    np.testing.assert_allclose(r @ r.T, np.eye(d), atol=1e-5)


@pytest.mark.parametrize("d", [64, 1536, 2560, 1408, 6912])
def test_fast_apply_matches_dense(d):
    x = jax.random.normal(jax.random.PRNGKey(0), (3, d))
    dense = x @ jnp.asarray(hadamard_matrix(d))
    fast = apply_hadamard(x, d)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(dense),
                               atol=2e-3)


@pytest.mark.parametrize("d", [64, 1536, 1408])
def test_inverse_roundtrip(d):
    x = jax.random.normal(jax.random.PRNGKey(1), (5, d))
    rt = apply_hadamard(apply_hadamard(x, d), d, inverse=True)
    np.testing.assert_allclose(np.asarray(rt), np.asarray(x), atol=2e-3)


@pytest.mark.parametrize("d", [1536, 2560, 4096])
def test_skip_last_plus_kernel_factor_equals_full(d):
    """partial(XLA) ∘ grouped(kernel) == full rotation (ops.py contract)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, d))
    last = kernel_fusable_factor(d)
    assert last >= 2
    part = apply_hadamard(x, d, skip_last=True)
    grouped = apply_hadamard(
        part.reshape(4, d // last, last), last).reshape(4, d)
    full = apply_hadamard(x, d)
    np.testing.assert_allclose(np.asarray(grouped), np.asarray(full),
                               atol=2e-3)


def test_norm_preservation():
    """Rotation preserves ||x||₂ (orthogonality) — quantization range
    redistribution only."""
    for d in (128, 1536):
        x = jax.random.normal(jax.random.PRNGKey(3), (7, d))
        y = apply_hadamard(x, d)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=1),
            np.linalg.norm(np.asarray(y), axis=1), rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([16, 64, 128, 1536]), st.integers(1, 16))
def test_property_outlier_spread(d, seed):
    """A single massive outlier spreads to |o|/√d across channels
    (paper Eq. 8 with |O| = 1)."""
    o = 1000.0
    t = jnp.zeros((1, d)).at[0, seed % d].set(o)
    y = np.asarray(apply_hadamard(t, d))
    np.testing.assert_allclose(np.abs(y), o / np.sqrt(d), rtol=1e-4)


def test_plan_splits_large_sylvester():
    plan = plan_hadamard(16384)
    assert all(s <= 512 for s in plan.factor_sizes)
    assert int(np.prod(plan.factor_sizes)) == 16384
