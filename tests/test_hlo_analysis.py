"""Unit tests for the HLO-text analyzer (the roofline's instrument)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import compat
from repro.launch.hlo_analysis import (
    HloMetrics,
    _is_s2_tensor,
    _type_bytes,
    analyze_hlo,
)


def _compile(f, *args, in_shardings=None):
    jf = jax.jit(f) if in_shardings is None else jax.jit(
        f, in_shardings=in_shardings)
    return jf.lower(*args).compile()


def test_type_bytes():
    assert _type_bytes("f32[8,64]{1,0}") == 8 * 64 * 4
    assert _type_bytes("bf16[2,3]") == 12
    assert _type_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert _type_bytes("pred[]") == 1


def test_s2_detection():
    assert _is_s2_tensor("f32[1,32,4096,4096]{3,2,1,0}")
    assert not _is_s2_tensor("f32[4096,128]{1,0}")
    assert not _is_s2_tensor("f32[]")


def test_dot_flops_exact():
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    compiled = _compile(lambda a, b: a @ b, x, w)
    m = analyze_hlo(compiled.as_text())
    assert m.flops == 2 * 32 * 128 * 64


def test_while_trip_count_multiplies():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((11, 32, 32), jnp.float32)
    m = analyze_hlo(_compile(f, x, ws).as_text())
    assert 11 in m.while_trips.values()
    assert m.flops == 11 * 2 * 8 * 32 * 32


def test_nested_scan_trips():
    def f(x, ws):
        def outer(c, wpair):
            def inner(ci, w):
                return jnp.tanh(ci @ w), ()
            c, _ = jax.lax.scan(inner, c, wpair)
            return c, ()
        y, _ = jax.lax.scan(outer, x, ws)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 3, 16, 16), jnp.float32)  # 5 outer × 3 inner
    m = analyze_hlo(_compile(f, x, ws).as_text())
    assert m.flops == 15 * 2 * 4 * 16 * 16


def test_collective_detection_and_wire():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    # single-device: no collectives expected — the parser must return 0
    with compat.set_mesh(mesh):
        compiled = _compile(lambda a: jnp.sum(a),
                            jax.ShapeDtypeStruct((8, 8), jnp.float32))
    m = analyze_hlo(compiled.as_text())
    assert m.collective_bytes == 0


def test_dynamic_slice_not_overcounted():
    """Reading one layer from a stacked (L, d, d) param inside scan must
    charge the SLICE bytes per iteration, not the full stack (the L²
    overcount bug caught during bring-up)."""
    def f(x, ws):
        def body(c, i):
            w = jax.lax.dynamic_index_in_dim(ws, i, 0, keepdims=False)
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, jnp.arange(16))
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 64, 64), jnp.float32)
    m = analyze_hlo(_compile(f, x, ws).as_text())
    stack_bytes = 16 * 64 * 64 * 4
    # total traffic must be well under trips × full-stack (16×) reads
    assert m.hbm_bytes < 0.5 * 16 * stack_bytes, m.hbm_bytes


def test_metrics_scaled_add():
    a = HloMetrics(flops=2.0, hbm_bytes=10.0, s2_bytes=1.0,
                   wire_bytes=4.0, wire_bytes_by_group={4: 4.0})
    b = a.scaled(3)
    assert b.flops == 6.0 and b.wire_bytes_by_group[4] == 12.0
    a.add(b)
    assert a.flops == 8.0 and a.s2_bytes == 4.0
