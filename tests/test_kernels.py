"""Per-kernel correctness: shape/dtype sweeps, interpret-mode kernels vs
pure-jnp oracles (ref.py).

Contract notes: per-token scales must match to ~1 ulp; integer codes may
differ by ±1 on exact rounding ties (XLA fuses the divide differently in
the two paths) at <1% of entries; the fused matmul output must match the
oracle within the dequantization step size.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core.qlinear import QuantPolicy, qlinear, quantize_weight
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _codes_close(a, b, frac=0.01):
    diff = np.abs(np.asarray(a, np.int32) - np.asarray(b, np.int32))
    assert diff.max() <= 1, diff.max()
    assert (diff > 0).mean() <= frac, (diff > 0).mean()


@pytest.mark.parametrize("n,d", [(8, 128), (16, 256), (3, 384), (32, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_kernel_sweep(n, d, dtype, bits):
    x = (jax.random.normal(KEY, (n, d)) * 3).astype(dtype)
    qk, sk = ops.quantize_per_token(x, bits=bits, interpret=True)
    qr, sr = ref.quantize_per_token_ref(x, bits)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-5)
    _codes_close(qk, qr)


@pytest.mark.parametrize("n,k,m", [(8, 128, 64), (16, 256, 192),
                                   (4, 512, 128), (32, 1024, 256)])
@pytest.mark.parametrize("w_bits,packed", [(4, False), (4, True), (8, False)])
def test_quant_matmul_kernel_sweep(n, k, m, w_bits, packed):
    x = jax.random.normal(KEY, (n, k)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, m)) * 0.05
    aq, a_scale = ref.quantize_per_token_ref(x, 4)
    qw = quantize_weight(w, bits=w_bits, pack=packed)
    y = ops.quant_matmul(aq, qw.w_q, a_scale, qw.scale, packed=qw.packed,
                         interpret=True)
    qw_ref = quantize_weight(w, bits=w_bits, pack=False)
    y_ref = ref.quant_matmul_ref(x, qw_ref.w_q, qw_ref.scale, 4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("n,d,block", [(8, 256, 128), (16, 512, 256),
                                       (4, 1024, 128), (8, 128, 128)])
@pytest.mark.parametrize("bits", [4, 8])
def test_fused_hadamard_quant_sweep(n, d, block, bits):
    x = jax.random.normal(KEY, (n, d)).astype(jnp.bfloat16)
    qk, sk = ops.fused_hadamard_quant(x, block=block, bits=bits,
                                      interpret=True)
    qr, sr = ref.fused_hadamard_quant_ref(x, block, bits)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-4)
    _codes_close(qk, qr)


def test_packed_equals_unpacked_exactly():
    """Nibble packing is lossless: identical int32 accumulators."""
    x = jax.random.normal(KEY, (16, 256)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 96)) * 0.05
    aq, a_scale = ref.quantize_per_token_ref(x, 4)
    qw_u = quantize_weight(w, bits=4, pack=False)
    qw_p = quantize_weight(w, bits=4, pack=True)
    y_u = ops.quant_matmul(aq, qw_u.w_q, a_scale, qw_u.scale, interpret=True)
    y_p = ops.quant_matmul(aq, qw_p.w_q, a_scale, qw_p.scale, packed=True,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(y_u, np.float32),
                                  np.asarray(y_p, np.float32))


def test_fused_path_matches_qlinear_xla():
    """Pallas fused path ≡ XLA qlinear path (same rotation + arithmetic)."""
    x = jax.random.normal(KEY, (8, 1536)).astype(jnp.bfloat16)  # Paley dim
    w = jax.random.normal(jax.random.PRNGKey(3), (1536, 64)) * 0.05
    from repro.core.hadamard import apply_hadamard

    wf = apply_hadamard(w.astype(jnp.float32), axis=0)
    qw = quantize_weight(wf, bits=4, pack=True, had_dim=1536)
    y_kernel = np.asarray(ops.fused_quant_matmul(x, qw, interpret=True),
                          np.float32)
    y_xla = np.asarray(qlinear(x, qw, QuantPolicy(use_kernels="never")),
                       np.float32)
    # ±1-code rounding ties (<0.5% of entries) perturb individual outputs
    # by ~Δa·Δw; compare at the tensor level
    rel = np.linalg.norm(y_kernel - y_xla) / np.linalg.norm(y_xla)
    assert rel < 0.05, rel


def test_qlinear_interpret_policy_matches_xla():
    """qlinear's interpret dispatch must hand the fused path the RAW
    activation — smooth/rotation are the fused path's job (regression:
    they used to be applied twice, x/s² and H(Hx))."""
    d = 256
    x = jax.random.normal(KEY, (8, d)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(3), (d, 64)) * 0.05
    from repro.core.hadamard import apply_hadamard

    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (d,))) + 0.5
    wf = apply_hadamard((w * s[:, None]).astype(jnp.float32), axis=0)
    qw = quantize_weight(wf, bits=4, pack=True, had_dim=d, smooth=s)
    y_interp = np.asarray(
        qlinear(x, qw, QuantPolicy(use_kernels="interpret")), np.float32)
    y_xla = np.asarray(
        qlinear(x, qw, QuantPolicy(use_kernels="never")), np.float32)
    rel = np.linalg.norm(y_interp - y_xla) / np.linalg.norm(y_xla)
    assert rel < 0.05, rel


def test_qlinear_interpret_with_had_mask_stays_fused():
    """Mixed layerwise stacks (had_mask) run on the fused path — the
    traced scalar gates the rotation IN-KERNEL (no XLA fallback; the
    seed forced these onto the XLA route).  Codes may flip ±1 on exact
    rounding ties (bf16 inputs), so compare at the tensor level."""
    import dataclasses as dc

    d = 256
    x = jax.random.normal(KEY, (4, d)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(5), (d, 32)) * 0.05
    qw = quantize_weight(w.astype(jnp.float32), bits=4, pack=True, had_dim=d)
    qw = dc.replace(qw, had_mask=jnp.asarray(0.0))   # un-rotated layer
    y_interp = np.asarray(
        qlinear(x, qw, QuantPolicy(use_kernels="interpret")), np.float32)
    y_xla = np.asarray(
        qlinear(x, qw, QuantPolicy(use_kernels="never")), np.float32)
    rel = np.linalg.norm(y_interp - y_xla) / np.linalg.norm(y_xla)
    assert rel < 0.05, rel


@pytest.mark.parametrize("n,k,m", [(5, 250, 66), (3, 130, 7), (1, 384, 96),
                                   (7, 512, 130)])
def test_quant_matmul_nondivisible_dims(n, k, m):
    """Prime/odd dims and tiny decode row counts: blocks pad to tile
    boundaries (the old largest-divisor heuristic degenerated to
    divisor-1 scalar-ish grids)."""
    x = jax.random.normal(KEY, (n, k)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(7), (k, m)) * 0.05
    aq, a_scale = ref.quantize_per_token_ref(x, 4)
    qw = quantize_weight(w, bits=8, pack=False)
    y = ops.quant_matmul(aq, qw.w_q, a_scale, qw.scale, interpret=True)
    acc = ref.int_matmul_ref(aq, qw.w_q)
    y_ref = (acc.astype(jnp.float32) * a_scale * qw.scale
             ).astype(jnp.bfloat16)
    assert y.shape == (n, m)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(y_ref, np.float32))


def test_quant_matmul_packed_odd_block_k_override():
    """Caller-specified odd block_k must be repaired, not trace-crash
    (nibble pairs may not straddle k-blocks)."""
    n, k, m = 8, 512, 64
    x = jax.random.normal(KEY, (n, k)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(9), (k, m)) * 0.05
    aq, a_scale = ref.quantize_per_token_ref(x, 4)
    qw_u = quantize_weight(w, bits=4, pack=False)
    qw_p = quantize_weight(w, bits=4, pack=True)
    from repro.kernels.quant_matmul import quant_matmul_packed

    y_p = quant_matmul_packed(aq, qw_p.w_q, a_scale, qw_p.scale,
                              block_k=255, interpret=True)
    y_u = ops.quant_matmul(aq, qw_u.w_q, a_scale, qw_u.scale, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_p, np.float32),
                                  np.asarray(y_u, np.float32))


def test_quant_matmul_packed_nondivisible_m():
    """Packed path with odd m and non-power-of-two k: padding keeps the
    nibble pairs aligned and the result identical to unpacked."""
    n, k, m = 7, 384, 66
    x = jax.random.normal(KEY, (n, k)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(8), (k, m)) * 0.05
    aq, a_scale = ref.quantize_per_token_ref(x, 4)
    qw_u = quantize_weight(w, bits=4, pack=False)
    qw_p = quantize_weight(w, bits=4, pack=True)
    y_u = ops.quant_matmul(aq, qw_u.w_q, a_scale, qw_u.scale, interpret=True)
    y_p = ops.quant_matmul(aq, qw_p.w_q, a_scale, qw_p.scale, packed=True,
                           interpret=True)
    assert y_p.shape == (n, m)
    np.testing.assert_array_equal(np.asarray(y_u, np.float32),
                                  np.asarray(y_p, np.float32))


@pytest.mark.parametrize("n", [1, 3, 5])
def test_quantize_kernels_row_padding(n):
    """Ragged/tiny-n (decode) rows pad up to a full sublane tile and the
    padding is sliced off — both single-pass quantize kernels."""
    x = jax.random.normal(KEY, (n, 256)).astype(jnp.bfloat16)
    qk, sk = ops.quantize_per_token(x, bits=4, interpret=True)
    qr, sr = ref.quantize_per_token_ref(x, 4)
    assert qk.shape == (n, 256) and sk.shape == (n, 1)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-5)
    _codes_close(qk, qr)
    qk, sk = ops.fused_hadamard_quant(x, block=128, interpret=True)
    qr, sr = ref.fused_hadamard_quant_ref(x, 128, 4)
    assert qk.shape == (n, 256) and sk.shape == (n, 1)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-4)
    _codes_close(qk, qr)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 16), st.sampled_from([128, 256, 512]),
       st.integers(0, 10**6))
def test_property_w4a4_error_bound(n, k, seed):
    """End-to-end W4A4 error ≤ what independent RTN noise predicts:
    ‖y−ŷ‖ ≤ ‖Δa‖·‖W‖ + ‖X‖·‖ΔW‖ style bound with slack."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, k)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, 32)) * 0.05
    qw = quantize_weight(w, bits=4, pack=True)
    y = np.asarray(qlinear(x, qw, QuantPolicy(use_kernels="never")),
                   np.float32)
    y_ref = np.asarray(x.astype(jnp.float32) @ w)
    rel = np.linalg.norm(y - y_ref) / max(np.linalg.norm(y_ref), 1e-6)
    assert rel < 0.5, rel
