"""Per-arch smoke tests (reduced configs, task-spec requirement) +
model-level invariants: prefill+decode ≡ full forward, SSD ≡ sequential
recurrence, causality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, input_specs, list_archs
from repro.models.api import get_model
from repro.models.mamba2 import ssd_chunked

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.embeds_input and cfg.family in ("audio", "vlm"):
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model)
                                            ).astype(jnp.bfloat16),
                "labels": toks}
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_train_step(arch):
    """Reduced config: one forward + one train grad step, shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    batch = _batch(cfg)
    out = model.forward(params, cfg, batch.get("tokens"),
                        embeds=batch.get("embeds"))
    logits = out[0] if isinstance(out, tuple) else out
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, grads = jax.value_and_grad(
        lambda p: model.train_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all()


@pytest.mark.parametrize("arch", ["stablelm_3b", "qwen15_4b", "mamba2_780m",
                                  "zamba2_12b", "deepseek_v2_lite_16b",
                                  "arctic_480b", "musicgen_large"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    out = model.forward(params, cfg, toks)
    full = np.asarray(out[0] if isinstance(out, tuple) else out, np.float32)
    cache = model.make_cache(cfg, B, 32)
    lg, cache = model.prefill(params, cfg, toks[:, :S], cache)
    rel = np.abs(np.asarray(lg[:, -1], np.float32) - full[:, S - 1]).max() \
        / (np.abs(full[:, S - 1]).max() + 1e-9)
    assert rel < 0.05, rel
    lg2, cache = model.decode_step(params, cfg, toks[:, S:S + 1], cache)
    rel2 = np.abs(np.asarray(lg2[:, 0], np.float32) - full[:, S]).max() \
        / (np.abs(full[:, S]).max() + 1e-9)
    assert rel2 < 0.05, rel2


def test_causality():
    """Changing future tokens must not change past logits."""
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    toks2 = toks.at[0, 10:].set((toks[0, 10:] + 7) % cfg.vocab_size)
    l1 = np.asarray(model.forward(params, cfg, toks), np.float32)
    l2 = np.asarray(model.forward(params, cfg, toks2), np.float32)
    np.testing.assert_allclose(l1[0, :10], l2[0, :10], atol=1e-2)


def test_ssd_chunked_equals_sequential():
    b, l, h, p, n = 2, 32, 4, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, 1, n))
    C = jax.random.normal(ks[4], (b, l, 1, n))
    D = jnp.ones((h,))
    y_chunk, S_chunk = ssd_chunked(x, dt, A, B, C, D, chunk=8)
    S = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        Bh = jnp.repeat(B[:, t], h, 1)
        Ch = jnp.repeat(C[:, t], h, 1)
        S = S * jnp.exp(dt[:, t] * A[None])[:, :, None, None] + jnp.einsum(
            "bhn,bh,bhp->bhpn", Bh, dt[:, t], x[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch, S)
                  + x[:, t] * D[None, :, None])
    y_seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(S_chunk), np.asarray(S),
                               rtol=1e-3, atol=1e-3)


def test_ssd_nondivisible_length_padding():
    b, l, h, p, n = 1, 13, 2, 4, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, l, 1, n))
    C = jax.random.normal(ks[4], (b, l, 1, n))
    y8, s8 = ssd_chunked(x, dt, A, B, C, jnp.ones((h,)), chunk=8)
    y13, s13 = ssd_chunked(x, dt, A, B, C, jnp.ones((h,)), chunk=13)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y13), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s13), rtol=1e-3,
                               atol=1e-3)


def test_sliding_window_attention_masks_past():
    """attn_window: tokens beyond the window do not influence logits."""
    import dataclasses

    cfg = dataclasses.replace(get_config("stablelm_3b").reduced(),
                              attn_window=4)
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    toks2 = toks.at[0, 0:4].set((toks[0, 0:4] + 3) % cfg.vocab_size)
    l1 = np.asarray(model.forward(params, cfg, toks), np.float32)
    l2 = np.asarray(model.forward(params, cfg, toks2), np.float32)
    # position 15 attends [12..15] only → unaffected by changing [0..3]
    np.testing.assert_allclose(l1[0, 15], l2[0, 15], atol=1e-2)


def test_input_specs_cover_all_cells():
    for arch in list_archs():
        cfg = get_config(arch)
        for cell in SHAPES.values():
            specs = input_specs(cfg, cell)
            assert all(hasattr(v, "shape") for v in specs.values())


def test_moe_load_balance_aux_positive():
    cfg = get_config("arctic_480b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, aux = model.forward(params, cfg, toks)
    assert float(aux) >= 1.0  # E·Σf·P ≥ 1 by Cauchy-Schwarz
