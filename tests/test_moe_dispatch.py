"""MoE dispatch correctness: the capacity-bounded, sort-based,
shard_map'd expert compute vs a dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.models.moe import _local_expert_compute
from repro.launch import compat

KEY = jax.random.PRNGKey(0)


def _dense_reference(x, topi, topv, wg, wu, wd):
    """Every token through its top-k experts, no capacity limit."""
    T, d = x.shape
    k = topi.shape[1]
    out = jnp.zeros((T, d), jnp.float32)
    for slot in range(k):
        for e in range(wg.shape[0]):
            m = (topi[:, slot] == e).astype(jnp.float32)[:, None]
            g = x.astype(jnp.float32) @ wg[e].astype(jnp.float32)
            u = x.astype(jnp.float32) @ wu[e].astype(jnp.float32)
            y = (jax.nn.silu(g) * u) @ wd[e].astype(jnp.float32)
            out = out + m * topv[:, slot][:, None] * y
    return out


def _setup(T=16, d=32, f=24, E=4, k=2, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (T, d))
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    logits = jax.random.normal(ks[4], (T, E))
    topv, topi = jax.lax.top_k(jax.nn.softmax(logits), k)
    topv = topv / topv.sum(-1, keepdims=True)
    return x, topi, topv, wg, wu, wd


def test_local_compute_matches_dense_reference():
    x, topi, topv, wg, wu, wd = _setup()
    got = _local_expert_compute(x, topi, topv, wg, wu, wd, n_experts=4, k=2,
                                capacity_factor=4.0, axis=None)
    want = _dense_reference(x, topi, topv, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-3)


def test_capacity_drops_overflow_tokens():
    """With capacity 1 token per expert, output norm shrinks but stays
    finite (dropped tokens contribute zero, never NaN)."""
    x, topi, topv, wg, wu, wd = _setup(T=32)
    got = _local_expert_compute(x, topi, topv, wg, wu, wd, n_experts=4, k=2,
                                capacity_factor=0.1, axis=None)
    full = _local_expert_compute(x, topi, topv, wg, wu, wd, n_experts=4,
                                 k=2, capacity_factor=8.0, axis=None)
    assert np.isfinite(np.asarray(got)).all()
    assert (np.linalg.norm(np.asarray(got))
            < np.linalg.norm(np.asarray(full)) + 1e-6)


def test_differentiable():
    x, topi, topv, wg, wu, wd = _setup()

    def loss(w):
        y = _local_expert_compute(x, topi, topv, w, wu, wd, n_experts=4,
                                  k=2, capacity_factor=4.0, axis=None)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(wg)
    assert np.isfinite(np.asarray(g, np.float32)).all()
    assert float(jnp.abs(g).max()) > 0


def test_shard_map_path_matches_local(test_mesh):
    """shard_map over a size-1 'model' axis ≡ plain local compute."""
    from jax.sharding import PartitionSpec as P

    x, topi, topv, wg, wu, wd = _setup()
    local = _local_expert_compute(x, topi, topv, wg, wu, wd, n_experts=4,
                                  k=2, capacity_factor=4.0, axis=None)
    with compat.set_mesh(test_mesh):
        def fn(x_, ti, tv, g_, u_, d_):
            return _local_expert_compute(x_, ti, tv, g_, u_, d_,
                                         n_experts=4, k=2,
                                         capacity_factor=4.0, axis="model")
        sharded = jax.jit(compat.shard_map(
            fn,
            in_specs=(P("data", None), P("data", None), P("data", None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=P("data", None)
        ))(x, topi, topv, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(sharded), np.asarray(local),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 32), st.integers(1, 3), st.integers(0, 100))
def test_property_gates_bound_output(T, k, seed):
    """Output norm ≤ Σ gates × max expert gain × ||x|| (stability)."""
    x, topi, topv, wg, wu, wd = _setup(T=T, k=k, seed=seed)
    y = _local_expert_compute(x, topi, topv, wg, wu, wd, n_experts=4, k=k,
                              capacity_factor=8.0, axis=None)
    assert np.isfinite(np.asarray(y)).all()
