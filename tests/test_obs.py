"""Observability layer (repro.obs + docs/observability.md).

Pins the contracts the serving stack leans on:

  * metrics primitives under a fake clock — counters, gauges,
    fixed-bucket histograms with EXACT nearest-rank percentiles;
  * trace JSONL round trip: emit → load_trace → summarize reproduces
    the in-memory summary byte-for-byte;
  * deterministic span math: hand-built event streams give exact TTFT /
    per-token / queue-wait numbers (no wall clock involved);
  * tracing is FREE to turn on and off: with obs attached the engines'
    greedy tokens are IDENTICAL to an untraced run (all three engines,
    paged included), and with obs off they emit zero events and issue
    exactly the same jitted dispatches;
  * the three engines report ONE run_stats schema (satellite of the
    obs PR: stats() lives once in _EngineBase);
  * kernels.ops.dispatch_resolutions tallies every resolve_backend
    outcome;
  * quant-health sampling reports per-layer absmax / clip-fraction /
    Eq.-2 difficulty keyed like the autoplan telemetry.
"""

import functools
import json

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels import ops
from repro.models.api import get_model
from repro.obs import (ManualClock, MetricsRegistry, Observability,
                       QuantHealthSampler, Tracer, exact_percentile,
                       format_summary, load_trace, percentile_summary,
                       summarize)
from repro.serving.engine import (EngineConfig, PagedServingEngine,
                                  PerSlotServingEngine, Request,
                                  ServingEngine)

KEY = jax.random.PRNGKey(0)

ENGINES = {
    "per_slot": PerSlotServingEngine,
    "batched": ServingEngine,
    "paged": functools.partial(PagedServingEngine, page_size=4,
                               prefill_bucket=8),
}


@functools.lru_cache(maxsize=None)
def _setup():
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    return cfg, model, model.init(KEY, cfg)


def _requests(cfg, n=4, max_new=5):
    return [Request(uid=i,
                    prompt=np.random.default_rng(i).integers(
                        0, cfg.vocab_size, size=(3 + i,)),
                    max_new_tokens=max_new) for i in range(n)]


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


def test_manual_clock():
    clk = ManualClock()
    t0 = clk()
    clk.advance(1.5)
    assert clk() - t0 == pytest.approx(1.5)


def test_counter_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    with pytest.raises(ValueError):
        c.inc(-1)
    # create-on-first-use returns the SAME instrument
    assert reg.counter("c") is c
    g = reg.gauge("g")
    g.set(7)
    g.set(-2)
    assert g.value == -2


def test_exact_percentiles_nearest_rank():
    xs = sorted(float(v) for v in range(1, 101))    # 1..100
    assert exact_percentile(xs, 50) == 50.0
    assert exact_percentile(xs, 90) == 90.0
    assert exact_percentile(xs, 99) == 99.0
    assert exact_percentile(xs, 100) == 100.0
    s = percentile_summary(list(reversed(xs)))
    assert s["count"] == 100 and s["p50"] == 50.0 and s["p99"] == 99.0
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert percentile_summary([])["count"] == 0


def test_histogram_buckets_and_summary():
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    # per-bucket ≤-upper-bound counts; the trailing slot catches overflow
    assert h.bucket_counts == [1, 2, 1, 1]
    s = h.summary()
    assert s["count"] == 5 and s["max"] == 50.0
    assert s["p50"] == 0.5
    assert s["overflow"] == 1
    assert reg.histogram("h") is h


def test_registry_prefix_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("dispatch.decode.xla").inc(3)
    reg.counter("dispatch.prefill.xla").inc()
    reg.counter("other").inc()
    assert reg.counters_with_prefix("dispatch.") == {
        "decode.xla": 3.0, "prefill.xla": 1.0}
    snap = reg.snapshot()
    assert snap["counters"]["other"] == 1.0
    assert set(snap) == {"counters", "gauges", "histograms"}


# ---------------------------------------------------------------------------
# tracing + summary math
# ---------------------------------------------------------------------------


def _hand_events():
    """Two requests with hand-picked timestamps (no clock involved)."""
    return [
        {"ev": "submit", "ts": 0.0, "uid": 1, "prompt_len": 4},
        {"ev": "submit", "ts": 1.0, "uid": 2, "prompt_len": 6},
        {"ev": "admit", "ts": 2.0, "uid": 1, "slot": 0, "queue_wait_s": 2.0,
         "resumed": False},
        {"ev": "prefill", "ts": 3.0, "n_requests": 1, "n_tokens": 4,
         "rows": 1, "padded_len": 4, "dur_s": 1.0},
        {"ev": "first_token", "ts": 3.0, "uid": 1, "ttft_s": 3.0},
        {"ev": "admit", "ts": 4.0, "uid": 2, "slot": 1, "queue_wait_s": 3.0,
         "resumed": True},
        {"ev": "first_token", "ts": 5.0, "uid": 2, "ttft_s": 4.0},
        {"ev": "tick", "ts": 7.0, "tick": 1, "n_active": 2, "uids": [1, 2],
         "dur_s": 2.0, "alloc_dur_s": 0.5},
        {"ev": "tick", "ts": 10.0, "tick": 2, "n_active": 1, "uids": [1],
         "dur_s": 3.0, "alloc_dur_s": 1.0},
        {"ev": "preempt", "ts": 10.5, "uid": 2, "slot": 1, "n_generated": 2},
        {"ev": "retire", "ts": 11.0, "uid": 1, "prompt_len": 4,
         "decode_tokens": 3, "e2e_s": 11.0},
        # uid 2 resumes: queue wait runs from the REQUEUE at 10.5 (not
        # the original submit at 1.0), and the resume-prefill token gets
        # its own ``token`` event joining the per-token chain
        {"ev": "admit", "ts": 12.0, "uid": 2, "slot": 0, "queue_wait_s": 1.5,
         "resumed": True},
        {"ev": "token", "ts": 13.0, "uid": 2, "resumed": True},
        # front-end events ride the same schema
        {"ev": "shed", "ts": 13.5, "queue_depth": 5, "occupancy": 0.8,
         "score": 4.0},
        {"ev": "deadline", "ts": 14.0, "uid": 2, "deadline_s": 10.0,
         "n_streamed": 3},
        {"ev": "retire", "ts": 15.0, "uid": 2, "prompt_len": 6,
         "decode_tokens": 3, "e2e_s": 14.0, "cancelled": True},
    ]


def test_summarize_exact_numbers():
    s = summarize(_hand_events())
    assert s["counts"] == {"submitted": 2, "admitted": 3, "retired": 2,
                           "preemptions": 1, "resumes": 2, "decode_tokens": 4,
                           "prefill_tokens": 4, "ticks": 2, "cancelled": 1,
                           "deadline_expired": 1, "shed": 1, "failed": 0,
                           "faults_injected": 0, "guard_trips": 0,
                           "breaker_trips": 0, "breaker_recoveries": 0,
                           "watchdog_restarts": 0, "disconnects": 0}
    assert s["ttft_s"]["count"] == 2
    assert s["ttft_s"]["p50"] == 3.0 and s["ttft_s"]["max"] == 4.0
    # uid 1 token ts: 3, 7, 10 → deltas 4, 3;  uid 2: 5, 7, 13 → 2, 6
    assert s["per_token_s"]["count"] == 4
    assert s["per_token_s"]["min"] == 2.0 and s["per_token_s"]["max"] == 6.0
    assert s["queue_wait_s"]["mean"] == pytest.approx(6.5 / 3)
    assert s["tick_alloc_s"]["count"] == 2
    assert s["tick_decode_s"]["max"] == pytest.approx(2.0)  # 3.0 - 1.0
    assert s["e2e_s"]["count"] == 2 and s["e2e_s"]["max"] == 14.0
    # the human table renders without error and carries the counts,
    # front-end outcome, and end-to-end rows
    table = format_summary(s)
    assert "2 submitted" in table
    assert "front-end: 1 shed, 1 deadline-expired, 1 cancelled" in table
    assert "| end-to-end | 2 |" in table


def test_format_summary_no_frontend_line_when_clean():
    """Offline runs (no sheds/deadlines/cancels) keep the pre-front-end
    table layout: no front-end outcome line appears."""
    events = [ev for ev in _hand_events()
              if ev["ev"] not in ("shed", "deadline")
              and not ev.get("cancelled")]
    assert "front-end:" not in format_summary(summarize(events))


def test_tracer_jsonl_roundtrip(tmp_path):
    path = tmp_path / "trace.jsonl"
    with Tracer(str(path)) as tr:
        for ev in _hand_events():
            kind = ev.pop("ev")
            tr.emit(kind, **ev)
        mem = summarize(tr.events)
    loaded = load_trace(str(path))
    assert summarize(loaded) == mem
    # every line is standalone JSON with the schema fields
    with open(path) as fh:
        for line in fh:
            rec = json.loads(line)
            assert "ev" in rec and "ts" in rec


def test_tracer_rejects_unknown_event():
    tr = Tracer()
    with pytest.raises(ValueError):
        tr.emit("not_an_event", ts=0.0)


# ---------------------------------------------------------------------------
# engines under observability
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ENGINES))
def test_tracing_token_identical_and_zero_overhead(name):
    """obs on/off must not change a single sampled token, and obs OFF
    must cost nothing: zero trace events, identical dispatch counts."""
    cfg, model, params = _setup()
    cls = ENGINES[name]

    def serve(obs):
        eng = cls(model, params, cfg, max_slots=2, max_len=64, obs=obs)
        for r in _requests(cfg):
            eng.submit(r)
        done = eng.run(max_ticks=500)
        return eng, {r.uid: list(r.out_tokens) for r in done}

    eng_off, toks_off = serve(None)
    obs = Observability(clock=ManualClock())
    eng_on, toks_on = serve(obs)
    assert toks_on == toks_off
    # same jitted work either way
    assert eng_on.decode_dispatches == eng_off.decode_dispatches
    assert eng_on.prefill_dispatches == eng_off.prefill_dispatches
    assert eng_on.ticks == eng_off.ticks
    # obs off: nothing was traced anywhere
    assert eng_off.obs is None and eng_off._tracer is None
    # obs on: the trace tells the full request story
    s = obs.summary()
    assert s["counts"]["submitted"] == s["counts"]["retired"] == 4
    assert s["counts"]["decode_tokens"] == sum(
        len(t) for t in toks_on.values()) - 4   # first tokens from prefill
    assert s["ttft_s"]["count"] == 4
    assert s["per_token_s"]["count"] == s["counts"]["decode_tokens"]


def test_run_stats_schema_identical_across_engines():
    """ONE stats() implementation: every engine reports the same keys
    (the paged engine adds only its page-pool block on top)."""
    cfg, model, params = _setup()
    schemas = {}
    for name, cls in ENGINES.items():
        eng = cls(model, params, cfg, max_slots=2, max_len=64)
        for r in _requests(cfg, n=2, max_new=3):
            eng.submit(r)
        eng.run(max_ticks=200)
        schemas[name] = set(eng.run_stats)
    assert schemas["per_slot"] == schemas["batched"]
    pool_keys = {"page_size", "n_pages", "table_width", "pages_in_use",
                 "peak_pages_in_use", "page_occupancy",
                 "page_occupancy_peak", "paged_attention_backend",
                 "prefill_chunk", "chunked_prefill", "prefix", "spec"}
    assert schemas["paged"] == schemas["batched"] | pool_keys
    base_keys = {"requests", "prefill_tokens", "decode_tokens",
                 "per_request", "ticks", "decode_dispatches",
                 "prefill_dispatches", "dispatches_per_tick",
                 "kernel_backend", "dispatch_backends", "hbm_modeled_bytes"}
    assert base_keys <= schemas["batched"]


def test_engine_dispatch_attribution():
    """Per-backend dispatch counters match the legacy dispatch counts,
    and the obs run also models HBM bytes per dispatch kind."""
    cfg, model, params = _setup()
    obs = Observability(clock=ManualClock())
    eng = ENGINES["paged"](model, params, cfg, max_slots=2, max_len=64,
                           obs=obs)
    for r in _requests(cfg, n=3, max_new=4):
        eng.submit(r)
    eng.run(max_ticks=200)
    st = eng.run_stats
    assert st["dispatch_backends"]["decode.bf16"] == st["decode_dispatches"]
    assert st["dispatch_backends"]["prefill.bf16"] == st["prefill_dispatches"]
    # the paged engine also attributes its decode-attention executor
    pa = st["paged_attention_backend"]
    assert st["dispatch_backends"][f"paged_attention.{pa}"] == st["ticks"]
    assert st["hbm_modeled_bytes"]["decode.bf16"] > 0
    assert st["hbm_modeled_bytes"]["prefill.bf16"] > 0


def _preemption_run():
    """Tiny-pool paged run under a ManualClock: both prompts fill the
    pool exactly, so decode growth forces a preemption + resume."""
    cfg, model, params = _setup()
    clk = ManualClock()
    obs = Observability(clock=clk)
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                             page_size=4, prefill_bucket=8, n_pages=2,
                             obs=obs)
    for r in (Request(uid=0, prompt=np.arange(1, 5), max_new_tokens=3),
              Request(uid=1, prompt=np.arange(3, 7), max_new_tokens=3)):
        eng.submit(r)
    done = []
    for _ in range(100):
        clk.advance(1.0)
        eng.step()
        done += eng.pop_retired()
        if not eng.queue and not any(eng.slots):
            break
    assert not eng.queue and not any(eng.slots), "run did not drain"
    return eng, obs, done


def test_resumed_queue_wait_measured_from_requeue():
    """The preemption-era latency fix: a resumed request's queue wait
    runs from the REQUEUE (the preempt tick), not the original submit —
    otherwise the first service period is double-counted."""
    _, obs, _ = _preemption_run()
    events = obs.tracer.events
    preempts = [e for e in events if e["ev"] == "preempt"]
    assert preempts, "workload no longer preempts"
    for pre in preempts:
        resumed = next(e for e in events
                       if e["ev"] == "admit" and e["uid"] == pre["uid"]
                       and e.get("resumed") and e["ts"] >= pre["ts"])
        # submits happened at t=0, preempts strictly later: measuring
        # from the original submit would give queue_wait == ts
        assert resumed["queue_wait_s"] == pytest.approx(
            resumed["ts"] - pre["ts"])
        assert resumed["queue_wait_s"] < resumed["ts"]


def test_trace_token_counts_match_engine_under_preemption():
    """The resume-prefill token gets a ``token`` event, so the
    trace-derived token count equals the engine's: first_token +
    decode_tokens events == every token every client streamed."""
    eng, obs, done = _preemption_run()
    s = obs.summary()
    streamed = sum(len(r.out_tokens) for r in done)
    assert streamed == eng.stats()["decode_tokens"]
    assert s["counts"]["decode_tokens"] + s["ttft_s"]["count"] == streamed
    assert s["counts"]["resumes"] >= 1
    # every non-first streamed token contributes one inter-token gap
    assert s["per_token_s"]["count"] == s["counts"]["decode_tokens"]


# ---------------------------------------------------------------------------
# speculative-decoding accounting (docs/speculative.md)
# ---------------------------------------------------------------------------


def _spec_run(spec_k=4):
    cfg, model, params = _setup()
    clk = ManualClock()
    obs = Observability(clock=clk)
    eng = PagedServingEngine(
        model, params, cfg,
        config=EngineConfig(max_slots=2, max_len=32, page_size=4,
                            prefill_bucket=8, spec_k=spec_k, obs=obs))
    for r in _requests(cfg, n=3, max_new=5):
        eng.submit(r)
    done = []
    for _ in range(200):
        clk.advance(1.0)
        eng.step()
        done += eng.pop_retired()
        if not eng.queue and not any(eng.slots):
            break
    assert not eng.queue and not any(eng.slots), "run did not drain"
    return eng, obs, done


def test_spec_trace_token_counts_match_engine():
    """Accepted tokens past a tick's first ride extra ``token`` events,
    so trace-derived token accounting stays exact under speculation:
    first_token + decode_tokens events == every token every client
    streamed, per-uid per-token chains cover each ACCEPTED token, and
    the trace's spec block reconciles with the engine counters."""
    eng, obs, done = _spec_run()
    s = obs.summary()
    streamed = sum(len(r.out_tokens) for r in done)
    assert streamed == eng.stats()["decode_tokens"]
    assert s["counts"]["decode_tokens"] + s["ttft_s"]["count"] == streamed
    # every non-first streamed token contributes one inter-token gap
    assert s["per_token_s"]["count"] == s["counts"]["decode_tokens"]
    # a verify tick with > 1 accepted token actually occurred (otherwise
    # this test pins nothing beyond the plain-path one above)
    assert s["spec"]["emitted"] > s["spec"]["ticks"]
    # decode-phase tokens all went through verify ticks
    assert s["spec"]["emitted"] == s["counts"]["decode_tokens"]
    est = eng.stats()["spec"]
    assert s["spec"]["emitted"] == est["emitted_tokens"]
    assert s["spec"]["ticks"] == est["verify_dispatches"]
    assert s["spec"]["accepted"] == est["accepted"]


def test_summarize_spec_exact_and_table_line():
    """Hand-built spec events: exact aggregation plus the conditional
    ``spec:`` table line — both absent from plain-run summaries."""
    events = _hand_events()
    assert "spec" not in summarize(events)
    assert "spec:" not in format_summary(summarize(events))
    events += [
        {"ev": "spec", "ts": 7.0, "tick": 1, "drafted": 4, "accepted": 3,
         "rejected": 1, "emitted": 5, "n_rows": 2},
        {"ev": "spec", "ts": 10.0, "tick": 2, "drafted": 2, "accepted": 2,
         "rejected": 0, "emitted": 3, "n_rows": 1},
    ]
    s = summarize(events)
    assert s["spec"] == {"ticks": 2, "drafted": 6, "accepted": 5,
                         "rejected": 1, "emitted": 8,
                         "acceptance_rate": 5 / 6}
    assert ("spec: 6 drafted, 5 accepted (rate 0.833), 1 rejected, "
            "8 emitted over 2 verify ticks") in format_summary(s)


def test_dispatch_resolutions_tally():
    ops.dispatch_resolutions(reset=True)
    ops.resolve_backend("never")
    ops.resolve_backend("never")
    ops.resolve_backend("interpret")
    ops.resolve_backend("auto")
    counts = ops.dispatch_resolutions(reset=True)
    assert counts["xla"] >= 2 and counts["interpret"] == 1
    assert sum(counts.values()) == 4
    assert ops.dispatch_resolutions() == {}


# ---------------------------------------------------------------------------
# quant-health sampling
# ---------------------------------------------------------------------------


def test_quant_health_sampler_smoke():
    cfg, model, params = _setup()
    qh = QuantHealthSampler(model, params, cfg, every=2, bucket=8)
    assert qh.due(0) and qh.due(2) and not qh.due(3)
    ctx = np.arange(5) % cfg.vocab_size
    rec = qh.sample(2, 7, ctx)
    assert rec["uid"] == 7 and rec["context_len"] == 5
    assert rec["modules"], "no linear-input taps collected"
    for m, sig in rec["modules"].items():
        assert len(sig["absmax"]) == len(sig["difficulty"]) >= 1
        assert all(v >= 0 for v in sig["absmax"])
        assert sig["clip_fraction"] is None      # no calibration reference
    assert qh.samples == [rec]


def test_quant_health_clip_fraction_with_reference():
    from repro.serving.fold import collect_calibration

    cfg, model, params = _setup()
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    stats = collect_calibration(model, params, cfg, [{"tokens": toks}])
    qh = QuantHealthSampler(model, params, cfg, every=1, reference=stats,
                            bucket=8)
    rec = qh.sample(1, 0, np.arange(6) % cfg.vocab_size)
    clips = [c for sig in rec["modules"].values()
             for c in (sig["clip_fraction"] or [])]
    assert clips, "calibration reference given but no clip fractions"
    assert all(0.0 <= c <= 1.0 for c in clips)


def test_quant_health_in_engine_and_summary():
    """--quant-health wiring end to end: due() gates on ticks, events
    land in the trace, the summary aggregates per module."""
    cfg, model, params = _setup()
    qh = QuantHealthSampler(model, params, cfg, every=2, bucket=8)
    obs = Observability(clock=ManualClock(), quant_health=qh)
    eng = ENGINES["batched"](model, params, cfg, max_slots=2, max_len=64,
                             obs=obs)
    for r in _requests(cfg, n=2, max_new=4):
        eng.submit(r)
    eng.run(max_ticks=200)
    assert qh.samples, "sampler never fired"
    s = obs.summary()
    assert "quant_health" in s
    for m, agg in s["quant_health"].items():
        assert agg["samples"] >= 1 and agg["absmax_max"] >= 0
    assert "| module |" in format_summary(s)
