"""Pallas paged-attention decode kernel: parity vs the XLA gather path.

The kernel (kernels/paged_attention.py) must match the
``paged_view`` + reference-attention composition the engines fall back
to, across:

  * bf16 AND int8-KV pools (in-kernel dequant from the paged scale
    leaves);
  * page-boundary lengths (exactly at / one past a page edge);
  * ragged per-slot lengths (every slot at its own depth, dead table
    entries skipped);
  * GQA group sizes (MQA g=hq, grouped, MHA g=1).

Runs through the Pallas INTERPRETER on CPU (the same mode
``use_kernels="interpret"`` selects in the engines — CI's kernels job
exercises exactly this path); ``tests/test_serving_paged.py`` pins the
end-to-end greedy token-identity with the kernel enabled.  Also pins
``flash_decode`` under per-slot (b,) length vectors (the batched
engine's flash path) and the ``paged_attn_backend`` dispatch table.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.kernels import ops, ref
from repro.kernels import paged_attention as pa
from repro.models import common as cm

KEY = jax.random.PRNGKey(0)

# the engine's actual KV quantizer — parity must cover what the serving
# pool really stores, not a lookalike scheme
_quant_pool = cm._quant_kv


def _make_case(b, hq, hkv, d, page, width, lengths, *, quantized=False,
               seed=0):
    """Random pool + a scattered (non-identity) page table.

    Pages are assigned logically-contiguously per slot (the engine's
    allocation invariant) but to arbitrary physical pages, so parity
    failures in the table indirection cannot hide behind an identity
    layout.  Unassigned logical pages are -1.
    """
    rng = np.random.default_rng(seed)
    n_pages = b * width + 3
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    kp = jax.random.normal(ks[0], (n_pages, page, hkv, d)).astype(jnp.bfloat16)
    vp = jax.random.normal(ks[1], (n_pages, page, hkv, d)).astype(jnp.bfloat16)
    q = jax.random.normal(ks[2], (b, 1, hq, d)).astype(jnp.bfloat16)
    perm = rng.permutation(n_pages)
    table = np.full((b, width), -1, np.int32)
    nxt = 0
    for i, ln in enumerate(lengths):
        for j in range(-(-int(ln) // page)):
            table[i, j] = perm[nxt]
            nxt += 1
    layer_kv = {"k": kp, "v": vp}
    if quantized:
        kq, ksc = _quant_pool(kp)
        vq, vsc = _quant_pool(vp)
        layer_kv = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
    return q, layer_kv, jnp.asarray(table), jnp.asarray(lengths, jnp.int32)


def _parity(q, layer_kv, table, lengths, atol=2e-2):
    out = ops.paged_attention(q, layer_kv, table, lengths, interpret=True)
    want = ref.paged_attention_ref(q, layer_kv, table, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)
    # and vs the exact engine fallback: paged_view + attention_scores
    kc, vc = cm.paged_view(layer_kv, table)
    want2 = cm.attention_scores(q, kc, vc, causal=False, length=lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want2, np.float32), atol=atol)


# ---------------------------------------------------------------------------
# parity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8kv"])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (4, 1)],
                         ids=["mha", "gqa4", "mqa"])
def test_parity_ragged_lengths(hq, hkv, quantized):
    """Per-slot ragged depths across GQA group sizes × pool dtypes."""
    q, kv, table, lens = _make_case(4, hq, hkv, 16, page=4, width=5,
                                    lengths=[1, 7, 20, 13],
                                    quantized=quantized, seed=1)
    _parity(q, kv, table, lens)


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8kv"])
@pytest.mark.parametrize("length", [4, 5, 8, 9, 16],
                         ids=["at_edge", "past_edge", "at_edge2",
                              "past_edge2", "full"])
def test_parity_page_boundaries(length, quantized):
    """Lengths exactly at and one past a page edge: the masked tail of a
    partially filled page and the first row of a fresh page are where a
    wrong prefix mask or off-by-one page index would show."""
    q, kv, table, lens = _make_case(2, 4, 2, 16, page=4, width=4,
                                    lengths=[length, max(length - 1, 1)],
                                    quantized=quantized, seed=2)
    _parity(q, kv, table, lens)


def test_scalar_length_broadcasts():
    """attn_apply's single-sequence contract passes a SCALAR valid
    length; the wrapper must broadcast it per slot, not reshape-crash."""
    q, kv, table, lens = _make_case(2, 4, 2, 16, page=4, width=3,
                                    lengths=[9, 9], seed=9)
    out = ops.paged_attention(q, kv, table, 9, interpret=True)
    want = ref.paged_attention_ref(q, kv, table, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)


def test_parity_under_jit_and_odd_dims():
    """Jitted call, non-square head dims, width-1 table."""
    q, kv, table, lens = _make_case(3, 6, 3, 8, page=2, width=1,
                                    lengths=[1, 2, 2], seed=3)
    out = jax.jit(functools.partial(ops.paged_attention, interpret=True)
                  )(q, kv, table, lens)
    want = ref.paged_attention_ref(q, kv, table, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)


def test_dead_table_entries_are_skipped():
    """Entries past a slot's pages are -1; poisoning every unassigned
    physical page with huge values must not leak into the output (the
    kernel's pl.when gate + length mask)."""
    q, kv, table, lens = _make_case(2, 4, 2, 16, page=4, width=4,
                                    lengths=[5, 3], seed=4)
    used = np.unique(np.asarray(table)[np.asarray(table) >= 0])
    poison = np.setdiff1d(np.arange(kv["k"].shape[0]), used)
    kv2 = dict(kv,
               k=kv["k"].at[poison].set(jnp.asarray(300.0, kv["k"].dtype)),
               v=kv["v"].at[poison].set(jnp.asarray(300.0, kv["v"].dtype)))
    out = ops.paged_attention(q, kv2, table, lens, interpret=True)
    want = ops.paged_attention(q, kv, table, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=1e-6)


def test_zero_length_row_returns_finite():
    """Inactive slots (length 0, all pages -1) decode garbage by
    contract — but it must be FINITE garbage (zeros), not NaN from an
    all-masked softmax."""
    q, kv, table, lens = _make_case(2, 4, 2, 16, page=4, width=2,
                                    lengths=[6, 0], seed=5)
    out = np.asarray(ops.paged_attention(q, kv, table, lens, interpret=True),
                     np.float32)
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out[1], 0.0, atol=1e-6)


def test_one_pallas_call_per_invocation(monkeypatch):
    """ONE kernel launch per layer invocation (the fused contract)."""
    calls = []
    orig = pa._pallas_call

    def counting(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(pa, "_pallas_call", counting)
    q, kv, table, lens = _make_case(2, 4, 2, 16, page=4, width=2,
                                    lengths=[3, 6], seed=6)
    ops.paged_attention(q, kv, table, lens, interpret=True)
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# dispatch table (common.paged_attn_backend)
# ---------------------------------------------------------------------------


def test_paged_attn_backend_dispatch(monkeypatch):
    """The resolver shares ops.resolve_backend with the linears; MLA /
    bf16_io / pure-SSM configs pin the documented fallbacks."""
    dense = get_config("stablelm_3b").reduced()
    assert cm.paged_attn_backend(dense, None) == "xla"          # CPU auto
    assert cm.paged_attn_backend(
        dense, QuantPolicy(use_kernels="interpret")) == "interpret"
    assert cm.paged_attn_backend(
        dense, QuantPolicy(use_kernels="never")) == "xla"
    monkeypatch.setattr(ops, "use_pallas", lambda backend="auto": True)
    assert cm.paged_attn_backend(dense, None) == "pallas"       # TPU auto
    mla = get_config("deepseek_v2_lite_16b").reduced()
    assert cm.paged_attn_backend(
        mla, QuantPolicy(use_kernels="interpret")) == "xla"     # latent gather
    import dataclasses

    bf16io = dataclasses.replace(dense, attn_bf16_io=True)
    assert cm.paged_attn_backend(
        bf16io, QuantPolicy(use_kernels="interpret")) == "xla"
    ssm = get_config("mamba2_780m").reduced()
    assert cm.paged_attn_backend(ssm, None) == "none"


# ---------------------------------------------------------------------------
# flash_decode under per-slot length vectors (satellite)
# ---------------------------------------------------------------------------


def _dense_case(b, S, hq, hkv, d, *, quantized=False, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, S, hkv, d)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, S, hkv, d)).astype(jnp.bfloat16)
    layer_kv = {"k": k, "v": v}
    if quantized:
        kq, ksc = _quant_pool(k)
        vq, vsc = _quant_pool(v)
        layer_kv = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
    return q, layer_kv


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8kv"])
def test_flash_decode_per_slot_length_vector(quantized):
    """flash_decode with a (b,) depth vector == the masked reference at
    each row's own depth — the batched engine's ONE (max_slots, 1) tick
    is now flash-eligible, not just scalar-length callers.  (The autouse
    test mesh provides the 'model' axis the shard_map needs.)"""
    q, layer_kv = _dense_case(3, 16, 4, 2, 16, quantized=quantized, seed=7)
    valid = jnp.array([5, 16, 1], jnp.int32)
    out = cm.flash_decode(q, layer_kv, valid, dp_spec=None)
    kc, vc = cm.cache_read(layer_kv)
    want = cm.attention_scores(q, kc, vc, causal=False, length=valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)


def test_flash_decode_scalar_length_still_exact():
    """Scalar depths (the original contract) broadcast to the vector
    path unchanged."""
    q, layer_kv = _dense_case(2, 8, 4, 4, 16, seed=8)
    out = cm.flash_decode(q, layer_kv, 6, dp_spec=None)
    want = cm.attention_scores(q, layer_kv["k"], layer_kv["v"], causal=False,
                               length=6)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)
