"""Prefix caching over the paged KV pool (docs/serving.md §Prefix
caching).

Pins the tentpole's contracts:

  * **suffix-only re-prefill** (the acceptance pin): with two requests
    sharing a ≥2-page prompt prefix, the second admit dispatches prefill
    only for the non-shared suffix — pinned via ``run_stats``
    prefill-token counts — with output tokens BIT-IDENTICAL to a
    cache-off run;
  * correctness matrix: cache-hit streams bit-identical to cache-off
    for dense bf16 / W8A8 / int8-KV, one-shot and chunked prefill; the
    moe family (no ``supports_chunked_prefill``) falls back to
    cache-off behavior with ``prefix.enabled == False``;
  * **COW isolation**: a full-prefix-match request clones its final
    shared page before the last-token re-prefill writes it, so a
    divergent continuation never perturbs a co-resident (or the cache
    itself — a later identical request still hits and still matches);
  * **refcount partition**: free ∪ cached-unreferenced ∪ referenced is
    a disjoint cover of ``range(n_pages)`` — held after every workload
    here, including the seeded hypothesis chaos plans from
    tests/test_resilience.py (no page leaked or double-freed);
  * LRU eviction reclaims cached pages under pool pressure;
  * preemption and the front-end watchdog restart both resume
    shared-prefix requests token-exact (shared pages survive a
    co-resident's preemption; a rebuilt engine re-admits from
    ``_resume_ctx`` against an empty cache).
"""

import asyncio

import numpy as np
import pytest

from repro.kernels import ops
from repro.obs import Observability
from repro.resilience.faults import FaultPlan, FaultSpec
from repro.serving.engine import EngineConfig, PagedServingEngine, Request
from repro.serving.frontend import ServingFrontend, http_generate
# shared cross-suite harness (tests/_engine_matrix.py)
from tests._engine_matrix import assert_partition as _assert_partition
from tests._engine_matrix import serve as _serve
from tests._engine_matrix import setup
from tests._hypothesis_support import given, settings, st

PAGE = 4


def _setup(arch: str, use_kernels: str | None = None):
    """(cfg, model, params, policy); ``use_kernels=None`` → bf16, else a
    W8A8 folded model ("never" = pure XLA, "interpret" = the kernel path
    with a fallback jit — what the chaos plans need so dispatch_raise is
    recoverable)."""
    return setup(arch, quantized=use_kernels is not None,
                 use_kernels=use_kernels or "never")


def _engine(cfg, model, params, *, policy=None, prefix=True, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 32)
    return PagedServingEngine(
        model, params, cfg,
        config=EngineConfig(policy=policy, page_size=PAGE, prefill_bucket=8,
                            prefix_cache=prefix, **kw))


def _sys(cfg, pages=2):
    """The shared system prefix: PAGES full pages of tokens."""
    return np.random.default_rng(99).integers(0, cfg.vocab_size,
                                              size=(pages * PAGE,))


def _shared_reqs(cfg, n=2, max_new=4, pages=2):
    sys_prompt = _sys(cfg, pages)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         np.random.default_rng(50 + i).integers(
                             0, cfg.vocab_size, size=(3 + i,))]),
                    max_new_tokens=max_new) for i in range(n)]


def _seed(cfg, uid=100):
    """A request whose prompt IS the bare system prefix: running it to
    completion registers the shared pages (same-round co-admissions
    never share, so tests seed the cache explicitly first)."""
    return Request(uid=uid, prompt=_sys(cfg), max_new_tokens=1)


# ---------------------------------------------------------------------------
# the acceptance pin: suffix-only prefill, bit-identical tokens
# ---------------------------------------------------------------------------


def test_second_admit_prefills_suffix_only():
    """Two requests share a 2-page (8-token) prefix and are admitted
    sequentially: the second dispatches prefill ONLY for its 4-token
    suffix (run_stats prefill-token pin), tokens bit-identical to
    cache-off."""
    cfg, model, params, _ = _setup("stablelm_3b")

    def serve(prefix):
        eng = _engine(cfg, model, params, prefix=prefix)
        a, b = _shared_reqs(cfg, n=2)           # prompts: 8+3 and 8+4
        toks = _serve(eng, [a])
        toks.update(_serve(eng, [b]))
        return eng, toks

    eng_off, toks_off = serve(False)
    eng_on, toks_on = serve(True)
    assert toks_on == toks_off
    assert eng_off.run_stats["prefill_tokens"] == 11 + 12
    assert eng_on.run_stats["prefill_tokens"] == 11 + 4   # suffix only
    px = eng_on.run_stats["prefix"]
    assert px["enabled"]
    assert px["hits"] == 1 and px["misses"] == 1
    assert px["shared_pages"] == 2
    assert px["saved_prefill_tokens"] == 8
    assert px["saved_prefill_flops"] > 0 and px["saved_hbm_bytes"] > 0
    # cache-off engine reports the block too, disabled and all-zero
    off = eng_off.run_stats["prefix"]
    assert not off["enabled"] and off["hits"] == 0
    _assert_partition(eng_on)


# ---------------------------------------------------------------------------
# correctness matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [None, 8], ids=["oneshot", "chunked"])
@pytest.mark.parametrize("precision", ["bf16", "w8a8", "int8kv"])
def test_cache_hit_streams_bit_identical(precision, chunk):
    """Seed the cache, then co-admit two shared-prefix requests: both
    hit, and every stream matches the cache-off run token for token —
    bf16, W8A8 (folded scales), int8 KV (scale leaves ride the page
    clone), one-shot and chunked prefill."""
    cfg, model, params, policy = _setup(
        "stablelm_3b", "never" if precision == "w8a8" else None)
    kv = 8 if precision == "int8kv" else None

    def serve(prefix):
        eng = _engine(cfg, model, params, policy=policy, prefix=prefix,
                      kv_bits=kv, prefill_chunk=chunk)
        toks = _serve(eng, [_seed(cfg)])
        toks.update(_serve(eng, _shared_reqs(cfg, n=2)))
        return eng, toks

    eng_off, toks_off = serve(False)
    eng_on, toks_on = serve(True)
    assert toks_on == toks_off
    px = eng_on.run_stats["prefix"]
    assert px["hits"] == 2 and px["misses"] == 1     # seed was the miss
    _assert_partition(eng_on)


def test_moe_falls_back_to_miss():
    """The MoE family has no chunked-prefill continuation path, so the
    cache gates itself off: identical serving behavior, ``enabled``
    False, zero counters."""
    cfg, model, params, _ = _setup("deepseek_v2_lite_16b")

    def serve(prefix):
        eng = _engine(cfg, model, params, prefix=prefix)
        toks = _serve(eng, [_seed(cfg)])
        toks.update(_serve(eng, _shared_reqs(cfg, n=2)))
        return eng, toks

    eng_off, toks_off = serve(False)
    eng_on, toks_on = serve(True)
    assert toks_on == toks_off
    px = eng_on.run_stats["prefix"]
    assert not px["enabled"]
    assert px["hits"] == 0 and px["misses"] == 0 and px["cached_pages"] == 0
    assert eng_on.pages_in_use == 0            # nothing retained
    assert sorted(eng_on._free) == list(range(eng_on.n_pages))


# ---------------------------------------------------------------------------
# copy-on-write
# ---------------------------------------------------------------------------


def test_cow_isolation():
    """A FULL-prefix-match request re-prefills its last token, whose KV
    write lands in the final shared page — the engine clones that page
    first (COW).  The divergent co-resident and the cache itself are
    unperturbed: every stream matches cache-off, and a later identical
    request still hits and reproduces the first request's tokens."""
    cfg, model, params, _ = _setup("stablelm_3b")
    sys_prompt = _sys(cfg)

    def full(uid, n):
        return Request(uid=uid, prompt=sys_prompt.copy(), max_new_tokens=n)

    def serve(prefix):
        eng = _engine(cfg, model, params, prefix=prefix)
        toks = _serve(eng, [_seed(cfg)])
        # full match (uid 0) co-resident with a divergent hit (uid 1)
        toks.update(_serve(eng, [full(0, 5), _shared_reqs(cfg, n=2)[1]]))
        toks.update(_serve(eng, [full(2, 5)]))   # cache still intact?
        return eng, toks

    eng_off, toks_off = serve(False)
    eng_on, toks_on = serve(True)
    assert toks_on == toks_off
    assert toks_on[2] == toks_on[0]              # identical prompt, identical
    px = eng_on.run_stats["prefix"]
    assert px["hits"] == 3                       # uids 0, 1, 2
    assert px["cow_copies"] == 2                 # both full matches cloned
    _assert_partition(eng_on)


# ---------------------------------------------------------------------------
# eviction
# ---------------------------------------------------------------------------


def test_lru_eviction_under_pool_pressure():
    """A pool too small to cache every retired prefix: the LRU tier
    evicts cached-unreferenced pages instead of stalling admission, and
    every request still serves bit-identically to cache-off."""
    cfg, model, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(2 * PAGE,)),
                    max_new_tokens=2) for i in range(4)]

    def serve(prefix):
        eng = _engine(cfg, model, params, prefix=prefix, max_slots=1,
                      n_pages=6)
        toks = {}
        for r in reqs:
            toks.update(_serve(
                eng, [Request(uid=r.uid, prompt=r.prompt.copy(),
                              max_new_tokens=r.max_new_tokens)]))
        return eng, toks

    eng_off, toks_off = serve(False)
    eng_on, toks_on = serve(True)
    assert toks_on == toks_off
    px = eng_on.run_stats["prefix"]
    assert px["evictions"] > 0
    assert px["cached_pages"] <= eng_on.n_pages
    _assert_partition(eng_on)


# ---------------------------------------------------------------------------
# preemption with shared pages
# ---------------------------------------------------------------------------


def test_preemption_resume_with_shared_pages():
    """A tight pool forces a full stall while two shared-prefix requests
    decode: the youngest is preempted (its refs released — the shared
    pages SURVIVE because the co-resident still holds them), resumes
    against the cache, and every stream matches a roomy cache-off run."""
    cfg, model, params, _ = _setup("stablelm_3b")

    def reqs():
        # EQUAL-length prompts: both slots cross page boundaries on the
        # same tick, so pool exhaustion stalls both at once (a full
        # stall is what triggers _preempt_youngest)
        return [Request(uid=i,
                        prompt=np.concatenate(
                            [_sys(cfg),
                             np.random.default_rng(60 + i).integers(
                                 0, cfg.vocab_size, size=(3,))]),
                        max_new_tokens=14) for i in range(2)]

    def serve(prefix, n_pages):
        obs = Observability()
        eng = _engine(cfg, model, params, prefix=prefix, n_pages=n_pages,
                      obs=obs)
        toks = _serve(eng, [_seed(cfg)])
        toks.update(_serve(eng, reqs(), max_ticks=500))
        return eng, obs, toks

    eng_off, _, toks_off = serve(False, n_pages=None)    # roomy reference
    eng_on, obs, toks_on = serve(True, n_pages=8)
    assert toks_on == toks_off
    preempts = [e for e in obs.tracer.events if e["ev"] == "preempt"]
    assert preempts                              # the stall actually happened
    px = eng_on.run_stats["prefix"]
    assert px["hits"] >= 3                       # 2 admits + ≥1 resume
    _assert_partition(eng_on)


# ---------------------------------------------------------------------------
# chaos: the partition invariant under random fault plans
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_chaos_partition_invariant(seed):
    """The resilience suite's seeded chaos plans (NaN logits, dispatch
    raise, page-alloc fail, slow ticks + a random mid-run cancel) on a
    prefix-sharing workload: every request retires exactly once and the
    refcount partition holds — no page leaked or double-freed."""
    ops.breaker.reset()
    try:
        rng = np.random.default_rng(seed)
        plan = FaultPlan.random(seed, n_faults=4,
                                sites=("nan_logits", "dispatch_raise",
                                       "page_alloc_fail", "slow_tick"),
                                uids=range(4), max_at=12)
        # the quantized-interpret engine: dispatch_raise is recoverable
        # through the kernel circuit breaker's fallback jit
        cfg, model, params, policy = _setup("stablelm_3b", "interpret")
        eng = _engine(cfg, model, params, policy=policy, max_slots=2,
                      n_pages=12, faults=plan, nan_guard=True)
        _serve(eng, [_seed(cfg)])
        reqs = _shared_reqs(cfg, n=2, max_new=5) + [
            Request(uid=2 + i,
                    prompt=np.random.default_rng(200 + i).integers(
                        0, cfg.vocab_size, size=(5 + i,)),
                    max_new_tokens=5) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        cancel_uid = int(rng.integers(4))
        cancel_tick = int(rng.integers(1, 6))
        for _ in range(300):
            if not (eng.queue or any(s is not None for s in eng.slots)):
                break
            eng.step()
            if eng.ticks == cancel_tick:
                eng.cancel(cancel_uid)
        done = {r.uid: r for r in eng.pop_retired()}
        assert sorted(u for u in done if u < 100) == list(range(4))
        assert not any(eng.slots)
        _assert_partition(eng)
    finally:
        ops.breaker.reset()


# ---------------------------------------------------------------------------
# watchdog restart with shared pages
# ---------------------------------------------------------------------------


def test_watchdog_resume_with_shared_pages():
    """An engine crash mid-decode on a prefix-cached engine: the
    front-end watchdog rebuilds from the factory (EMPTY cache) and
    resumes the in-flight request via ``_resume_ctx`` — the client
    stream is token-exact vs an uninterrupted run, and the rebuilt
    engine's page accounting is clean."""
    cfg, model, params, _ = _setup("stablelm_3b")
    prompt = np.concatenate([_sys(cfg), np.asarray([3, 1, 4])])

    ref_eng = _engine(cfg, model, params)
    _serve(ref_eng, [_seed(cfg)])
    ref = _serve(ref_eng, [Request(uid=0, prompt=prompt.copy(),
                                   max_new_tokens=6)])[0]

    obs = Observability()
    plan = FaultPlan([FaultSpec("dispatch_raise", op="decode", at=2)])

    def factory():
        return _engine(cfg, model, params, obs=obs)

    eng = _engine(cfg, model, params, obs=obs, faults=plan)
    _serve(eng, [_seed(cfg)])

    async def go():
        async with ServingFrontend(eng, host="127.0.0.1", port=0,
                                   engine_factory=factory,
                                   watchdog_interval_s=0.05) as fe:
            r = await http_generate("127.0.0.1", fe.port,
                                    {"prompt": prompt.tolist(),
                                     "max_new_tokens": 6})
            final = fe.engine
        return r, final

    r, final = asyncio.run(go())
    assert r["status"] == 200 and r["body"]["failed"] is False
    assert r["tokens"] == ref
    wd = [e["action"] for e in obs.tracer.events if e["ev"] == "watchdog"]
    assert "restart" in wd
    _assert_partition(final)
