"""Unit + property tests for the symmetric RTN quantizer (paper Eq. 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core.quantizer import (
    QuantConfig,
    dequantize,
    fake_quantize,
    pack_int4,
    qmax,
    quantize,
    unpack_int4,
)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("gran", ["per_token", "per_channel", "per_tensor"])
def test_roundtrip_error_bound(bits, gran):
    """RTN error is bounded by Δ/2 per element (Eq. 1)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 3.0
    cfg = QuantConfig(bits=bits, granularity=gran)
    q, scale = quantize(x, cfg)
    err = jnp.abs(x - dequantize(q, scale))
    assert float(err.max()) <= float(scale.max()) / 2 + 1e-6


def test_codes_in_grid():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32)) * 100
    for bits in (4, 8):
        q, _ = quantize(x, QuantConfig(bits=bits))
        lim = qmax(bits)
        assert int(q.min()) >= -lim and int(q.max()) <= lim


def test_absmax_is_exact():
    """max|X| per token maps exactly to ±levels (no clipping, §III-B)."""
    x = jnp.array([[1.0, -7.0, 3.0], [0.5, 0.25, -0.125]])
    q, scale = quantize(x, QuantConfig(bits=4, granularity="per_token"))
    np.testing.assert_array_equal(np.abs(np.asarray(q)).max(axis=1), [7, 7])


def test_zero_row_safe():
    x = jnp.zeros((4, 16))
    q, scale = quantize(x, QuantConfig(bits=4))
    assert np.isfinite(np.asarray(scale)).all()
    assert (np.asarray(q) == 0).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 60), st.integers(1, 40), st.sampled_from([4, 8]))
def test_property_quant_error_below_uniform_bound(rows, cols, bits):
    """Quantization noise variance ≤ Δ²/12·(1+slack) (paper §II-B)."""
    key = jax.random.PRNGKey(rows * 100 + cols)
    x = jax.random.normal(key, (rows, cols))
    cfg = QuantConfig(bits=bits, granularity="per_token")
    q, scale = quantize(x, cfg)
    err = np.asarray(x - dequantize(q, scale))
    step = np.asarray(scale)
    assert (np.abs(err) <= step / 2 + 1e-7).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 64))
def test_property_pack_unpack_roundtrip(rows, half_cols):
    key = jax.random.PRNGKey(rows * 977 + half_cols)
    q = jax.random.randint(key, (rows, 2 * half_cols), -8, 8, jnp.int8)
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(q))),
                                  np.asarray(q))


def test_fake_quantize_idempotent_on_grid():
    """Values already on the grid survive fake-quant exactly."""
    cfg = QuantConfig(bits=4, granularity="per_tensor")
    x = jnp.arange(-7, 8, dtype=jnp.float32)[None] / 7.0
    y = fake_quantize(x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_stochastic_rounding_unbiased():
    cfg = QuantConfig(bits=8, granularity="per_tensor", stochastic=True)
    x = jnp.full((200, 200), 0.3)
    keys = jax.random.split(jax.random.PRNGKey(3), 8)
    means = [float(fake_quantize(x, cfg, key=k).mean()) for k in keys]
    assert abs(np.mean(means) - 0.3) < 2e-3
