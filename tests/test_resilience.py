"""Resilience subsystem (docs/resilience.md): fault plane, numerical
guards, kernel circuit breaker, chaos invariants.

The contracts this suite pins:

  * the fault plane is DETERMINISTIC — per-spec arrival windows with
    uid/op filters, a seeded ``FaultPlan.random`` that replays
    identically, JSON round trip for ``serve.py --fault-plan``;
  * zero overhead when off — ``faults=None`` + ``nan_guard`` runs are
    token-identical to the seed engine with identical dispatch counts;
  * per-request guard isolation — a NaN-poisoned slot retires ``failed``
    with its pages freed and a quant-health-style escalation, while
    every surviving request's tokens are BIT-IDENTICAL to the
    fault-free run;
  * the kernel circuit breaker trips ONE failing dispatch to the XLA
    fallback jit (the tick completes), rides the fallback through the
    cooldown, then recovers on the half-open probe — with pinned
    counters, trace events and ``stats()`` surfacing;
  * chaos: under seeded random fault schedules + cancels, no request is
    lost or double-retired, accounting is exact, every page returns to
    the free list, and the JSONL trace replays to the identical summary
    through ``repro.obs`` — across all four model families.
"""

import functools
import json
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.kernels import ops
from repro.models.api import get_model
from repro.obs import Observability, QuantHealthSampler, load_trace, summarize
from repro.resilience.faults import SITES, FaultInjected, FaultPlan, FaultSpec
from repro.serving.engine import (PagedServingEngine, PerSlotServingEngine,
                                  Request, ServingEngine)
from repro.serving.fold import collect_calibration, fold_quantize
from tests._hypothesis_support import given, settings, st

KEY = jax.random.PRNGKey(0)

FAMILY_ARCHS = {
    "dense": "stablelm_3b",
    "moe": "deepseek_v2_lite_16b",
    "ssm": "mamba2_780m",
    "hybrid": "zamba2_12b",
}


@functools.lru_cache(maxsize=None)
def _setup(arch: str = "stablelm_3b", use_kernels: str | None = None):
    """(cfg, model, params, policy); ``use_kernels=None`` → bf16."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    policy = None
    if use_kernels is not None:
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        stats = collect_calibration(model, params, cfg, [{"tokens": toks}])
        policy = QuantPolicy(weight_bits=8, act_bits=8, pack_weights=False,
                             use_kernels=use_kernels)
        params = fold_quantize(params, cfg, policy=policy, stats=stats)
    return cfg, model, params, policy


def _engine(cls=PagedServingEngine, arch="stablelm_3b", use_kernels=None,
            **kw):
    cfg, model, params, policy = _setup(arch, use_kernels)
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 32)
    if cls is PagedServingEngine:
        kw.setdefault("page_size", 4)
        kw.setdefault("prefill_bucket", 8)
    return cls(model, params, cfg, policy=policy, **kw)


def _prompts(n, arch="stablelm_3b"):
    cfg, _, _, _ = _setup(arch)
    return [np.random.default_rng(100 + i).integers(
        0, cfg.vocab_size, size=(3 + i % 4,)) for i in range(n)]


def _reqs(n, max_new=5, arch="stablelm_3b"):
    return [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(_prompts(n, arch))]


def _run(eng, reqs, max_ticks=300):
    for r in reqs:
        eng.submit(r)
    return {r.uid: r for r in eng.run(max_ticks=max_ticks)}


@functools.lru_cache(maxsize=None)
def _ref_tokens(cls_name: str, use_kernels: str | None, n=4, max_new=5,
                arch="stablelm_3b", **kw):
    """Fault-free reference tokens for the IDENTICAL engine shape (batch
    shape perturbs reduction order → greedy near-ties, so the twin run
    must match max_slots etc. exactly)."""
    cls = {"paged": PagedServingEngine, "batched": ServingEngine,
           "perslot": PerSlotServingEngine}[cls_name]
    done = _run(_engine(cls, arch, use_kernels, **kw),
                _reqs(n, max_new, arch))
    return {u: tuple(r.out_tokens) for u, r in done.items()}


@pytest.fixture
def clean_breaker():
    """Process-wide breaker: isolate and restore around breaker tests."""
    ops.breaker.reset()
    saved = ops.breaker.cooldown
    yield ops.breaker
    ops.breaker.cooldown = saved
    ops.breaker.reset()


# -- fault plane -----------------------------------------------------------


def test_fault_spec_validates_site():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("flux_capacitor")


def test_fire_arrival_windows_and_filters():
    plan = FaultPlan([
        FaultSpec("nan_logits", at=2, count=2, uid=7),
        FaultSpec("dispatch_raise", op="decode"),
    ])
    # uid filter: non-matching uids never advance the spec's arrivals
    assert plan.fire("nan_logits", uid=3) is None
    assert plan.fire("nan_logits", uid=7) is None          # arrival 0
    assert plan.fire("nan_logits", uid=7) is None          # arrival 1
    assert plan.fire("nan_logits", uid=7) is not None      # arrival 2: fires
    assert plan.fire("nan_logits", uid=7) is not None      # arrival 3: fires
    assert plan.fire("nan_logits", uid=7) is None          # window closed
    # op filter + default window (at=0, count=1): first match only
    assert plan.fire("dispatch_raise", op="prefill") is None
    spec = plan.fire("dispatch_raise", op="decode")
    assert spec is not None and spec.op == "decode"
    assert plan.fire("dispatch_raise", op="decode") is None
    assert [f["site"] for f in plan.fired] == ["nan_logits", "nan_logits",
                                               "dispatch_raise"]
    assert plan.fired[0]["arrival"] == 2 and plan.fired[2]["arrival"] == 0


def test_plan_json_round_trip_and_seeded_random():
    plan = FaultPlan.random(seed=42, n_faults=4, uids=(0, 1, 2),
                            delay_s=0.25)
    again = FaultPlan.random(seed=42, n_faults=4, uids=(0, 1, 2),
                             delay_s=0.25)
    assert plan.specs == again.specs                  # same seed, same plan
    assert plan.specs != FaultPlan.random(seed=43, n_faults=4,
                                          uids=(0, 1, 2)).specs
    back = FaultPlan.from_json(plan.to_json())
    assert back.specs == plan.specs
    assert all(s.site in SITES for s in back.specs)
    # serde is declarative only: arrival state does not travel
    assert back._arrivals == [0] * len(back.specs) and back.fired == []


def test_fault_injected_carries_site():
    exc = FaultInjected("dispatch_raise", "decode")
    assert exc.site == "dispatch_raise"
    assert "injected fault at dispatch_raise: decode" in str(exc)


# -- circuit breaker unit --------------------------------------------------


def test_breaker_state_machine(clean_breaker):
    b = clean_breaker
    b.cooldown = 2
    assert b.allow_native("decode")                   # closed
    assert b.record_success("decode") is False        # success while closed
    b.record_failure("decode")
    st_ = b.state()["decode"]
    assert st_["state"] == "open" and st_["trips"] == 1
    assert not b.allow_native("decode")               # cooldown 2 → refuse
    assert b.allow_native("decode")                   # countdown → half_open
    assert b.state()["decode"]["state"] == "half_open"
    assert b.record_success("decode") is True         # probe → recovery
    assert b.state()["decode"] == {"state": "closed", "trips": 1,
                                   "recoveries": 1, "until_probe": 0}
    # a failed probe re-opens and restarts the cooldown
    b.record_failure("decode")
    b.allow_native("decode")
    b.allow_native("decode")
    b.record_failure("decode")                        # half-open probe fails
    assert b.state()["decode"]["state"] == "open"
    assert b.state()["decode"]["trips"] == 3


def test_resolve_backend_consults_breaker(clean_breaker):
    clean_breaker.cooldown = 2
    ops.dispatch_resolutions(reset=True)
    assert ops.resolve_backend("interpret", op="decode") == "interpret"
    clean_breaker.record_failure("decode")
    assert ops.resolve_backend("interpret", op="decode") == "xla"
    assert ops.dispatch_resolutions()["breaker_fallback"] == 1
    # legacy op-less resolutions never consult the breaker
    assert ops.resolve_backend("interpret") == "interpret"
    # the forced-xla resolution counted down: next call is the probe
    assert ops.resolve_backend("interpret", op="decode") == "interpret"
    assert clean_breaker.state()["decode"]["state"] == "half_open"
    ops.dispatch_resolutions(reset=True)


# -- zero overhead when off ------------------------------------------------


def test_zero_overhead_when_off():
    """faults=None + nan_guard (clean logits) change nothing: tokens and
    dispatch counters identical to the seed engine."""
    ref = _run(_engine(), _reqs(4))
    guarded = _engine(nan_guard=True)
    got = _run(guarded, _reqs(4))
    for u in ref:
        assert list(got[u].out_tokens) == list(ref[u].out_tokens)
        assert not got[u].failed
    plain = _engine()
    _run(plain, _reqs(4))
    assert guarded.stats()["requests_failed"] == 0
    for k in ("decode_dispatches", "prefill_dispatches", "ticks"):
        assert getattr(guarded, k) == getattr(plain, k)


# -- numerical guard -------------------------------------------------------


@pytest.mark.parametrize("cls_name,cls", [
    ("paged", PagedServingEngine), ("batched", ServingEngine),
    ("perslot", PerSlotServingEngine)])
def test_guard_isolates_poisoned_request(cls_name, cls):
    """nan_logits on ONE uid: that request retires failed (pages freed),
    every survivor's tokens are bit-identical to the fault-free run."""
    ref = _ref_tokens(cls_name, None)
    obs = Observability()
    plan = FaultPlan([FaultSpec("nan_logits", uid=1, at=2)])
    eng = _engine(cls, obs=obs, faults=plan, nan_guard=True)
    done = _run(eng, _reqs(4))
    # prefill token + 2 decode ticks before arrival 2 fires
    assert done[1].failed and len(done[1].out_tokens) == 3
    for u in (0, 2, 3):
        assert tuple(done[u].out_tokens) == ref[u]    # bit-identical
        assert not done[u].failed
    assert not any(eng.slots)
    if cls is PagedServingEngine:
        assert eng.pages_in_use == 0
        assert sorted(eng._free) == list(range(eng.n_pages))
    assert eng.stats()["requests_failed"] == 1
    kinds = [e["ev"] for e in obs.tracer.events]
    assert "fault" in kinds and "guard" in kinds
    guard = next(e for e in obs.tracer.events if e["ev"] == "guard")
    assert guard["uid"] == 1 and guard["reason"] == "nonfinite_logits"
    retire = next(e for e in obs.tracer.events
                  if e["ev"] == "retire" and e["uid"] == 1)
    assert retire["failed"] is True
    c = obs.summary()["counts"]
    assert c["failed"] == 1 and c["guard_trips"] == 1
    assert c["faults_injected"] == 1
    # failed rows stream no token: decode accounting stays exact
    streamed = sum(len(r.out_tokens) for r in done.values())
    assert c["decode_tokens"] + obs.summary()["ttft_s"]["count"] == streamed


def test_guard_escalation_cites_worst_difficulty_layer():
    """With the quant-health sampler attached, the guard event escalates
    the (module, layer) whose Eq.-2 difficulty is worst for the failing
    request's context — the runtime counterpart of the passive
    sampler."""
    cfg, model, params, _ = _setup()
    obs = Observability(
        quant_health=QuantHealthSampler(model, params, cfg, every=10_000,
                                        bucket=8))
    plan = FaultPlan([FaultSpec("nan_logits", uid=0, at=1)])
    eng = _engine(obs=obs, faults=plan, nan_guard=True)
    done = _run(eng, _reqs(2))
    assert done[0].failed
    guard = next(e for e in obs.tracer.events if e["ev"] == "guard")
    assert guard["module"] and isinstance(guard["layer"], int)
    assert np.isfinite(guard["difficulty"])


def test_unguarded_engine_ignores_poison():
    """nan_guard off: the poisoned run completes without failing anyone
    (the guard is strictly opt-in)."""
    plan = FaultPlan([FaultSpec("nan_logits", uid=1, at=2)])
    done = _run(_engine(faults=plan), _reqs(4))
    assert not any(r.failed for r in done.values())
    assert len(plan.fired) == 1


# -- circuit breaker through the engine ------------------------------------


def test_breaker_trips_to_xla_and_recovers(clean_breaker):
    """An injected decode-dispatch failure on the interpret path: the
    tick completes on the XLA fallback jit (tokens identical to the
    fault-free run), the breaker rides the fallback through the
    cooldown, then the half-open probe recovers — counters pinned."""
    clean_breaker.cooldown = 2
    ref = _ref_tokens("paged", "interpret", max_slots=2)
    ops.dispatch_resolutions(reset=True)
    obs = Observability()
    plan = FaultPlan([FaultSpec("dispatch_raise", op="decode", at=2)])
    # max_slots=2 + cooldown=2: the trip, the open-circuit tick and the
    # recovering probe all land while uids 0/1 are in flight — at
    # positions where the never-lowered fallback jit and the interpret
    # path agree exactly (they DO diverge on greedy near-ties: uid 3's
    # trajectory differs between backends, which is why the schedule
    # closes the circuit before uids 2/3 ever decode).  That makes the
    # whole run bit-identical to the fault-free twin — the strongest
    # form of "the tick was never lost".
    eng = _engine(use_kernels="interpret", obs=obs, faults=plan,
                  max_slots=2)
    done = _run(eng, _reqs(4))
    for u, r in done.items():
        assert tuple(r.out_tokens) == ref[u]          # tick never lost
        assert not r.failed
    st_ = eng.stats()
    assert st_["breaker"]["decode"] == {"state": "closed", "trips": 1,
                                        "recoveries": 1, "until_probe": 0}
    disp = st_["dispatch_backends"]
    # the trip + ONE open resolution ride the fallback, both tallied
    # under dispatch.fallback.decode AND dispatch.decode.xla
    assert disp["fallback.decode"] == 2
    assert disp["decode.xla"] == 2
    assert disp["decode.interpret"] == eng.decode_dispatches - 2
    evs = [e for e in obs.tracer.events if e["ev"] == "breaker"]
    assert [e["action"] for e in evs] == ["trip", "recover"]
    assert all(e["op"] == "decode" for e in evs)
    c = obs.summary()["counts"]
    assert c["breaker_trips"] == 1 and c["breaker_recoveries"] == 1
    assert ops.dispatch_resolutions()["breaker_fallback"] == 1
    ops.dispatch_resolutions(reset=True)


def test_dispatch_raise_without_fallback_propagates():
    """bf16 engine (no fallback jit): the injected dispatch failure
    escapes step() — containment is the front-end watchdog's job
    (tests/test_frontend.py)."""
    plan = FaultPlan([FaultSpec("dispatch_raise", op="decode")])
    eng = _engine(faults=plan)
    for r in _reqs(2):
        eng.submit(r)
    with pytest.raises(FaultInjected, match="dispatch_raise"):
        eng.run(max_ticks=50)


def test_page_alloc_fail_defers_without_corruption():
    """An injected empty-pool report at admission defers the head of the
    queue one round; the request still completes token-identically."""
    ref = _ref_tokens("paged", None)
    obs = Observability()
    plan = FaultPlan([FaultSpec("page_alloc_fail", uid=2, op="admit")])
    eng = _engine(obs=obs, faults=plan)
    done = _run(eng, _reqs(4))
    for u, r in done.items():
        assert tuple(r.out_tokens) == ref[u]
    assert len(plan.fired) == 1
    assert eng.pages_in_use == 0


def test_slow_tick_delays_but_preserves_tokens():
    ref = _ref_tokens("paged", None)
    plan = FaultPlan([FaultSpec("slow_tick", at=1, delay_s=0.05)])
    eng = _engine(faults=plan)
    done = _run(eng, _reqs(4))
    for u, r in done.items():
        assert tuple(r.out_tokens) == ref[u]
    assert len(plan.fired) == 1


# -- chaos -----------------------------------------------------------------


def _chaos_run(seed: int, trace_path: str):
    """One seeded chaos episode on the quantized-interpret paged engine:
    a random fault schedule + a deterministic mid-run cancel."""
    rng = np.random.default_rng(seed)
    plan = FaultPlan.random(seed, n_faults=4,
                            sites=("nan_logits", "dispatch_raise",
                                   "page_alloc_fail", "slow_tick"),
                            uids=range(4), max_at=12)
    obs = Observability(trace_path=trace_path)
    eng = _engine(use_kernels="interpret", obs=obs, faults=plan,
                  nan_guard=True, max_slots=2, n_pages=10)
    reqs = _reqs(4)
    for r in reqs:
        eng.submit(r)
    cancel_uid = int(rng.integers(4))
    cancel_tick = int(rng.integers(1, 6))
    for _ in range(300):
        if not (eng.queue or any(s is not None for s in eng.slots)):
            break
        eng.step()
        if eng.ticks == cancel_tick:
            eng.cancel(cancel_uid)
    done = {r.uid: r for r in eng.pop_retired()}
    return eng, obs, done, plan


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_chaos_accounting_pages_and_replay(seed):
    """Chaos invariants (the tentpole's cap): every submitted request
    retires exactly once with exact accounting, every page returns to
    the free list, surviving requests are BIT-IDENTICAL to the
    fault-free run, and the JSONL trace replays to the identical
    summary through the ``repro.obs`` pipeline."""
    ops.breaker.reset()
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        eng, obs, done, plan = _chaos_run(seed, path)
        # exact accounting: completed + failed + cancelled == submitted
        assert sorted(done) == list(range(4))         # nobody lost/duped
        failed = sum(r.failed for r in done.values())
        cancelled = sum(r.cancelled and not r.failed
                        for r in done.values())
        completed = 4 - failed - cancelled
        assert completed + failed + cancelled == 4
        c = obs.summary()["counts"]
        assert c["submitted"] == 4 and c["retired"] == 4
        assert c["failed"] == failed and c["cancelled"] == cancelled
        # every page back in the free list, no slot occupied
        assert not any(eng.slots)
        assert eng.pages_in_use == 0
        assert sorted(eng._free) == list(range(eng.n_pages))
        # no lost/duplicated tokens for survivors; when no dispatch ever
        # rode the fallback jit the survivors are BIT-IDENTICAL to the
        # fault-free twin (a dispatch_raise legitimately switches the
        # executing backend for a tick, and interpret/xla diverge on
        # greedy near-ties — the breaker test pins that case)
        ref = _ref_tokens("paged", "interpret", max_slots=2, n_pages=10)
        clean = not any(f["site"] == "dispatch_raise" for f in plan.fired)
        for u, r in done.items():
            if not r.failed and not r.cancelled:
                assert len(r.out_tokens) == 5, (seed, u)
                if clean:
                    assert tuple(r.out_tokens) == ref[u], (seed, u)
        # trace replay: python -m repro.obs on the JSONL reproduces the
        # in-memory summary byte for byte
        mem = obs.summary()
        obs.close()
        assert summarize(load_trace(path)) == mem
    finally:
        os.unlink(path)
        ops.breaker.reset()


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_chaos_accounting_every_family(family):
    """The guard + fault plane hold their accounting invariants on every
    cache family (dense / MoE-MLA / SSM / hybrid): all requests retire,
    pages restore, the poisoned request alone fails."""
    arch = FAMILY_ARCHS[family]
    obs = Observability()
    plan = FaultPlan([FaultSpec("nan_logits", uid=1, at=1),
                      FaultSpec("slow_tick", at=2, delay_s=0.01),
                      FaultSpec("page_alloc_fail", at=0, op="admit",
                                uid=2)])
    eng = _engine(arch=arch, obs=obs, faults=plan, nan_guard=True,
                  max_slots=2)
    done = _run(eng, _reqs(3, max_new=4, arch=arch))
    assert sorted(done) == [0, 1, 2]
    assert done[1].failed and not done[0].failed and not done[2].failed
    assert not any(eng.slots)
    assert eng.pages_in_use == 0
    assert sorted(eng._free) == list(range(eng.n_pages))
    c = obs.summary()["counts"]
    assert c["retired"] == 3 and c["failed"] == 1
    assert c["guard_trips"] == 1
