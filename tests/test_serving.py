"""Serving stack tests: fold+quantize pipeline, quantized-vs-bf16 logits,
KV-cache quantization, batched engine end-to-end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.qlinear import QuantPolicy
from repro.core.transforms import TransformPlan
from repro.models import common as cm
from repro.models.api import get_model
from repro.serving.engine import Request, ServingEngine
from repro.serving.fold import collect_calibration, fold_quantize

KEY = jax.random.PRNGKey(0)


def _calib(model, params, cfg, n=1):
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    return collect_calibration(model, params, cfg, [{"tokens": toks}] * n)


@pytest.mark.parametrize("arch", ["stablelm_3b", "qwen15_4b", "mamba2_780m",
                                  "zamba2_12b", "deepseek_v2_lite_16b",
                                  "arctic_480b"])
def test_fold_quantize_w8a8_faithful(arch):
    """W8A8 after fold must track bf16 logits closely (top-1 ≥ 90%)."""
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    stats = _calib(model, params, cfg)
    policy = QuantPolicy(weight_bits=8, act_bits=8, pack_weights=False,
                         use_kernels="never")
    q = fold_quantize(params, cfg, policy=policy, stats=stats)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    of = model.forward(params, cfg, toks)
    oq = model.forward(q, cfg, toks, policy=policy)
    lf = np.asarray(of[0] if isinstance(of, tuple) else of, np.float32)
    lq = np.asarray(oq[0] if isinstance(oq, tuple) else oq, np.float32)
    agree = (lf.argmax(-1) == lq.argmax(-1)).mean()
    assert agree >= 0.9, agree


def test_w4a4_with_transforms_beats_w4a4_without():
    """The paper's point at model level: transforms reduce quantized-model
    output error vs no transform at the same bit width."""
    cfg = get_config("stablelm_3b").reduced(num_layers=2)
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    stats = _calib(model, params, cfg)
    policy = QuantPolicy(weight_bits=4, act_bits=4, use_kernels="never")
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    lf = np.asarray(model.forward(params, cfg, toks), np.float32)

    def err(plan):
        q = fold_quantize(params, cfg, policy=policy, plan=plan, stats=stats)
        lq = np.asarray(model.forward(q, cfg, toks, policy=policy), np.float32)
        return np.linalg.norm(lq - lf)

    e_none = err(TransformPlan(attn_in="none", attn_out="none",
                               mlp_in="none", mlp_out="none"))
    e_paper = err(TransformPlan())  # rotate + smooth_rotate on down_proj
    assert e_paper < e_none, (e_paper, e_none)


def test_kv_cache_int8_close_to_bf16():
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    c16 = model.make_cache(cfg, 2, 32, bits=None)
    c8 = model.make_cache(cfg, 2, 32, bits=8)
    l16, c16 = model.prefill(params, cfg, toks, c16)
    l8, c8 = model.prefill(params, cfg, toks, c8)
    a, b = np.asarray(l16, np.float32), np.asarray(l8, np.float32)
    assert np.abs(a - b).max() / (np.abs(a).max() + 1e-9) < 0.1


def test_kv_cache_int8_close_to_bf16_batched_slots():
    """max_slots>1 extension: the slot-stacked int8 cache decoding two
    slots at DIFFERENT depths in one program stays close to its bf16
    twin, row for row."""
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    prompts = [jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size),
               jax.random.randint(jax.random.fold_in(KEY, 1), (1, 5), 0,
                                  cfg.vocab_size)]
    decoded = {}
    for bits in (None, 8):
        cache = cm.batch_slot_cache(model.make_cache(cfg, 2, 32, bits=bits))
        last = []
        for i, p in enumerate(prompts):  # per-slot admit at depths 12 and 5
            sc = model.make_cache(cfg, 1, 32, bits=bits)
            lg, sc = model.prefill(params, cfg, p, sc)
            cache = cm.write_slot(cache, sc, i)
            last.append(int(jnp.argmax(lg[0, -1])))
        toks = jnp.asarray(last, jnp.int32)[:, None]
        logits, cache = model.decode_step(params, cfg, toks, cache)
        decoded[bits] = np.asarray(logits[:, -1], np.float32)
    a, b = decoded[None], decoded[8]
    for row in range(2):
        rel = np.abs(a[row] - b[row]).max() / (np.abs(a[row]).max() + 1e-9)
        assert rel < 0.1, (row, rel)


def test_engine_end_to_end_batched():
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    eng = ServingEngine(model, params, cfg, max_slots=2, max_len=64)
    reqs = [Request(uid=i,
                    prompt=np.random.default_rng(i).integers(
                        0, cfg.vocab_size, size=(5 + i,)),
                    max_new_tokens=6) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_ticks=100)
    assert len(done) == 4
    for r in done:
        assert len(r.out_tokens) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_engine_greedy_matches_decode_loop():
    """The engine's greedy output == hand-rolled prefill+decode loop."""
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    prompt = np.asarray([1, 2, 3, 4, 5], np.int32)
    eng = ServingEngine(model, params, cfg, max_slots=1, max_len=64)
    req = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run(max_ticks=50)
    # manual loop
    cache = model.make_cache(cfg, 1, 64)
    lg, cache = model.prefill(params, cfg, jnp.asarray(prompt[None]), cache)
    toks = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(4):
        lg, cache = model.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0, -1])))
    assert req.out_tokens == toks
