"""Batched-slot serving engine: cross-family equivalence & stress suite.

Pins the contract the batched engine must keep before anything scales on
top of it (paged KV, sharded serve):

  * greedy output token-identical to the per-slot seed loop
    (``PerSlotServingEngine``) for every model family, bf16 AND
    fold+quantized params;
  * exactly ONE jitted decode dispatch per tick regardless of the
    active-slot count;
  * scheduler invariants under random submit/retire churn (hypothesis
    property test via tests/_hypothesis_support.py);
  * temperature sampling draws per-request keys (step-only folding gave
    every slot in a tick the same draw);
  * int8 KV slot reuse leaks no stale keys or dequant scales.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_support import given, settings, st

from repro.models import common as cm
from repro.serving.engine import (PerSlotServingEngine, Request,
                                  ServingEngine, _sample_key)
# shared cross-suite harness (tests/_engine_matrix.py)
from tests._engine_matrix import FAMILY_ARCHS, KEY
from tests._engine_matrix import count_decodes as _count_decodes
from tests._engine_matrix import mk_requests as _mk_requests
from tests._engine_matrix import setup as _setup

# ---------------------------------------------------------------------------
# tentpole: greedy equivalence + single dispatch, all families × precisions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "w8a8"])
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_batched_matches_per_slot_greedy(family, quantized):
    """Batched decode == seed per-slot loop, token for token, with ONE
    decode dispatch per tick (the per-slot loop pays one per slot)."""
    cfg, model, params, policy = _setup(FAMILY_ARCHS[family], quantized)
    outs, dispatch_ratio = {}, {}
    for name, cls in (("batched", ServingEngine),
                      ("per_slot", PerSlotServingEngine)):
        eng = cls(model, params, cfg, max_slots=2, max_len=32, policy=policy)
        calls = _count_decodes(eng)
        reqs = _mk_requests(cfg)
        for r in reqs:
            eng.submit(r)
        while eng.queue or any(eng.slots):
            before = len(calls)
            n_active = eng.step()
            if name == "batched":  # exactly one dispatch, active count ≥ 1
                assert len(calls) - before == (1 if n_active else 0)
            else:
                assert len(calls) - before == n_active
        done = eng.pop_retired()
        assert sorted(r.uid for r in done) == [0, 1, 2]
        outs[name] = {r.uid: list(r.out_tokens) for r in done}
        dispatch_ratio[name] = len(calls)
    assert outs["batched"] == outs["per_slot"]
    # 2 slots busy most ticks → the per-slot loop pays more dispatches
    assert dispatch_ratio["per_slot"] > dispatch_ratio["batched"]


def test_batched_slots_at_different_depths():
    """Slots admitted at different ticks decode at different cache depths
    in one program — per-slot RoPE positions and valid-length masks."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = ServingEngine(model, params, cfg, max_slots=2, max_len=32)
    a = Request(uid=0, prompt=np.arange(1, 8, dtype=np.int64), max_new_tokens=8)
    eng.submit(a)
    eng.step()                     # a alone at depth 7
    b = Request(uid=1, prompt=np.asarray([9, 8, 7]), max_new_tokens=8)
    eng.submit(b)
    eng.run(max_ticks=50)
    # reference: each request served alone
    for req, uid in ((a, 0), (b, 1)):
        solo = ServingEngine(model, params, cfg, max_slots=1, max_len=32)
        ref = Request(uid=uid, prompt=req.prompt, max_new_tokens=8)
        solo.submit(ref)
        solo.run(max_ticks=50)
        assert req.out_tokens == ref.out_tokens, uid


# ---------------------------------------------------------------------------
# sampling: per-request PRNG keys
# ---------------------------------------------------------------------------


def test_sample_key_folds_uid():
    k0, k1 = _sample_key(3, 0), _sample_key(3, 1)
    assert not np.array_equal(np.asarray(k0), np.asarray(k1))


@pytest.mark.parametrize("cls", [ServingEngine, PerSlotServingEngine])
def test_temperature_sampling_distinct_across_slots(cls):
    """Regression: the seed folded the key on the step only, so identical
    prompts decoding in the same ticks drew IDENTICAL token sequences at
    temperature > 0."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = cls(model, params, cfg, max_slots=2, max_len=32)
    prompt = np.asarray([1, 2, 3], np.int64)
    reqs = [Request(uid=i, prompt=prompt, max_new_tokens=12, temperature=1.0)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=50)
    # same prompt, same ticks, same logits — only the uid fold separates
    # the draws (P[12 identical draws | distinct keys] ≈ vocab^-12)
    assert reqs[0].out_tokens != reqs[1].out_tokens


# ---------------------------------------------------------------------------
# scheduler invariants under churn (hypothesis property test)
# ---------------------------------------------------------------------------


def _emitted_token():
    """A token the greedy model actually emits, for live-EOS examples."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = ServingEngine(model, params, cfg, max_slots=1, max_len=32)
    req = Request(uid=0, prompt=np.asarray([5, 6, 7]), max_new_tokens=3)
    eng.submit(req)
    eng.run(max_ticks=20)
    return req.out_tokens[0]


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4),          # initial submissions
       st.integers(0, 3),          # mid-run submissions
       st.integers(0, 3),          # ticks before the mid-run burst
       st.integers(1, 5),          # max_new_tokens (incl. the 1 edge case)
       st.sampled_from(["none", "live"]),   # EOS placement
       st.integers(0, 5))          # prompt-length seed
def test_scheduler_invariants_under_churn(n_init, n_mid, mid_ticks, max_new,
                                          eos_mode, seed):
    """No request lost or duplicated, out_tokens ≤ max_new_tokens, and
    run() + pop_retired() hand each uid back exactly once."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eos = -1 if eos_mode == "none" else _emitted_token()
    eng = ServingEngine(model, params, cfg, max_slots=2, max_len=32,
                        eos_id=eos)
    rng = np.random.default_rng(seed)
    uids = list(range(n_init + n_mid))

    def mk(uid):
        return Request(uid=uid,
                       prompt=rng.integers(0, cfg.vocab_size,
                                           size=(int(rng.integers(1, 6)),)),
                       max_new_tokens=max_new)

    for uid in uids[:n_init]:
        eng.submit(mk(uid))
    for _ in range(mid_ticks):
        eng.step()
    for uid in uids[n_init:]:
        eng.submit(mk(uid))          # mid-run churn
    done = eng.run(max_ticks=200)
    done += eng.pop_retired()        # must add nothing (run drained all)
    assert sorted(r.uid for r in done) == uids
    assert not eng.queue and not any(eng.slots)
    for r in done:
        assert r.done
        assert 1 <= len(r.out_tokens) <= max_new
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)
        if eos != -1 and eos in r.out_tokens:  # EOS retires immediately
            assert r.out_tokens.index(eos) == len(r.out_tokens) - 1


# ---------------------------------------------------------------------------
# int8 KV under the slot-major layout
# ---------------------------------------------------------------------------


def test_kv_int8_slot_reuse_no_stale_scales():
    """A slot reused after retirement must not leak the previous
    occupant's keys or int8 dequant scales: the reused slot's tokens
    match a fresh engine's, and scale rows past the new depth are 0."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    long_req = Request(uid=0, prompt=np.arange(1, 13, dtype=np.int64) % 7,
                       max_new_tokens=6)
    short = np.asarray([3, 1, 4], np.int64)

    eng = ServingEngine(model, params, cfg, max_slots=1, max_len=32,
                        kv_bits=8)
    eng.submit(long_req)
    eng.run(max_ticks=50)            # slot 0 filled to depth 17
    reused = Request(uid=1, prompt=short, max_new_tokens=6)
    eng.submit(reused)
    eng.run(max_ticks=50)

    fresh_eng = ServingEngine(model, params, cfg, max_slots=1, max_len=32,
                              kv_bits=8)
    fresh = Request(uid=2, prompt=short, max_new_tokens=6)
    fresh_eng.submit(fresh)
    fresh_eng.run(max_ticks=50)
    assert reused.out_tokens == fresh.out_tokens

    # the reused request filled 3 (prompt) + 5 (decodes) positions; every
    # scale row beyond that must be the write_slot-copied zero, not the
    # long request's stale scale
    depth = len(short) + len(reused.out_tokens) - 1
    for leaf in (eng.cache.k_scale, eng.cache.v_scale):
        tail = np.asarray(leaf)[:, 0, depth:]
        assert (tail == 0).all()


def test_multi_token_chunk_decode_with_vector_lengths():
    """A multi-token chunk (s=2) against a slot-major cache of 3 slots at
    DIFFERENT depths: causal mask + RoPE must use each row's own offset
    (a shared q_pos would silently alias slot positions), and the result
    must match per-slot sequential decode."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    prompts = [np.arange(1, 8) % 7, np.asarray([3, 1, 4]),
               np.asarray([9, 8, 7, 6, 5])]        # depths 7, 3, 5
    cache = cm.batch_slot_cache(model.make_cache(cfg, 3, 32))
    singles = []
    for i, p in enumerate(prompts):
        sc = model.make_cache(cfg, 1, 32)
        _, sc = model.prefill(params, cfg, jnp.asarray(p)[None].astype(jnp.int32),
                              sc)
        cache = cm.write_slot(cache, sc, i)
        singles.append(sc)
    chunk = jnp.asarray([[5, 6], [2, 9], [1, 1]], jnp.int32)
    logits_b, cache = model.decode_step(params, cfg, chunk, cache)
    assert list(np.asarray(cache.length)) == [9, 5, 7]
    for i in range(3):
        sc, lg = singles[i], None
        for t in np.asarray(chunk[i]):  # sequential single-token reference
            lg, sc = model.decode_step(params, cfg,
                                       jnp.asarray([[t]], jnp.int32), sc)
        np.testing.assert_allclose(np.asarray(logits_b[i, -1], np.float32),
                                   np.asarray(lg[0, -1], np.float32),
                                   rtol=1e-3, atol=1e-3, err_msg=str(i))


def test_slot_cache_roundtrip_helpers():
    """cache_at is the inverse of write_slot on the slot-major layout."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    batched = cm.batch_slot_cache(model.make_cache(cfg, 2, 16, bits=8))
    slot = model.make_cache(cfg, 1, 16, bits=8)
    _, slot = model.prefill(params, cfg,
                            jnp.asarray([[1, 2, 3, 4]], jnp.int32), slot)
    batched = cm.write_slot(batched, slot, 1)
    view = cm.cache_at(batched, 1)
    for a, b in zip(jax.tree.leaves(view), jax.tree.leaves(slot)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # untouched slot 0 stayed zero-length
    assert int(cm.cache_at(batched, 0).length) == 0
