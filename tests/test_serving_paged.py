"""Paged serving engine: paged KV pool + in-engine batched prefill.

Pins the continuous-batching contracts the paged rebuild must keep:

  * greedy output token-identical to the per-slot seed loop
    (``PerSlotServingEngine``) for every family, bf16 AND quantized,
    under scheduler churn — with exactly ONE decode dispatch per tick;
  * ONE batched prefill dispatch admits a whole mixed-prompt-length
    batch (length-bucketed padding) and writes straight into pages;
  * page-pool lifecycle: retire-then-admit reuses freed physical pages
    with no stale KV or stale int8-scale leakage (the PR 2 slot-reuse
    test at page granularity), pool exhaustion backpressures admission,
    slots grow on demand, and a fully stalled engine preempts without
    changing any request's tokens.
"""

import numpy as np
import pytest

from repro.core.qlinear import QuantPolicy
from repro.serving.engine import (PagedServingEngine, PerSlotServingEngine,
                                  Request, ServingEngine)
# shared cross-suite harness (tests/_engine_matrix.py)
from tests._engine_matrix import FAMILY_ARCHS
from tests._engine_matrix import count_decodes as _count_decodes
from tests._engine_matrix import mk_requests as _mk_requests
from tests._engine_matrix import serve as _serve
from tests._engine_matrix import setup as _setup


# ---------------------------------------------------------------------------
# tentpole: greedy equivalence + single dispatch, all families × precisions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "w8a8"])
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_paged_matches_per_slot_greedy(family, quantized):
    """Paged decode == seed per-slot loop, token for token, with ONE
    decode dispatch per tick and pages fully returned on drain."""
    cfg, model, params, policy = _setup(FAMILY_ARCHS[family], quantized)
    outs = {}
    for name, cls, kw in (("paged", PagedServingEngine,
                           dict(page_size=4, prefill_bucket=8)),
                          ("per_slot", PerSlotServingEngine, {})):
        eng = cls(model, params, cfg, max_slots=2, max_len=32, policy=policy,
                  **kw)
        calls = _count_decodes(eng)
        reqs = _mk_requests(cfg)
        for r in reqs:
            eng.submit(r)
        while eng.queue or any(eng.slots):
            before = len(calls)
            n_active = eng.step()
            if name == "paged":
                assert len(calls) - before == (1 if n_active else 0)
        done = eng.pop_retired()
        assert sorted(r.uid for r in done) == [0, 1, 2]
        outs[name] = {r.uid: list(r.out_tokens) for r in done}
        if name == "paged":
            assert eng.pages_in_use == 0        # every page back in the pool
    assert outs["paged"] == outs["per_slot"]


def test_paged_matches_batched_int8_kv():
    """Paged + int8 KV (scale leaves page alongside data leaves) matches
    the dense batched engine token for token."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    outs = {}
    for name, cls, kw in (("paged", PagedServingEngine, dict(page_size=4)),
                          ("batched", ServingEngine, {})):
        eng = cls(model, params, cfg, max_slots=2, max_len=32, kv_bits=8, **kw)
        outs[name] = _serve(eng, _mk_requests(cfg, max_new=6))
    assert outs["paged"] == outs["batched"]


# ---------------------------------------------------------------------------
# in-engine batched prefill
# ---------------------------------------------------------------------------


def test_batched_prefill_one_dispatch_mixed_lengths():
    """A mixed-prompt-length admission batch shares ONE prefill dispatch
    (length-bucketed padding), vs one per request on the seed path."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = PagedServingEngine(model, params, cfg, max_slots=4, max_len=32,
                             page_size=4, prefill_bucket=8)
    reqs = [Request(uid=i, prompt=np.arange(1, 3 + 2 * i), max_new_tokens=3)
            for i in range(4)]                  # prompt lengths 2, 4, 6, 8
    outs = _serve(eng, reqs)
    assert eng.prefill_dispatches == 1

    per_slot = PerSlotServingEngine(model, params, cfg, max_slots=4,
                                    max_len=32)
    ref = _serve(per_slot, [Request(uid=i, prompt=np.arange(1, 3 + 2 * i),
                                    max_new_tokens=3) for i in range(4)])
    assert per_slot.prefill_dispatches == 4
    assert outs == ref


def test_prefill_finish_retires_without_slot():
    """max_new_tokens=1 requests finish at prefill: pages free the same
    tick and the next admission round reuses the slot (per-slot oracle
    semantics)."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = PagedServingEngine(model, params, cfg, max_slots=1, max_len=32,
                             page_size=4)
    reqs = [Request(uid=i, prompt=np.asarray([5, 6, 7]), max_new_tokens=1)
            for i in range(3)]
    outs = _serve(eng, reqs)
    assert sorted(outs) == [0, 1, 2]
    assert all(len(t) == 1 for t in outs.values())
    assert eng.pages_in_use == 0


# ---------------------------------------------------------------------------
# page-pool lifecycle
# ---------------------------------------------------------------------------


def test_page_reuse_no_stale_kv_or_scales():
    """Retire-then-admit must REUSE freed physical pages (tight pool) and
    still match a fresh engine token for token — no stale keys/values or
    int8 dequant scales can leak through a recycled page (the PR 2
    slot-reuse test at page granularity)."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    long_req = Request(uid=0, prompt=np.arange(1, 13) % 7, max_new_tokens=6)
    short = np.asarray([3, 1, 4])

    # pool of exactly 5 pages (page_size 4): the long request fills
    # 12 + 5 = 17 positions → all 5 pages carry its data when it retires
    eng = PagedServingEngine(model, params, cfg, max_slots=1, max_len=32,
                             kv_bits=8, page_size=4, n_pages=5)
    eng.submit(long_req)
    eng.run(max_ticks=50)
    assert eng.peak_pages_in_use == 5
    assert eng.pages_in_use == 0
    reused = Request(uid=1, prompt=short, max_new_tokens=6)
    eng.submit(reused)
    eng.run(max_ticks=50)

    fresh_eng = PagedServingEngine(model, params, cfg, max_slots=1,
                                   max_len=32, kv_bits=8, page_size=4,
                                   n_pages=5)
    fresh = Request(uid=2, prompt=short, max_new_tokens=6)
    fresh_eng.submit(fresh)
    fresh_eng.run(max_ticks=50)
    assert reused.out_tokens == fresh.out_tokens


def test_submit_rejects_never_admissible_prompt():
    """A prompt that can never fit the page-table width / pool fails
    loudly at submit instead of starving the FIFO queue forever."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = PagedServingEngine(model, params, cfg, max_slots=1, max_len=32,
                             page_size=4, n_pages=4)     # capacity 16 tokens
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(Request(uid=0, prompt=np.arange(20), max_new_tokens=2))
    ok = Request(uid=1, prompt=np.arange(10), max_new_tokens=2)
    eng.submit(ok)
    eng.run(max_ticks=50)
    assert len(ok.out_tokens) == 2


def test_pool_exhaustion_backpressure():
    """Admission waits for pages even while a slot is free, resumes when
    the occupant retires, and both requests' tokens match the oracle."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                             page_size=4, n_pages=3)
    r0 = Request(uid=0, prompt=np.arange(1, 9), max_new_tokens=4)
    r1 = Request(uid=1, prompt=np.arange(2, 10), max_new_tokens=4)
    eng.submit(r0)
    eng.submit(r1)
    eng.step()
    # r0 holds 2 of 3 pages; r1 (2 pages) must wait though slot 1 is free
    assert eng.slots[0] is not None and eng.slots[1] is None
    assert len(eng.queue) == 1
    done = eng.run(max_ticks=200)
    assert sorted(r.uid for r in done) == [0, 1]
    assert eng.pages_in_use == 0

    oracle = PerSlotServingEngine(model, params, cfg, max_slots=2, max_len=32)
    ref = _serve(oracle, [Request(uid=0, prompt=np.arange(1, 9),
                                  max_new_tokens=4),
                          Request(uid=1, prompt=np.arange(2, 10),
                                  max_new_tokens=4)])
    assert {0: r0.out_tokens, 1: r1.out_tokens} == ref


def test_slots_grow_on_demand():
    """A slot's pages accrete as it decodes past page boundaries; the
    stats dict reports the growth."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = PagedServingEngine(model, params, cfg, max_slots=1, max_len=32,
                             page_size=4)
    req = Request(uid=0, prompt=np.asarray([1, 2, 3]), max_new_tokens=10)
    eng.submit(req)
    eng.step()
    assert eng.pages_in_use == 1                # ceil(3/4) at admission
    eng.run(max_ticks=50)
    # 3 prompt + 9 decode writes = 12 positions → 3 pages at peak
    assert eng.peak_pages_in_use == 3
    assert eng.run_stats["page_occupancy_peak"] == pytest.approx(3 / 8)
    assert eng.run_stats["per_request"][0] == {"prefill": 3, "decode": 10}


def test_total_stall_preempts_and_tokens_unchanged():
    """When EVERY active slot needs a page and none are free, the
    youngest occupant is preempted back to the queue; greedy output still
    matches the oracle request for request."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                             page_size=4, n_pages=2)
    reqs = [Request(uid=0, prompt=np.arange(1, 5), max_new_tokens=3),
            Request(uid=1, prompt=np.arange(3, 7), max_new_tokens=3)]
    outs = _serve(eng, reqs, max_ticks=300)
    oracle = PerSlotServingEngine(model, params, cfg, max_slots=2, max_len=32)
    ref = _serve(oracle, [Request(uid=0, prompt=np.arange(1, 5),
                                  max_new_tokens=3),
                          Request(uid=1, prompt=np.arange(3, 7),
                                  max_new_tokens=3)], max_ticks=300)
    assert outs == ref


def test_preemption_does_not_mutate_submitted_request():
    """Resume state must never leak into the caller's Request: the old
    preempt path folded ``out_tokens`` into ``req.prompt`` in place, so
    retired requests came back with a prompt they never submitted (and
    re-serving the same prompts produced different tokens).  Retire
    events must report the ORIGINAL prompt length too."""
    from repro.obs import Observability

    cfg, model, params, _ = _setup("stablelm_3b", False)
    prompts = {0: np.arange(1, 5), 1: np.arange(3, 7)}
    obs = Observability()
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                             page_size=4, n_pages=2, obs=obs)
    reqs = [Request(uid=u, prompt=p.copy(), max_new_tokens=3)
            for u, p in prompts.items()]
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_ticks=300)
    assert any(e["ev"] == "preempt" for e in obs.tracer.events)
    for r in done:
        assert np.array_equal(r.prompt, prompts[r.uid])
    for e in obs.tracer.events:
        if e["ev"] == "retire":
            assert e["prompt_len"] == len(prompts[e["uid"]])
    # the untouched Requests replay token-identically on a fresh engine
    replay = PagedServingEngine(model, params, cfg, max_slots=2,
                                max_len=32, page_size=4, n_pages=2)
    for r in done:
        replay.submit(Request(uid=r.uid, prompt=r.prompt,
                              max_new_tokens=3))
    ref = {r.uid: list(r.out_tokens) for r in replay.run(max_ticks=300)}
    assert {r.uid: list(r.out_tokens) for r in done} == ref


def test_pool_too_small_for_growth_retires_truncated_not_livelock():
    """A request admitted within capacity but whose DECODE outgrows the
    whole pool cannot be resumed after preemption — it must retire
    truncated rather than wedge the FIFO head and starve the queue."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    # 20-token pool; 18-token prompt + 8 decodes needs 25 > 20 tokens
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                             page_size=4, n_pages=5)
    big = Request(uid=0, prompt=np.arange(1, 19) % 7, max_new_tokens=8)
    small = Request(uid=1, prompt=np.asarray([3, 1, 4]), max_new_tokens=2)
    eng.submit(big)
    eng.submit(small)
    done = eng.run(max_ticks=300)
    assert sorted(r.uid for r in done) == [0, 1]       # nobody starves
    assert len(small.out_tokens) == 2                  # small unaffected
    assert 1 <= len(big.out_tokens) < 8                # truncated, not lost
    assert not eng.queue and not any(eng.slots)


# ---------------------------------------------------------------------------
# paged-attention kernel enabled (use_kernels="interpret" → the Pallas
# kernel runs through the interpreter on CPU — the same dispatch a TPU
# host resolves to "pallas")
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family,kw", [("dense", {}), ("dense",
                                                       dict(kv_bits=8)),
                                       ("hybrid", {})],
                         ids=["dense-bf16", "dense-int8kv",
                              "hybrid-sharedattn"])
def test_paged_attention_kernel_token_identical(family, kw):
    """With the in-VMEM paged-attention kernel enabled, the paged engine
    stays greedy token-identical to the per-slot oracle — dense GQA and
    the hybrid shared-attention invocations, bf16 and int8-KV pools."""
    cfg, model, params, _ = _setup(FAMILY_ARCHS[family], False)
    pol = QuantPolicy(use_kernels="interpret")
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                             policy=pol, page_size=4, prefill_bucket=8, **kw)
    assert eng.paged_attention_backend == "interpret"
    outs = _serve(eng, _mk_requests(cfg))
    assert eng.run_stats["paged_attention_backend"] == "interpret"
    oracle = PerSlotServingEngine(model, params, cfg, max_slots=2, max_len=32,
                                  **kw)
    assert outs == _serve(oracle, _mk_requests(cfg))


def test_paged_attention_backend_in_run_stats():
    """The resolved paged-attention mode is surfaced per engine run:
    "xla" on CPU auto (the gather fallback), and MLA configs report the
    latent-gather fallback even with kernels forced on."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                             page_size=4)
    _serve(eng, _mk_requests(cfg, n=1))
    assert eng.run_stats["paged_attention_backend"] == "xla"

    cfg_m, model_m, params_m, _ = _setup("deepseek_v2_lite_16b", False)
    eng_m = PagedServingEngine(model_m, params_m, cfg_m, max_slots=2,
                               max_len=32, page_size=4,
                               policy=QuantPolicy(use_kernels="interpret"))
    assert eng_m.paged_attention_backend == "xla"


# ---------------------------------------------------------------------------
# run() stats dict (satellite)
# ---------------------------------------------------------------------------


def test_run_stats_token_counts_all_engines():
    """Every engine reports aggregate + per-request prefill/decode token
    counts, so benchmarks stop re-deriving them from Request lists."""
    cfg, model, params, _ = _setup("stablelm_3b", False)
    for cls in (PagedServingEngine, ServingEngine, PerSlotServingEngine):
        eng = cls(model, params, cfg, max_slots=2, max_len=32)
        reqs = _mk_requests(cfg, n=3, max_new=4)
        for r in reqs:
            eng.submit(r)
        done = eng.run(max_ticks=200)
        st = eng.run_stats
        assert st["prefill_tokens"] == sum(3 + i for i in range(3))
        assert st["decode_tokens"] == sum(len(r.out_tokens) for r in done)
        for r in done:
            assert st["per_request"][r.uid]["prefill"] == len(r.prompt)
            assert st["per_request"][r.uid]["decode"] == len(r.out_tokens)
        assert st["dispatches_per_tick"] == (
            1.0 if cls is not PerSlotServingEngine
            else pytest.approx(eng.decode_dispatches / max(eng.ticks, 1)))


# ---------------------------------------------------------------------------
# chunked prefill (async front-end PR): long admits interleave with decode
# ---------------------------------------------------------------------------


def _chunked_workload(cfg):
    victim = Request(uid=0, prompt=np.asarray([5, 3, 2]), max_new_tokens=8)
    long_req = Request(uid=1, prompt=np.arange(1, 40) % cfg.vocab_size,
                       max_new_tokens=4)
    return victim, long_req


def _drive_victim_then_long(eng, cfg):
    """Victim decoding first, long prompt arriving mid-stream."""
    victim, long_req = _chunked_workload(cfg)
    eng.submit(victim)
    eng.step()
    eng.step()
    eng.submit(long_req)
    done = eng.run(max_ticks=300)
    return {r.uid: list(r.out_tokens) for r in done}


def test_chunked_prefill_interleaves_and_tokens_identical():
    """With ``prefill_chunk`` set, a long prompt streams through bounded
    continuation dispatches: the decoding victim keeps emitting tokens
    BETWEEN chunks (no whole-prompt stall), and every output is
    token-identical to the unchunked engine and the per-slot oracle."""
    from repro.obs import Observability

    cfg, model, params, _ = _setup("stablelm_3b", False)
    obs = Observability()
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=64,
                             page_size=4, prefill_bucket=8, prefill_chunk=8,
                             obs=obs)
    outs = _drive_victim_then_long(eng, cfg)
    assert eng.run_stats["chunked_prefill"] is True
    assert eng.run_stats["pages_in_use"] == 0          # drained clean

    # the 39-token prompt streamed through ≥2 bounded chunk dispatches
    chunk_evs = [e for e in obs.tracer.events
                 if e["ev"] == "prefill" and e.get("chunked")]
    assert len(chunk_evs) >= 2
    assert all(e["padded_len"] == 8 for e in chunk_evs)
    # and the victim decoded BETWEEN chunk dispatches — the stall the
    # chunking exists to remove
    interleaved = [e for e in obs.tracer.events if e["ev"] == "tick"
                   and chunk_evs[0]["ts"] < e["ts"] < chunk_evs[-1]["ts"]
                   and 0 in e["uids"]]
    assert interleaved, "victim starved during the long admit"

    ref = PagedServingEngine(model, params, cfg, max_slots=2, max_len=64,
                             page_size=4, prefill_bucket=8)
    assert _drive_victim_then_long(ref, cfg) == outs
    assert ref.run_stats["chunked_prefill"] is False
    assert ref.prefill_dispatches < eng.prefill_dispatches

    oracle = PerSlotServingEngine(model, params, cfg, max_slots=2,
                                  max_len=64)
    assert _drive_victim_then_long(oracle, cfg) == outs


def test_chunked_prefill_exact_multiple_and_quantized():
    """Chunk-boundary edge (prompt length an exact chunk multiple) and
    the w8a8 path both stay token-identical to the unchunked engine."""
    cfg, model, params, policy = _setup("stablelm_3b", True)
    reqs = lambda: [Request(uid=0, prompt=np.arange(2, 18) % cfg.vocab_size,
                            max_new_tokens=3),
                    Request(uid=1, prompt=np.asarray([4, 1]),
                            max_new_tokens=3)]
    outs = {}
    for name, chunk in (("chunked", 8), ("oneshot", None)):
        eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                                 page_size=4, prefill_bucket=8,
                                 prefill_chunk=chunk, policy=policy,
                                 kv_bits=8)
        outs[name] = _serve(eng, reqs())
    assert outs["chunked"] == outs["oneshot"]


def test_chunked_prefill_falls_back_without_model_support():
    """Families without a prefill continuation path (SSM scan state)
    ignore ``prefill_chunk`` and serve whole-prompt as before."""
    cfg, model, params, _ = _setup("mamba2_780m", False)
    eng = PagedServingEngine(model, params, cfg, max_slots=2, max_len=32,
                             page_size=4, prefill_bucket=8, prefill_chunk=4)
    outs = _serve(eng, [Request(uid=0,
                                prompt=np.arange(12) % cfg.vocab_size,
                                max_new_tokens=3)])
    assert len(outs[0]) == 3
    assert eng.run_stats["chunked_prefill"] is False
