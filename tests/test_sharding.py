"""Sharding-rule tests: divisibility fallbacks, param/cache specs,
strategies, and the flash/naive + SP model invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import (
    axis_if,
    batch_spec,
    cache_specs,
    param_specs,
    set_strategy,
)
from repro.models.api import get_model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _reset_strategy():
    set_strategy("2d")
    yield
    set_strategy("2d")


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_axis_if_divisibility():
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert axis_if(mesh, "model", 32) == "model"
    assert axis_if(mesh, "model", 20) is None          # 20 % 16 != 0
    assert axis_if(mesh, ("data", "model"), 256) == ("data", "model")
    assert axis_if(mesh, ("data", "model"), 64) is None
    assert axis_if(mesh, "pod", 8) is None             # axis absent


def test_batch_spec_fallbacks():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_spec(mesh, 256) == P(("pod", "data"), None)
    assert batch_spec(mesh, 16) == P("data", None)     # pod×data=32 ∤ 16
    assert batch_spec(mesh, 1) == P(None, None)        # replicate


def test_param_specs_cover_all_leaves():
    """Every arch's param tree gets a spec whose sharded dims divide."""
    mesh = _FakeMesh({"data": 16, "model": 16})
    for arch in ("stablelm_3b", "arctic_480b", "deepseek_v2_lite_16b",
                 "mamba2_780m", "zamba2_12b", "llama3_405b"):
        cfg = get_config(arch)
        model = get_model(cfg)
        shapes = jax.eval_shape(lambda k, c=cfg, m=model: m.init(k, c),
                                jax.random.PRNGKey(0))
        specs = param_specs(shapes, cfg, mesh)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                size = np.prod([mesh.shape[a] for a in
                                ((ax,) if isinstance(ax, str) else ax)])
                assert dim % size == 0, (arch, path, leaf.shape, spec)


def test_cache_specs_sequence_sharding_for_few_heads():
    """kv_heads < model ⇒ cache sequence is sharded over model (§Perf C)."""
    mesh = _FakeMesh({"data": 16, "model": 16})
    cfg = get_config("llama3_405b")  # kv=8 < 16
    model = get_model(cfg)
    cache = jax.eval_shape(lambda: model.make_cache(cfg, 128, 1024, bits=8))
    specs = cache_specs(cfg, mesh, cache)
    k_spec = specs.k
    assert k_spec[2] == "model"      # S axis
    assert k_spec[3] is None         # heads unshardable


def test_cache_specs_head_sharding_when_divisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    cfg = get_config("stablelm_3b")  # kv=32 ≥ 16
    model = get_model(cfg)
    cache = jax.eval_shape(lambda: model.make_cache(cfg, 128, 1024))
    specs = cache_specs(cfg, mesh, cache)
    assert specs.k[3] == "model"


def test_fsdp_strategy_shards_over_all_axes():
    mesh = _FakeMesh({"data": 16, "model": 16})
    set_strategy("fsdp")
    cfg = get_config("stablelm_3b")
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_specs(shapes, cfg, mesh)
    wq = specs["layers"]["attn"]["wq"]["w"]
    assert wq[1] == ("data", "model")  # c_in over all 256 devices
    assert batch_spec(mesh, 256) == P(("data", "model"), None)


def test_quantized_param_specs():
    """Quantized trees (QuantizedWeight leaves) get coherent specs."""
    from repro.core.qlinear import QuantPolicy
    from repro.core.transforms import TransformPlan
    from repro.serving.fold import fold_quantize

    mesh = _FakeMesh({"data": 2, "model": 2})
    cfg = get_config("stablelm_3b").reduced()
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    policy = QuantPolicy(use_kernels="never")
    qshapes = jax.eval_shape(
        lambda p: fold_quantize(p, cfg, policy=policy,
                                plan=TransformPlan(attn_in="rotate",
                                                   attn_out="rotate",
                                                   mlp_in="rotate",
                                                   mlp_out="rotate")),
        shapes)
    specs = param_specs(qshapes, cfg, mesh)
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(qshapes)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            size = np.prod([mesh.shape[a] for a in
                            ((ax,) if isinstance(ax, str) else ax)])
            assert dim % size == 0, (path, leaf.shape, spec)


def test_sp_and_strategy_model_forward_unchanged(test_mesh):
    """Perf options must not change numerics: SP + flash + bf16io forward
    matches the baseline on a reduced model."""
    cfg = get_config("stablelm_3b").reduced()
    cfg_opt = dataclasses.replace(cfg, attn_impl="flash", attn_bf16_io=True,
                                  seq_parallel=True,
                                  remat_policy="dots_no_batch")
    model = get_model(cfg)
    params = model.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    l0 = np.asarray(model.forward(params, cfg, toks), np.float32)
    l1 = np.asarray(model.forward(params, cfg_opt, toks), np.float32)
    assert np.abs(l0 - l1).max() / (np.abs(l0).max() + 1e-9) < 0.03
