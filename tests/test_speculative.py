"""Speculative decoding: differential token-identity conformance suite
(docs/speculative.md).

Pins the tentpole's contracts:

  * **token identity** (the acceptance pin): speculative greedy serving
    is BIT-IDENTICAL to the plain paged engine for dense bf16 / W8A8 /
    int8-KV at spec_k ∈ {1, 2, 4}, including under prefix caching and
    chunked prefill — speculation is a pure latency transform, never a
    sampling change;
  * **one verify dispatch per tick**: all k+1 candidate positions of
    every ready slot score in ONE batched ragged ``verify_paged``
    dispatch (``dispatches_per_tick == 1.0``, zero plain-decode
    dispatches);
  * **clean fallback**: families without ``verify_paged`` (moe / ssm /
    hybrid) serve identically with ``stats()["spec"]["enabled"] is
    False`` and zero ``spec.*`` activity;
  * separate-draft configs must share the target's token space
    (vocab-mismatch → ``ValueError``) and keep token identity even at
    low acceptance — every rejection exercises the suffix rollback;
  * **no stale state after rollback**: pages returned by the
    rejected-suffix rollback are reusable with no KV / int8-scale
    leakage, and the refcount partition holds under seeded chaos plans
    (faults + preemption + mid-run cancel, hypothesis property test).
"""

import asyncio
import dataclasses

import numpy as np
import pytest

from repro.kernels import ops
from repro.resilience.faults import FaultPlan
from repro.serving.config import EngineConfig
from repro.serving.engine import PagedServingEngine, Request
from repro.serving.frontend import ServingFrontend, http_generate
# shared cross-suite harness (tests/_engine_matrix.py)
from tests._engine_matrix import (FAMILY_ARCHS, assert_partition,
                                  mk_requests, serve, setup)
from tests._hypothesis_support import given, settings, st

PAGE = 4


def _cell(precision: str):
    """(cfg, model, params, policy, kv_bits) for one precision column of
    the identity matrix."""
    cfg, model, params, policy = setup("stablelm_3b",
                                       quantized=precision == "w8a8")
    return cfg, model, params, policy, (8 if precision == "kv8" else None)


def _engine(cfg, model, params, *, policy=None, kv_bits=None, spec_k=0,
            **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 32)
    return PagedServingEngine(
        model, params, cfg,
        config=EngineConfig(policy=policy, kv_bits=kv_bits, page_size=PAGE,
                            prefill_bucket=8, spec_k=spec_k, **kw))


def _sys(cfg, pages=2):
    """A shared system prefix: PAGES full pages of tokens."""
    return np.random.default_rng(99).integers(0, cfg.vocab_size,
                                              size=(pages * PAGE,))


def _shared_reqs(cfg, n=2, max_new=4):
    sys_prompt = _sys(cfg)
    return [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         np.random.default_rng(50 + i).integers(
                             0, cfg.vocab_size, size=(3 + i,))]),
                    max_new_tokens=max_new) for i in range(n)]


# ---------------------------------------------------------------------------
# the acceptance pin: token identity across the precision × depth matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_k", [1, 2, 4])
@pytest.mark.parametrize("precision", ["bf16", "w8a8", "kv8"])
def test_spec_matches_plain_greedy(precision, spec_k):
    """Speculative greedy == plain paged greedy, token for token.  The
    self-draft replays the per-slot oracle's numerics (including the
    int8-KV roundtrip), so every draft matches the verify argmax and
    acceptance is total — the bench's throughput ceiling."""
    cfg, model, params, policy, kv = _cell(precision)
    plain = serve(_engine(cfg, model, params, policy=policy, kv_bits=kv),
                  mk_requests(cfg, max_new=6))
    eng = _engine(cfg, model, params, policy=policy, kv_bits=kv,
                  spec_k=spec_k)
    assert serve(eng, mk_requests(cfg, max_new=6)) == plain
    sp = eng.run_stats["spec"]
    assert sp["enabled"] and sp["self_draft"]
    assert sp["verify_dispatches"] > 0
    assert sp["drafted"] > 0 and sp["rejected"] == 0   # oracle numerics
    assert sp["acceptance_rate"] == 1.0
    # every decode-phase token went through the verify path (each
    # request's FIRST token samples from its prefill logits)
    assert sp["emitted_tokens"] == sum(len(v) - 1 for v in plain.values())
    assert_partition(eng)


@pytest.mark.parametrize("prefix,chunk", [(True, None), (False, 2),
                                          (True, 2)],
                         ids=["prefix", "chunked", "prefix+chunked"])
def test_spec_identity_under_prefix_and_chunked(prefix, chunk):
    """Speculation composes with the prefix cache (verify writes never
    land in shared pages — the budget COWs them out first) and with
    chunked prefill: tokens stay bit-identical to the same engine with
    spec off."""
    cfg, model, params, _ = _cell("bf16")[:4]

    def mk(spec_k):
        return _engine(cfg, model, params, spec_k=spec_k,
                       prefix_cache=prefix, prefill_chunk=chunk)

    seed = Request(uid=100, prompt=_sys(cfg), max_new_tokens=1)
    eng_off, eng_on = mk(0), mk(2)
    plain = dict(serve(eng_off, [seed]))
    plain.update(serve(eng_off, _shared_reqs(cfg)))
    spec = dict(serve(eng_on, [Request(uid=100, prompt=_sys(cfg),
                                       max_new_tokens=1)]))
    spec.update(serve(eng_on, _shared_reqs(cfg)))
    assert spec == plain
    sp = eng_on.run_stats["spec"]
    assert sp["enabled"] and sp["emitted_tokens"] > 0
    if prefix:
        assert eng_on.run_stats["prefix"]["hits"] >= 2
    assert_partition(eng_on)


def test_eos_truncates_mid_emission():
    """EOS landing inside an accepted run truncates the emission there
    (tokens past EOS are discarded) — identical to the plain engine with
    the same eos_id, and EOS is the stream's last token."""
    cfg, model, params, _ = _cell("bf16")[:4]
    probe = serve(_engine(cfg, model, params), mk_requests(cfg, n=1,
                                                           max_new=6))
    eos = probe[0][1]           # a token the greedy model actually emits
    plain = serve(_engine(cfg, model, params, eos_id=eos),
                  mk_requests(cfg, n=2, max_new=6))
    eng = _engine(cfg, model, params, eos_id=eos, spec_k=4)
    spec = serve(eng, mk_requests(cfg, n=2, max_new=6))
    assert spec == plain
    assert spec[0][-1] == eos and len(spec[0]) < 6
    assert_partition(eng)


def test_temperature_rows_degenerate_to_plain_decode():
    """A temperature > 0 request drafts nothing (its verify row is the
    single next position) while a co-resident greedy request keeps
    speculating — the greedy stream stays bit-identical to a plain solo
    run, and the sampled stream completes within budget."""
    cfg, model, params, _ = _cell("bf16")[:4]
    plain = serve(_engine(cfg, model, params),
                  mk_requests(cfg, n=1, max_new=6))
    eng = _engine(cfg, model, params, spec_k=4)
    reqs = mk_requests(cfg, n=2, max_new=6)
    reqs[1].temperature = 1.0
    out = serve(eng, reqs)
    assert out[0] == plain[0]
    assert 1 <= len(out[1]) <= 6
    assert all(0 <= t < cfg.vocab_size for t in out[1])
    assert_partition(eng)


# ---------------------------------------------------------------------------
# dispatch shape: ONE batched ragged verify per tick
# ---------------------------------------------------------------------------


def test_one_verify_dispatch_per_tick():
    """Every tick with ready slots runs exactly ONE verify dispatch and
    ZERO plain decode dispatches, whatever the active-slot count or
    draft depth."""
    cfg, model, params, _ = _cell("bf16")[:4]
    eng = _engine(cfg, model, params, spec_k=4)
    verifies, decodes = [], []
    orig_v, orig_d = eng._verify, eng._decode
    eng._verify = lambda *a: (verifies.append(1), orig_v(*a))[1]
    eng._decode = lambda *a: (decodes.append(1), orig_d(*a))[1]
    for r in mk_requests(cfg, max_new=6):
        eng.submit(r)
    while eng.queue or any(eng.slots):
        before = len(verifies)
        n_active = eng.step()
        assert len(verifies) - before == (1 if n_active else 0)
    assert not decodes                 # the plain path never ran
    s = eng.stats()
    assert s["dispatches_per_tick"] == 1.0
    assert s["spec"]["verify_dispatches"] == s["decode_dispatches"]
    assert s["spec"]["verify_dispatches"] == len(verifies)


def test_accepted_tokens_per_dispatch_exceeds_plain():
    """The headline: at spec_k=4 the self-draft emits > 1.5 tokens per
    verify dispatch (the plain engine's ceiling is exactly 1) — the
    bench contract benchmarks/spec_bench.py gates on."""
    cfg, model, params, _ = _cell("bf16")[:4]
    eng = _engine(cfg, model, params, spec_k=4)
    serve(eng, mk_requests(cfg, max_new=8))
    assert eng.run_stats["spec"]["accepted_per_dispatch"] > 1.5


# ---------------------------------------------------------------------------
# family gating: unsupported backbones fall back cleanly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["moe", "ssm", "hybrid"])
def test_unsupported_family_clean_fallback(family):
    """Families without the ``verify_paged`` continuation serve a
    spec_k > 0 config identically to spec-off, with speculation
    reporting disabled and zero spec activity."""
    cfg, model, params, _ = setup(FAMILY_ARCHS[family])
    plain = serve(_engine(cfg, model, params), mk_requests(cfg))
    eng = _engine(cfg, model, params, spec_k=4)
    assert serve(eng, mk_requests(cfg)) == plain
    sp = eng.run_stats["spec"]
    assert sp["enabled"] is False
    assert sp["verify_dispatches"] == 0 and sp["drafted"] == 0
    assert sp["draft_prefill_dispatches"] == 0


# ---------------------------------------------------------------------------
# separate draft model: shared token space, rollback-heavy identity
# ---------------------------------------------------------------------------


def test_separate_draft_vocab_mismatch_raises():
    cfg, model, params, _ = _cell("bf16")[:4]
    bad = dataclasses.replace(cfg, name="draft-bad-vocab",
                              vocab_size=cfg.vocab_size // 2)
    with pytest.raises(ValueError, match="vocab_size"):
        _engine(cfg, model, params, spec_k=2, spec_draft_config=bad)


def test_separate_draft_identity_with_rejections():
    """An UNTRAINED 1-layer draft proposes mostly-wrong tokens: the
    rejected-suffix rollback runs constantly, and the output must STILL
    be bit-identical to the plain engine — acceptance only ever changes
    latency."""
    cfg, model, params, _ = _cell("bf16")[:4]
    dcfg = dataclasses.replace(cfg, name="stablelm-draft", num_layers=1)
    plain = serve(_engine(cfg, model, params), mk_requests(cfg, max_new=6))
    eng = _engine(cfg, model, params, spec_k=2, spec_draft_config=dcfg)
    assert serve(eng, mk_requests(cfg, max_new=6)) == plain
    sp = eng.run_stats["spec"]
    assert sp["enabled"] and not sp["self_draft"]
    assert sp["drafted"] > 0
    assert sp["drafted"] == sp["accepted"] + sp["rejected"]
    assert 0.0 <= sp["acceptance_rate"] <= 1.0
    assert_partition(eng)


def test_rollback_leaves_no_stale_pages():
    """Pages freed by the rejected-suffix rollback are reused by LATER
    admissions with no stale KV or int8-scale leakage: a second wave on
    the rollback-churned engine matches a fresh engine bit for bit."""
    cfg, model, params, _ = _cell("bf16")[:4]
    dcfg = dataclasses.replace(cfg, name="stablelm-draft", num_layers=1)

    def mk():
        return _engine(cfg, model, params, spec_k=2, spec_draft_config=dcfg,
                       kv_bits=8, n_pages=10)

    wave2 = [Request(uid=10 + i,
                     prompt=np.random.default_rng(300 + i).integers(
                         0, cfg.vocab_size, size=(6 + i,)),
                     max_new_tokens=5) for i in range(2)]
    churned = mk()
    serve(churned, mk_requests(cfg, max_new=6))      # rollback churn
    assert_partition(churned)
    got = serve(churned, wave2)
    fresh = serve(mk(), [Request(uid=r.uid, prompt=r.prompt.copy(),
                                 max_new_tokens=r.max_new_tokens)
                         for r in wave2])
    assert got == fresh
    assert_partition(churned)


def test_config_spec_validation_and_serde():
    with pytest.raises(ValueError, match="spec_k"):
        EngineConfig(spec_k=-1)
    dcfg = dataclasses.replace(setup("stablelm_3b")[0], name="d",
                               num_layers=1)
    with pytest.raises(ValueError, match="spec_draft_config"):
        EngineConfig(spec_draft_config=dcfg)
    c = EngineConfig(spec_k=3, spec_draft_config=dcfg)
    assert EngineConfig.from_json(c.to_json()) == c


# ---------------------------------------------------------------------------
# front-end: speculation is invisible to a streaming client
# ---------------------------------------------------------------------------


def test_frontend_streams_spec_tokens():
    cfg, model, params, _ = _cell("bf16")[:4]
    prompt = np.asarray([3, 1, 4, 1, 5], np.int64)
    ref = serve(_engine(cfg, model, params),
                [Request(uid=0, prompt=prompt.copy(), max_new_tokens=6)])[0]
    eng = _engine(cfg, model, params, spec_k=4)

    async def go():
        async with ServingFrontend(eng, host="127.0.0.1", port=0) as fe:
            return await http_generate("127.0.0.1", fe.port,
                                       {"prompt": prompt.tolist(),
                                        "max_new_tokens": 6})

    r = asyncio.run(go())
    assert r["status"] == 200
    assert r["tokens"] == ref
    # all decode-phase tokens went through verify (the first token
    # samples from prefill logits)
    assert eng.stats()["spec"]["emitted_tokens"] == len(ref) - 1


# ---------------------------------------------------------------------------
# chaos: the partition invariant under faults + preemption + cancel
# ---------------------------------------------------------------------------


def test_preemption_under_pool_pressure_identity():
    """A pool too small for three co-residents at full draft depth
    forces stalls and preemptions mid-speculation: preempted requests
    resume token-exact (vs a pressure-free plain run) and no page
    leaks."""
    cfg, model, params, _ = _cell("bf16")[:4]
    plain = serve(_engine(cfg, model, params, n_pages=64),
                  mk_requests(cfg, max_new=6))
    eng = _engine(cfg, model, params, spec_k=4, n_pages=8, max_slots=3)
    assert serve(eng, mk_requests(cfg, max_new=6)) == plain
    assert_partition(eng)


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_chaos_partition_invariant(seed):
    """Seeded chaos plans (NaN logits, dispatch raise, page-alloc fail,
    slow ticks + a random mid-run cancel) on a speculating, prefix-
    sharing, int8-KV engine: every request retires exactly once, and the
    free / cached / referenced page partition holds — rollback never
    leaks or double-frees a page."""
    ops.breaker.reset()
    try:
        rng = np.random.default_rng(seed)
        plan = FaultPlan.random(seed, n_faults=4,
                                sites=("nan_logits", "dispatch_raise",
                                       "page_alloc_fail", "slow_tick"),
                                uids=range(4), max_at=12)
        # quantized-interpret: dispatch_raise is recoverable through the
        # kernel circuit breaker's fallback jit
        cfg, model, params, policy = setup("stablelm_3b", quantized=True,
                                           use_kernels="interpret")
        eng = _engine(cfg, model, params, policy=policy, spec_k=2,
                      prefix_cache=True, n_pages=12, faults=plan,
                      nan_guard=True)
        serve(eng, [Request(uid=100, prompt=_sys(cfg), max_new_tokens=1)])
        reqs = _shared_reqs(cfg, n=2, max_new=5) + [
            Request(uid=2 + i,
                    prompt=np.random.default_rng(200 + i).integers(
                        0, cfg.vocab_size, size=(5 + i,)),
                    max_new_tokens=5) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        cancel_uid = int(rng.integers(4))
        cancel_tick = int(rng.integers(1, 6))
        for _ in range(300):
            if not (eng.queue or any(s is not None for s in eng.slots)):
                break
            eng.step()
            if eng.ticks == cancel_tick:
                eng.cancel(cancel_uid)
        done = {r.uid: r for r in eng.pop_retired()}
        assert sorted(u for u in done if u < 100) == list(range(4))
        assert not any(eng.slots)
        assert_partition(eng)
    finally:
        ops.breaker.reset()
