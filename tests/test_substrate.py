"""Substrate tests: optimizer, schedules, gradient compression,
checkpointing (atomic/keep-K/elastic), data pipeline, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, restore_pytree, save_pytree
from repro.configs.base import get_config
from repro.data import TokenFileDataset, calibration_stream, synthetic_batches
from repro.optim import (
    adamw,
    apply_error_feedback,
    compress_decompress,
    global_norm,
    warmup_cosine,
    warmup_linear,
)
from repro.launch import compat
from repro.runtime.fault_tolerance import (
    Heartbeat,
    PreemptionHandler,
    StragglerPolicy,
)

KEY = jax.random.PRNGKey(0)


# --- optimizer -------------------------------------------------------------


def test_adamw_reduces_quadratic_loss():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for step in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.step(params, state, grads, jnp.asarray(step))
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_clips_gradients():
    opt = adamw(1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, m = opt.step(params, state, {"w": jnp.full(4, 100.0)},
                       jnp.asarray(0))
    assert float(m["grad_norm"]) > 100  # reported pre-clip norm


def test_schedules():
    s = warmup_cosine(1e-3, 10, 100)
    assert float(s(jnp.asarray(0))) < 2e-4
    assert abs(float(s(jnp.asarray(10))) - 1e-3) < 2e-4
    assert float(s(jnp.asarray(99))) < 3e-4
    lin = warmup_linear(1.0, 0, 100)
    assert abs(float(lin(jnp.asarray(50))) - 0.5) < 0.02


def test_grad_compression_unbiased_and_error_feedback():
    g = {"a": jax.random.normal(KEY, (64, 64)) * 0.01}
    outs = [compress_decompress(g, jax.random.PRNGKey(i))["a"]
            for i in range(16)]
    mean = np.mean([np.asarray(o) for o in outs], axis=0)
    assert np.abs(mean - np.asarray(g["a"])).mean() < 2e-4  # unbiased
    # error feedback: residual carried, bounded by one quantization step
    err = jax.tree.map(jnp.zeros_like, g)
    comp, err = apply_error_feedback(g, err, KEY)
    step = float(jnp.abs(g["a"]).max()) / 127
    assert float(jnp.abs(err["a"]).max()) <= step + 1e-7


def test_compressed_psum_inside_shard_map():
    """int8-wire psum runs under shard_map and reconstructs the sum within
    one stochastic-rounding step per participant (single-device CI uses a
    size-1 'pod' axis; the cross-pod wire path is identical SPMD code)."""
    from jax.sharding import PartitionSpec as P

    from repro.optim import compressed_psum

    mesh = compat.make_mesh((1,), ("pod",))
    g = jax.random.normal(KEY, (1, 128)) * 0.01

    with compat.set_mesh(mesh):
        def f(gl):
            return compressed_psum({"g": gl[0]}, jax.random.PRNGKey(1),
                                   axis="pod")["g"]
        out = jax.jit(compat.shard_map(f, in_specs=(P("pod", None),),
                                    out_specs=P()))(g)
    expected = np.asarray(g.sum(0))
    got = np.asarray(out)
    step = np.abs(np.asarray(g)).max() / 127
    assert np.abs(got - expected).max() <= 2 * step + 1e-6


# --- checkpointing ---------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32)}}
    save_pytree(tree, str(tmp_path / "ck"))
    out = restore_pytree(jax.tree.map(jnp.zeros_like, tree),
                         str(tmp_path / "ck"))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpointer_keep_k_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"w": jnp.zeros(3)}
    for step in (1, 2, 3, 4):
        ck.save({"w": jnp.full(3, float(step))}, step)
    assert ck.steps() == [3, 4]  # GC keeps last 2
    restored, step = ck.restore_latest(tree)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]), [4, 4, 4])


def test_checkpointer_atomicity(tmp_path):
    """A leftover .tmp dir from a crash is never picked up by restore."""
    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    ck.save({"w": jnp.ones(2)}, 5)
    os.makedirs(str(tmp_path / "step_00000009.tmp"))
    assert ck.steps() == [5]


def test_elastic_restore_under_different_sharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore into any target sharding."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_pytree(tree, str(tmp_path / "ck"))
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    template = {"w": jax.device_put(jnp.zeros((4, 4)),
                                    NamedSharding(mesh, P("data", None)))}
    out = restore_pytree(template, str(tmp_path / "ck"))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# --- data ------------------------------------------------------------------


def test_synthetic_batches_resumable_determinism():
    cfg = get_config("stablelm_3b").reduced()
    a = next(iter(synthetic_batches(cfg, 2, 8, start=7)))
    b = next(iter(synthetic_batches(cfg, 2, 8, start=7)))
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_token_file_dataset(tmp_path):
    path = str(tmp_path / "toks.bin")
    np.arange(1000, dtype=np.uint16).tofile(path)
    ds = TokenFileDataset(path, seq_len=16)
    batch = next(ds.batches(4))
    assert batch["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(batch["labels"][:, :-1]),
                                  np.asarray(batch["tokens"][:, 1:]))


def test_calibration_stream_covers_families():
    for arch in ("stablelm_3b", "musicgen_large"):
        cfg = get_config(arch).reduced()
        batches = list(calibration_stream(cfg, n_batches=2, batch=2, seq=8))
        assert len(batches) == 2


# --- fault tolerance -------------------------------------------------------


def test_preemption_handler_flag():
    h = PreemptionHandler(signals=())
    assert not h.should_stop
    h.trigger()
    assert h.should_stop


def test_heartbeat(tmp_path):
    p = str(tmp_path / "hb")
    hb = Heartbeat(p, interval=0.05).start()
    import time

    time.sleep(0.15)
    assert Heartbeat.alive(p, timeout=5)
    hb.stop()


def test_straggler_policy():
    sp = StragglerPolicy(factor=3.0)
    for _ in range(10):
        assert not sp.observe(1.0)
    assert sp.observe(10.0)
    assert sp.flagged == 1


def test_elastic_mesh_single_device():
    from repro.runtime.fault_tolerance import elastic_mesh

    mesh = elastic_mesh(1, model_parallel=16)
    assert mesh.devices.size == 1
