"""End-to-end system tests: training converges, checkpoint-restart
resumes identically, the calibrate→fold→serve path produces coherent text
-generation behaviour, and the small-mesh dry-run (lower+compile with
sharded params) succeeds — the CPU-scale version of launch/dryrun.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data import synthetic_batches
from repro.launch.sharding import batch_spec, param_specs
from repro.launch.train import make_train_step, shard_train_fns
from repro.models.api import get_model
from repro.optim import adamw
from repro.launch import compat

KEY = jax.random.PRNGKey(0)


def test_training_loss_decreases():
    """~100k-param model on structured synthetic data: loss must drop."""
    cfg = get_config("stablelm_3b").reduced(num_layers=2, d_model=64,
                                            vocab_size=64)
    model = get_model(cfg)
    opt = adamw(3e-3)
    params = model.init(KEY, cfg)
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt),
                      static_argnames=())
    losses = []
    for i, batch in enumerate(synthetic_batches(cfg, 8, 32)):
        if i >= 30:
            break
        params, state, m = step_fn(params, state, batch, jnp.asarray(i),
                                   jax.random.fold_in(KEY, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_grad_accumulation_matches_full_batch():
    """microbatched grads ≡ full-batch grads (same loss trajectory)."""
    cfg = get_config("stablelm_3b").reduced(num_layers=2)
    model = get_model(cfg)
    opt = adamw(1e-3)
    batch = next(iter(synthetic_batches(cfg, 8, 16)))
    params = model.init(KEY, cfg)
    state = opt.init(params)
    s1 = make_train_step(model, cfg, opt, microbatches=1)
    s4 = make_train_step(model, cfg, opt, microbatches=4)
    p1, _, m1 = jax.jit(s1)(params, state, batch, jnp.asarray(0), KEY)
    p4, _, m4 = jax.jit(s4)(params, state, batch, jnp.asarray(0), KEY)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.02
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0.05)


def test_checkpoint_restart_resumes_identically(tmp_path):
    from repro.checkpoint import Checkpointer

    cfg = get_config("stablelm_3b").reduced(num_layers=2)
    model = get_model(cfg)
    opt = adamw(1e-3)
    step_fn = jax.jit(make_train_step(model, cfg, opt))

    def run(params, state, start, n):
        for i in range(start, start + n):
            batch = next(iter(synthetic_batches(cfg, 4, 16, start=i)))
            params, state, m = step_fn(params, state, batch, jnp.asarray(i),
                                       jax.random.fold_in(KEY, i))
        return params, state, float(m["loss"])

    params = model.init(KEY, cfg)
    state = opt.init(params)
    # straight run: 6 steps
    pa, sa, loss_a = run(params, state, 0, 6)
    # interrupted run: 3 steps → checkpoint → restore → 3 more
    pb, sb, _ = run(params, state, 0, 3)
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save({"p": pb, "s": sb}, 3)
    restored, step = ck.restore_latest({"p": pb, "s": sb})
    pc, sc, loss_c = run(restored["p"], restored["s"], 3, 3)
    assert abs(loss_a - loss_c) < 1e-3, (loss_a, loss_c)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-3)


def test_small_mesh_dryrun_lower_compile(test_mesh):
    """CPU-scale twin of launch/dryrun.py: shard specs + lower + compile
    + memory/cost analysis on a (1,1) mesh, abstract params only."""
    cfg = get_config("qwen15_4b").reduced()
    model = get_model(cfg)
    opt = adamw(1e-3)
    with compat.set_mesh(test_mesh):
        params_shape = jax.eval_shape(lambda k: model.init(k, cfg),
                                      jax.random.PRNGKey(0))
        opt_shape = jax.eval_shape(opt.init, params_shape)
        pspecs = param_specs(params_shape, cfg, test_mesh)
        ospecs = param_specs(opt_shape, cfg, test_mesh)
        bspec = batch_spec(test_mesh, 4)
        batch = {"tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32)}
        lowered = jax.jit(
            make_train_step(model, cfg, opt, microbatches=2),
            in_shardings=compat.jit_shardings(
                test_mesh, (pspecs, ospecs,
                            {"tokens": bspec, "labels": bspec}, None, None)),
        ).lower(params_shape, opt_shape, batch,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
        compiled = lowered.compile()
        assert compiled.memory_analysis() is not None
        from repro.launch.hlo_analysis import analyze_hlo

        metrics = analyze_hlo(compiled.as_text())
        assert metrics.flops > 0
        assert metrics.while_trips  # layer scan + microbatch scan present


def test_quantized_generation_coherent():
    """Train a tiny model until it learns the +1 token pattern, quantize
    W8A8, and check the quantized model still generates the pattern."""
    cfg = get_config("stablelm_3b").reduced(num_layers=2, d_model=64,
                                            vocab_size=32)
    model = get_model(cfg)
    opt = adamw(5e-3)
    params = model.init(KEY, cfg)
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt))
    rng = np.random.default_rng(0)
    for i in range(60):
        start = rng.integers(0, 32, size=(8, 1))
        toks = (start + np.arange(24)[None]) % 32  # strict +1 pattern
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        params, state, m = step_fn(params, state, batch, jnp.asarray(i),
                                   jax.random.fold_in(KEY, i))
    from repro.core.qlinear import QuantPolicy
    from repro.serving.fold import collect_calibration, fold_quantize

    toks = jnp.asarray((np.arange(16)[None] + 3) % 32, jnp.int32)
    stats = collect_calibration(model, params, cfg, [{"tokens": toks}])
    policy = QuantPolicy(weight_bits=8, act_bits=8, pack_weights=False,
                         use_kernels="never")
    qparams = fold_quantize(params, cfg, policy=policy, stats=stats)
    logits = model.forward(qparams, cfg, toks, policy=policy)
    preds = np.asarray(jnp.argmax(logits, -1))[0]
    target = np.asarray((toks[0] + 1) % 32)
    acc = (preds[4:-1] == target[4:-1]).mean()
    assert acc > 0.8, acc
