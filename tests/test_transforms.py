"""Equivalent-transformation tests (paper Eq. 3-4, §IV) incl. the paper's
central quantitative claims as properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core.difficulty import (
    layerwise_error,
    layerwise_error_transformed,
    quantization_difficulty,
)
from repro.core.outliers import OutlierSpec, massive_outlier_token, synth_activations
from repro.core.quantizer import QuantConfig
from repro.core.transforms import (
    TRANSFORMS,
    TransformPlan,
    get_transform,
    rotate,
    smooth,
    smooth_rotate,
    smoothing_scales,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("kind", list(TRANSFORMS))
@pytest.mark.parametrize("d", [128, 1536, 1408])
def test_numerical_equivalence(kind, d):
    """Eq. (3): x̂ŵ == xw for every transform, incl. Paley dims."""
    x = jax.random.normal(KEY, (16, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, 32)) * 0.1
    xh, wh = TRANSFORMS[kind](x, w)
    ref = np.asarray(x @ w)
    got = np.asarray(xh @ wh)
    np.testing.assert_allclose(got, ref, atol=5e-3 * max(1, np.abs(ref).max()))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([64, 128, 256]), st.floats(0.2, 0.8),
       st.integers(0, 1000))
def test_property_smooth_equivalence_any_alpha(d, alpha, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (8, d)) * 5
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, 16)) * 0.05
    xh, wh = smooth(x, w, alpha)
    np.testing.assert_allclose(np.asarray(xh @ wh), np.asarray(x @ w),
                               rtol=2e-2, atol=2e-3)


def test_smoothing_scales_formula():
    """Eq. (4) with α = 0.5: max|X̂_j| == max|Ŵ_j| == √(max|X_j|·max|W_j|)."""
    x = jax.random.normal(KEY, (32, 64)) * jnp.linspace(0.1, 30, 64)
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 48)) * 0.3
    xh, wh = smooth(x, w, 0.5)
    ax = np.abs(np.asarray(xh)).max(0)
    aw = np.abs(np.asarray(wh)).max(1)
    expected = np.sqrt(np.abs(np.asarray(x)).max(0)
                       * np.abs(np.asarray(w)).max(1))
    np.testing.assert_allclose(ax, expected, rtol=1e-4)
    np.testing.assert_allclose(aw, expected, rtol=1e-4)


def test_smoothing_flattens_systematic_outliers():
    spec = OutlierSpec(n_tokens=64, d=256, n_systematic=5)
    x = synth_activations(KEY, spec)
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 64)) * 0.05
    xh, _ = smooth(x, w)
    assert float(quantization_difficulty(xh)) < float(quantization_difficulty(x))


def test_rotation_reduces_error_on_systematic_outliers():
    """§IV-D: rotation beats identity (and usually smoothing) absent
    massive outliers."""
    spec = OutlierSpec(n_tokens=64, d=256, n_systematic=5)
    x = synth_activations(KEY, spec)
    w = jax.random.normal(jax.random.PRNGKey(4), (256, 64)) * 0.05
    e_none = float(layerwise_error(x, w))
    e_rot = float(layerwise_error_transformed(x, w, rotate))
    assert e_rot < e_none


def test_rotation_worse_with_massive_outliers():
    """The paper's counterintuitive finding (§IV-D): with token-level
    massive outliers, rotation can exceed the UNTRANSFORMED error —
    while smooth-rotation stays below rotation."""
    d = 256
    # ≥4 outlier dims per token puts the draw firmly in the Eq. (8) regime
    # (rotated max grows with Σ|o_i|); with 2 dims the effect is marginal
    # and flips sign across RNG draws.
    spec = OutlierSpec(n_tokens=64, d=d, base_std=0.25, n_systematic=0,
                       n_massive_tokens=4, n_massive_dims=4,
                       massive_value=2000.0)
    x = synth_activations(KEY, spec)
    w = jax.random.normal(jax.random.PRNGKey(5), (d, 64)) * 0.05
    e_none = float(layerwise_error(x, w))
    e_rot = float(layerwise_error_transformed(x, w, rotate))
    e_sr = float(layerwise_error_transformed(x, w, get_transform("smooth_rotate")))
    assert e_rot > e_none, (e_rot, e_none)
    assert e_sr < e_rot, (e_sr, e_rot)


def test_eq7_centroid_count():
    """Eq. (7): rotated massive-outlier token clusters at 2^{|O|-1}
    distinct |value| centroids (signs fold pairs together)."""
    d = 512
    for n_out in (1, 2, 3):
        dims = list(range(0, 7 * n_out, 7))
        vals = [900.0 + 137 * i for i in range(n_out)]
        t = massive_outlier_token(KEY, d, dims, vals, sigma=0.0)
        y = np.asarray(jax.device_get(
            jnp.matmul(t[None], jnp.asarray(
                __import__("repro.core.hadamard", fromlist=["hadamard_matrix"]
                           ).hadamard_matrix(d)))))[0]
        centroids = np.unique(np.round(np.abs(y), 3))
        assert len(centroids) == 2 ** (n_out - 1), (n_out, centroids)


def test_eq8_max_value():
    """Eq. (8): max|t̂| = Σ|o_i|/√d + |ε| (σ=0 ⇒ exact)."""
    d = 1024
    dims, vals = [3, 100, 511], [1000.0, -800.0, 1200.0]
    t = massive_outlier_token(KEY, d, dims, vals, sigma=0.0)
    from repro.core.hadamard import apply_hadamard

    y = np.asarray(apply_hadamard(t[None], d))[0]
    expected = sum(abs(v) for v in vals) / np.sqrt(d)
    np.testing.assert_allclose(np.abs(y).max(), expected, rtol=1e-4)


def test_eq9_smooth_rotate_max():
    """Eq. (9): after smooth(α=.5)+rotate, max|t̃| ≈ Σ√(|o_i|·max|W_i|/d)
    — and is far below rotation-only's Eq. (8) max."""
    d = 1024
    dims, vals = [10, 200], [1500.0, 2000.0]
    t = massive_outlier_token(KEY, d, dims, vals, sigma=0.05)
    x = jnp.tile(t[None], (8, 1))  # outlier token present in the batch
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (d, 64))) * 0.05 + 0.01
    s = smoothing_scales(x, w, 0.5)
    from repro.core.hadamard import apply_hadamard

    t_sr = np.asarray(apply_hadamard((t / s)[None], d))[0]
    wmax = np.abs(np.asarray(w)).max(1)
    expected = sum(np.sqrt(abs(v) * wmax[j] / d) for j, v in zip(dims, vals))
    assert abs(np.abs(t_sr).max() - expected) / expected < 0.35
    rot_only = sum(abs(v) for v in vals) / np.sqrt(d)
    assert np.abs(t_sr).max() < rot_only


def test_transform_plan_default_follows_paper():
    plan = TransformPlan()
    assert plan.kind_for("down_proj") == "smooth_rotate"  # §V recommendation
    assert plan.kind_for("k_proj") == "rotate"
