#!/usr/bin/env python3
"""Docs link checker: every RELATIVE link target in the given markdown
files must exist on disk (CI lint job; also run by
tests/test_docs_links.py so tier-1 catches a broken link locally).

Checked: inline links/images ``[text](target)`` whose target is not an
absolute URL (``scheme://``), ``mailto:``, or a pure in-page anchor
(``#...``).  Fragments are stripped before the existence check; targets
resolve relative to the file containing the link.

Usage: python tools/check_links.py [README.md docs/*.md ...]
(no arguments → README.md + docs/*.md relative to the repo root).
"""

from __future__ import annotations

import glob
import os
import re
import sys

# [text](target) — stops at the first ')' not preceded by whitespace;
# good enough for this repo's plain relative links
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = re.compile(r"^(?:[a-zA-Z][a-zA-Z0-9+.-]*://|mailto:|#)")


def check_file(path: str) -> list[str]:
    """Return 'file: target' strings for every broken relative link."""
    base = os.path.dirname(os.path.abspath(path))
    broken = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for target in _LINK.findall(text):
        if _SKIP.match(target):
            continue
        resolved = os.path.join(base, target.split("#", 1)[0])
        if not os.path.exists(resolved):
            broken.append(f"{path}: {target}")
    return broken


def main(argv: list[str]) -> int:
    paths = argv or (["README.md"] + sorted(glob.glob("docs/*.md")))
    paths = [p for p in paths if os.path.exists(p)]
    if not paths:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    broken = [b for p in paths for b in check_file(p)]
    for b in broken:
        print(f"BROKEN LINK {b}", file=sys.stderr)
    print(f"check_links: {len(paths)} files, "
          f"{len(broken)} broken relative link(s)")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
